//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! * ABL-HELP   — §3.4: M&S-style helping vs retry-with-fresh-state.
//! * ABL-WIN    — §3.1: protection window W sweep (throughput + memory).
//! * ABL-RECL   — §3.3: reclaim period N sweep + trigger policy.
//! * ABL-CURSOR — §3.5: scan-cursor on/off.
//! * ABL-BATCH  — DESIGN.md §7: operation batch-size sweep (1/8/64).
//! * ABL-MAG    — DESIGN.md §7: per-thread node magazines on/off.
//! * FAULT      — §3.6: stall/crash tolerance vs HP/EBR.
//!
//! `cargo bench --bench ablations` (env: `BENCH_OPS`, `BENCH_ROUNDS`).

use std::sync::Arc;

use cmpq::bench::faults::{
    cmp_stalled_consumer, ebr_stalled_reader, fault_table, hp_stalled_reader,
};
use cmpq::bench::sigma;
use cmpq::bench::workload::{run_throughput_on, PairConfig, TrialConfig};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};
use cmpq::queue::ConcurrentQueue;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

/// Mean throughput of `rounds` trials of a fresh queue per trial.
fn bench_config(make: &dyn Fn() -> CmpConfig, pair: PairConfig, ops: u64, rounds: usize) -> f64 {
    bench_config_batched(make, pair, ops, rounds, 1)
}

/// As [`bench_config`], with an explicit operation batch size.
fn bench_config_batched(
    make: &dyn Fn() -> CmpConfig,
    pair: PairConfig,
    ops: u64,
    rounds: usize,
    batch: usize,
) -> f64 {
    let mut samples = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let q: Arc<dyn ConcurrentQueue<u64>> =
            Arc::new(CmpQueue::<u64>::with_config(make()));
        let cfg = TrialConfig {
            total_ops: ops,
            batch_size: batch,
            ..TrialConfig::default()
        };
        samples.push(run_throughput_on(q, pair, &cfg).items_per_sec);
    }
    let (kept, _) = sigma::three_sigma(&samples);
    sigma::mean_std(&kept).0
}

fn main() {
    let ops = env_u64("BENCH_OPS", 60_000);
    let rounds = env_u64("BENCH_ROUNDS", 3) as usize;

    // ---------------- ABL-HELP ----------------
    println!("# ABL-HELP — §3.4 helping vs retry-with-fresh-state (items/s)");
    println!("{:<10}{:>16}{:>16}{:>10}", "config", "no-helping", "helping", "Δ%");
    for n in [1usize, 4, 16, 32] {
        let pair = PairConfig::symmetric(n);
        let no_help = bench_config(&CmpConfig::default, pair, ops, rounds);
        let help = bench_config(&|| CmpConfig::default().with_helping(), pair, ops, rounds);
        println!(
            "{:<10}{:>16.0}{:>16.0}{:>9.1}%",
            pair.label(),
            no_help,
            help,
            100.0 * (no_help - help) / help
        );
    }

    // ---------------- ABL-WIN ----------------
    println!("\n# ABL-WIN — §3.1 protection window sweep (4P4C)");
    println!("{:<12}{:>16}{:>18}", "window", "items/s", "peak pool nodes");
    for w in [256u64, 1024, 4096, 16384, 65536, 1 << 20] {
        let pair = PairConfig::symmetric(4);
        // One instrumented trial for footprint + separate rounds for rate.
        let q = Arc::new(CmpQueue::<u64>::with_config(
            CmpConfig::default().with_window(w),
        ));
        let cfg = TrialConfig {
            total_ops: ops,
            ..TrialConfig::default()
        };
        let dynq: Arc<dyn ConcurrentQueue<u64>> = q.clone();
        run_throughput_on(dynq, pair, &cfg);
        let footprint = q.footprint_nodes();
        let rate = bench_config(&|| CmpConfig::default().with_window(w), pair, ops, rounds);
        println!("{:<12}{:>16.0}{:>18}", w, rate, footprint);
    }

    // ---------------- ABL-RECL ----------------
    println!("\n# ABL-RECL — §3.3 reclaim trigger policy (4P4C, items/s)");
    println!("{:<14}{:>12}{:>16}", "period N", "modulo", "bernoulli");
    for n in [128u64, 512, 1024, 4096, 16384] {
        let pair = PairConfig::symmetric(4);
        let modulo = bench_config(
            &|| CmpConfig::default().with_reclaim_period(n),
            pair,
            ops,
            rounds,
        );
        let bern = bench_config(
            &|| {
                CmpConfig::default()
                    .with_reclaim_period(n)
                    .with_trigger(ReclaimTrigger::Bernoulli)
            },
            pair,
            ops,
            rounds,
        );
        println!("{:<14}{:>12.0}{:>16.0}", n, modulo, bern);
    }

    // ---------------- ABL-CURSOR ----------------
    println!("\n# ABL-CURSOR — §3.5 scan-cursor on/off (items/s)");
    println!("{:<10}{:>14}{:>14}{:>10}", "config", "cursor", "no-cursor", "speedup");
    for n in [1usize, 4, 16] {
        let pair = PairConfig::symmetric(n);
        let with = bench_config(&CmpConfig::default, pair, ops, rounds);
        let without = bench_config(
            &|| CmpConfig::default().without_scan_cursor(),
            pair,
            ops,
            rounds,
        );
        println!(
            "{:<10}{:>14.0}{:>14.0}{:>9.2}x",
            pair.label(),
            with,
            without,
            with / without
        );
    }

    // ---------------- ABL-BATCH ----------------
    println!("\n# ABL-BATCH — DESIGN.md §7 operation batch size (items/s)");
    println!(
        "{:<10}{:>14}{:>14}{:>14}{:>10}",
        "config", "batch-1", "batch-8", "batch-64", "64 vs 1"
    );
    for n in [1usize, 4, 8, 16] {
        let pair = PairConfig::symmetric(n);
        let b1 = bench_config_batched(&CmpConfig::default, pair, ops, rounds, 1);
        let b8 = bench_config_batched(&CmpConfig::default, pair, ops, rounds, 8);
        let b64 = bench_config_batched(&CmpConfig::default, pair, ops, rounds, 64);
        println!(
            "{:<10}{:>14.0}{:>14.0}{:>14.0}{:>9.2}x",
            pair.label(),
            b1,
            b8,
            b64,
            if b1 > 0.0 { b64 / b1 } else { 0.0 }
        );
    }

    // ---------------- ABL-MAG ----------------
    println!("\n# ABL-MAG — DESIGN.md §7 per-thread node magazines (items/s)");
    println!("{:<10}{:>14}{:>14}{:>10}", "config", "magazines", "global-only", "speedup");
    for n in [1usize, 4, 16] {
        let pair = PairConfig::symmetric(n);
        let with = bench_config(&CmpConfig::default, pair, ops, rounds);
        let without = bench_config(
            &|| CmpConfig::default().without_magazines(),
            pair,
            ops,
            rounds,
        );
        println!(
            "{:<10}{:>14.0}{:>14.0}{:>9.2}x",
            pair.label(),
            with,
            without,
            if without > 0.0 { with / without } else { 0.0 }
        );
    }

    // ---------------- FAULT ----------------
    println!();
    let churn = ops.min(50_000);
    let rows = vec![
        cmp_stalled_consumer(churn, 8),
        hp_stalled_reader(churn),
        ebr_stalled_reader(churn),
    ];
    println!("{}", fault_table(&rows));
}
