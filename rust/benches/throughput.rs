//! FIG1 — Figure 1 + the §4.1 throughput narrative: items/sec across
//! 1P1C…64P64C for CMP vs the paper's comparator set (plus the extra
//! baselines), with round-robin sequencing and 3-sigma filtering.
//!
//! `cargo bench --bench throughput` — or `repro bench fig1` for the
//! CLI-configurable version. Env knobs: `BENCH_OPS`, `BENCH_ROUNDS`,
//! `BENCH_FULL=1` to include every implementation.

use cmpq::bench::report;
use cmpq::bench::runner::{throughput_suite, SuiteOptions};
use cmpq::bench::workload::PairConfig;
use cmpq::queue::Impl;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = SuiteOptions {
        total_ops: env_u64("BENCH_OPS", 60_000),
        rounds: env_u64("BENCH_ROUNDS", 3) as usize,
        warmup_rounds: 1,
        verbose: std::env::var("BENCH_VERBOSE").is_ok(),
        ..SuiteOptions::default()
    };
    let impls: Vec<Impl> = if std::env::var("BENCH_FULL").is_ok() {
        Impl::ALL.to_vec()
    } else {
        // The paper's set + the lock-based comparator for context.
        vec![Impl::Cmp, Impl::Segmented, Impl::MsHp, Impl::Mutex]
    };
    let pairs = PairConfig::paper_sweep();

    eprintln!(
        "FIG1: {} impls × {} pairs × {} rounds, {} ops/trial",
        impls.len(),
        pairs.len(),
        opts.rounds,
        opts.total_ops
    );
    let cells = throughput_suite(&impls, &pairs, &opts);
    println!("{}", report::fig1_table(&cells));

    let series: Vec<(String, f64)> = cells
        .iter()
        .map(|c| (format!("{} {}", c.pair.label(), c.imp.name()), c.mean_ips))
        .collect();
    println!("{}", report::bar_chart("Figure 1 (items/sec)", &series, 48));

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(
        "bench_results/fig1_throughput.json",
        report::throughput_json(&cells),
    )
    .ok();
    eprintln!("wrote bench_results/fig1_throughput.json");
}
