//! BENCH — execute the declarative workload library.
//!
//! Every scenario axis lives in the committed `workloads/*.json` specs
//! (DESIGN.md §14): implementation sets, producer/consumer shapes,
//! batch mixes, arrival processes (closed / bursty open-loop / idle /
//! async tasks), zipf-skewed contention, the sharded fabric's
//! `max_rank_error` sweep, and the coordinator / TCP-ingress
//! transports. This binary holds **no** hard-coded axes: it loads the
//! library, runs each spec through the one generic driver
//! ([`cmpq::bench::runner::run_workload`]), prints the SLO report, and
//! writes `BENCH_throughput.json` — the machine-readable perf
//! trajectory `repro bench diff` gates on.
//!
//! `cargo bench --bench throughput` — or `repro bench --workload-dir
//! ../workloads` for the CLI version. Env knobs:
//!
//! * `BENCH_WORKLOAD_DIR` — library directory (default `../workloads`,
//!   the committed library relative to the crate root).
//! * `BENCH_SMOKE` — run each spec's `smoke_ops` × `smoke_pairs`
//!   instead of the full axes (the CI trajectory knob).
//! * `BENCH_VERBOSE` — per-trial progress on stderr.
//! * `BENCH_OPS` / `BENCH_PAIRS` — **deprecated** spec-shadowing
//!   overrides, kept for one-off experiments; each prints a
//!   deprecation note when it shadows a spec value.
//!
//! The Figure-1 table/JSON (paper-narrative rendering of the closed
//! loop) stays available via `repro bench fig1`.

use cmpq::bench::report;
use cmpq::bench::runner::{run_workload, WorkloadRunOptions};
use cmpq::bench::spec::load_workload_dir;

fn main() {
    let dir = std::env::var("BENCH_WORKLOAD_DIR").unwrap_or_else(|_| "../workloads".to_string());
    let specs = match load_workload_dir(std::path::Path::new(&dir)) {
        Ok(specs) => specs,
        Err(e) => {
            eprintln!("cannot load workload library from {dir:?}: {e}");
            std::process::exit(2);
        }
    };
    let opts = WorkloadRunOptions {
        smoke: std::env::var("BENCH_SMOKE").is_ok(),
        verbose: std::env::var("BENCH_VERBOSE").is_ok(),
    };
    eprintln!(
        "BENCH: {} workloads from {dir:?}{}",
        specs.len(),
        if opts.smoke { " (smoke axes)" } else { "" }
    );

    let mut rows = Vec::new();
    for mut spec in specs {
        spec.apply_env_overrides();
        eprintln!("-- workload {} --", spec.name);
        match run_workload(&spec, &opts) {
            Ok(mut r) => rows.append(&mut r),
            Err(e) => {
                eprintln!("workload {} failed: {e}", spec.name);
                std::process::exit(1);
            }
        }
    }

    println!("{}", report::slo_table(&rows));
    std::fs::write("BENCH_throughput.json", report::batch_throughput_json(&rows)).ok();
    eprintln!("wrote BENCH_throughput.json ({} rows)", rows.len());
}
