//! FIG1 — Figure 1 + the §4.1 throughput narrative: items/sec across
//! 1P1C…64P64C for CMP vs the paper's comparator set (plus the extra
//! baselines), with round-robin sequencing and 3-sigma filtering —
//! swept across an operation batch-size axis (1/8/64) so the
//! batch-amortization win (DESIGN.md §7) is measured, not asserted,
//! plus an offered-load scenario axis (bursty arrival bursts with idle
//! gaps, a zero-load idle floor, and async-task consumers riding the
//! §10 waker bridge) whose parking consumers report ops per CPU-second
//! (DESIGN.md §8, §10).
//!
//! `cargo bench --bench throughput` — or `repro bench fig1` for the
//! CLI-configurable version. Env knobs: `BENCH_OPS`, `BENCH_ROUNDS`,
//! `BENCH_BATCHES` (comma-separated, default `1,8,64`),
//! `BENCH_PAIRS` (comma-separated symmetric pair sizes, default the
//! paper's `1,2,4,8,16,32,64` sweep — CI smoke runs pass `1,4`),
//! `BENCH_SCENARIOS` (comma-separated extra scenarios, default
//! `bursty,idle,async`; empty string disables), `BENCH_FULL=1` to
//! include every implementation.
//!
//! The run ends with the sharded fabric's rank-error axis (DESIGN.md
//! §13): strict vs relaxed `ShardedCmp` measured with
//! [`cmpq::bench::workload::rank_error_trial`], emitted as
//! `rank-strict` / `rank-relaxed` scenario rows whose
//! `rank_error_p99` field is a number instead of `null`.
//!
//! Outputs:
//! * `bench_results/fig1_throughput.json` — the batch-1 Figure 1 cells
//!   (unchanged schema).
//! * `BENCH_throughput.json` — impl × threads × batch × scenario →
//!   ops/s + ops per CPU-second + CPU utilization + p99 rank error,
//!   the machine-readable perf trajectory tracked across PRs.

use std::sync::Arc;
use std::time::Duration;

use cmpq::bench::report::{self, BatchThroughputRow};
use cmpq::bench::runner::{throughput_suite, SuiteOptions, ThroughputCell};
use cmpq::bench::workload::{rank_error_trial, PairConfig, Scenario};
use cmpq::queue::Impl;
use cmpq::{ConcurrentQueue, ShardMode, ShardedCmp, ShardedConfig};

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn env_batches() -> Vec<usize> {
    let mut batches: Vec<usize> = std::env::var("BENCH_BATCHES")
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&b| b > 0)
                .collect()
        })
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![1, 8, 64]);
    // Batch 1 is the amortization baseline and feeds the Figure-1
    // outputs; always include it, and drop duplicates so no batch size
    // is swept (or reported) twice.
    if !batches.contains(&1) {
        batches.insert(0, 1);
    }
    let mut seen = Vec::new();
    batches.retain(|b| {
        if seen.contains(b) {
            false
        } else {
            seen.push(*b);
            true
        }
    });
    batches
}

/// `BENCH_PAIRS=1,4` → symmetric 1P1C and 4P4C; unset/empty → the
/// paper's full Figure-1 sweep. Lets CI run a smoke-sized matrix with
/// keys that stay a subset of the full run's.
fn env_pairs() -> Vec<PairConfig> {
    std::env::var("BENCH_PAIRS")
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .map(PairConfig::symmetric)
                .collect()
        })
        .filter(|v: &Vec<PairConfig>| !v.is_empty())
        .unwrap_or_else(PairConfig::paper_sweep)
}

fn main() {
    let base_opts = SuiteOptions {
        total_ops: env_u64("BENCH_OPS", 60_000),
        rounds: env_u64("BENCH_ROUNDS", 3) as usize,
        warmup_rounds: 1,
        verbose: std::env::var("BENCH_VERBOSE").is_ok(),
        ..SuiteOptions::default()
    };
    let impls: Vec<Impl> = if std::env::var("BENCH_FULL").is_ok() {
        Impl::ALL.to_vec()
    } else {
        // The paper's set + the lock-based comparator for context.
        vec![Impl::Cmp, Impl::Segmented, Impl::MsHp, Impl::Mutex]
    };
    let pairs = env_pairs();
    let batches = env_batches();

    eprintln!(
        "FIG1: {} impls × {} pairs × {} batch sizes × {} rounds, {} ops/trial",
        impls.len(),
        pairs.len(),
        batches.len(),
        base_opts.rounds,
        base_opts.total_ops
    );

    let mut rows: Vec<BatchThroughputRow> = Vec::new();
    for &batch in &batches {
        let opts = SuiteOptions {
            batch_size: batch,
            ..base_opts.clone()
        };
        eprintln!("-- batch size {batch} --");
        let cells = throughput_suite(&impls, &pairs, &opts);

        if batch == 1 {
            println!("{}", report::fig1_table(&cells));
            let series: Vec<(String, f64)> = cells
                .iter()
                .map(|c| (format!("{} {}", c.pair.label(), c.imp.name()), c.mean_ips))
                .collect();
            println!("{}", report::bar_chart("Figure 1 (items/sec)", &series, 48));
            std::fs::create_dir_all("bench_results").ok();
            std::fs::write(
                "bench_results/fig1_throughput.json",
                report::throughput_json(&cells),
            )
            .ok();
            eprintln!("wrote bench_results/fig1_throughput.json");
        }

        rows.extend(cells.into_iter().map(|cell| BatchThroughputRow {
            cell,
            batch,
            scenario: "closed",
            rank_error_p99: None,
        }));
    }

    // Batch-amortization summary: CMP speedup of each batch size over
    // batch-1 at the same thread count.
    if batches.len() > 1 {
        println!("# Batch amortization — CMP items/s vs batch-1");
        print!("{:<10}", "config");
        for b in &batches {
            print!("{:>14}", format!("batch-{b}"));
        }
        println!();
        for p in &pairs {
            let base = rows
                .iter()
                .find(|r| r.cell.imp == Impl::Cmp && r.cell.pair == *p && r.batch == 1)
                .map(|r| r.cell.mean_ips)
                .unwrap_or(0.0);
            print!("{:<10}", p.label());
            for &b in &batches {
                let ips = rows
                    .iter()
                    .find(|r| r.cell.imp == Impl::Cmp && r.cell.pair == *p && r.batch == b)
                    .map(|r| r.cell.mean_ips)
                    .unwrap_or(0.0);
                if base > 0.0 {
                    print!("{:>13.2}x", ips / base);
                } else {
                    print!("{:>14}", "-");
                }
            }
            println!();
        }
    }

    // Offered-load scenario axis (DESIGN.md §8): bursty open-loop
    // arrivals and the zero-load idle floor, both with parking
    // consumers — measuring ops per CPU-second, not just wall clock.
    let scenarios: Vec<String> = std::env::var("BENCH_SCENARIOS")
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_else(|_| {
            vec![
                "bursty".to_string(),
                "idle".to_string(),
                "async".to_string(),
            ]
        });
    for name in &scenarios {
        let (scenario, scen_pairs, rounds) = match name.as_str() {
            "bursty" => (
                Scenario::Bursty {
                    burst: 512,
                    gap: Duration::from_millis(2),
                },
                vec![
                    PairConfig::symmetric(1),
                    PairConfig::symmetric(4),
                    PairConfig::symmetric(16),
                ],
                2usize,
            ),
            "idle" => (
                Scenario::Idle {
                    hold: Duration::from_millis(400),
                },
                vec![PairConfig::symmetric(4)],
                1usize,
            ),
            // The async bridge (DESIGN.md §10): consumer threads host
            // 4 async tasks each; CMP resolves on push-side waker
            // wakeups, baselines on the polling default — the row is
            // the measured cost/win of futures vs consumer threads.
            "async" => (
                Scenario::Async {
                    tasks_per_consumer: 4,
                },
                vec![PairConfig::symmetric(1), PairConfig::symmetric(4)],
                2usize,
            ),
            other => {
                eprintln!("unknown scenario {other:?} (bursty|idle|async), skipping");
                continue;
            }
        };
        eprintln!("-- scenario {} --", scenario.label());
        let opts = SuiteOptions {
            scenario,
            rounds,
            warmup_rounds: 0,
            ..base_opts.clone()
        };
        let cells = throughput_suite(&impls, &scen_pairs, &opts);
        println!(
            "# Scenario {} — items/s, ops per CPU-second, CPU util per thread",
            scenario.label()
        );
        println!(
            "{:<10}{:<12}{:>14}{:>18}{:>10}",
            "config", "impl", "items/s", "ops/cpu-s", "util"
        );
        for c in &cells {
            println!(
                "{:<10}{:<12}{:>14.0}{:>18.0}{:>10.4}",
                c.pair.label(),
                c.imp.name(),
                c.mean_ips,
                c.mean_ops_per_cpu,
                c.mean_cpu_util
            );
        }
        rows.extend(cells.into_iter().map(|cell| BatchThroughputRow {
            cell,
            batch: 1,
            scenario: scenario.label(),
            rank_error_p99: None,
        }));
    }

    // Rank-error axis (DESIGN.md §13): the sharded fabric's ordering
    // quality vs throughput. Strict pays one head-shard ticket RMW per
    // push and must hold rank error at ~0; relaxed round-robins
    // producers and is the row that shows what the bound buys.
    // Stamping is racy (`serialize_stamps = false`) so the producer
    // side stays contention-honest — the correctness oracle in
    // `tests/sharded_fabric.rs` is where exact-zero is asserted.
    // CPU columns are 0 (unmeasured) so `bench diff` never CPU-flags
    // these rows.
    let rank_ops = base_opts.total_ops;
    let rank_pairs = [PairConfig::symmetric(1), PairConfig::symmetric(4)];
    println!("# Sharded fabric — rank error vs items/s (4 shards)");
    println!(
        "{:<10}{:<14}{:>14}{:>10}{:>10}{:>10}",
        "config", "mode", "items/s", "rank p50", "rank p99", "rank max"
    );
    for (label, mode) in [
        ("rank-strict", ShardMode::Strict),
        (
            "rank-relaxed",
            ShardMode::Relaxed {
                max_rank_error: 4096,
            },
        ),
    ] {
        for pair in rank_pairs {
            // Warmup with default windows to observe the machine's
            // dequeue rate, then re-size the per-shard protection
            // windows for ~0.5 s of resilience at that rate.
            let warm: Arc<dyn ConcurrentQueue<u64>> = Arc::new(ShardedCmp::with_config(
                ShardedConfig::default().with_mode(mode),
            ));
            let rate = rank_error_trial(warm, pair, rank_ops.min(20_000), false).items_per_sec;
            let cfg = ShardedConfig::default()
                .with_mode(mode)
                .sized_for_rate(rate.max(1.0) as u64, 0.5);
            let q: Arc<dyn ConcurrentQueue<u64>> = Arc::new(ShardedCmp::with_config(cfg));
            let trial = rank_error_trial(q, pair, rank_ops, false);
            println!(
                "{:<10}{:<14}{:>14.0}{:>10}{:>10}{:>10}",
                pair.label(),
                label,
                trial.items_per_sec,
                trial.stats.p50,
                trial.stats.p99,
                trial.stats.max
            );
            rows.push(BatchThroughputRow {
                cell: ThroughputCell {
                    imp: Impl::Sharded,
                    pair,
                    samples: vec![trial.items_per_sec],
                    mean_ips: trial.items_per_sec,
                    std_ips: 0.0,
                    discarded: 0,
                    mean_ops_per_cpu: 0.0,
                    mean_cpu_util: 0.0,
                },
                batch: 1,
                scenario: label,
                rank_error_p99: Some(trial.stats.p99),
            });
        }
    }

    std::fs::write("BENCH_throughput.json", report::batch_throughput_json(&rows)).ok();
    eprintln!("wrote BENCH_throughput.json ({} rows)", rows.len());
}
