//! TAB1–TAB3 — the paper's latency tables: avg + P99 enqueue/dequeue
//! latency (ns) at 1P1C (Table 1), 4P4C (Table 2), 32P32C (Table 3),
//! plus the 64P64C numbers quoted in the text. 3-sigma filtered per §4.
//!
//! `cargo bench --bench latency` (env: `BENCH_OPS`, `BENCH_ROUNDS`).

use cmpq::bench::report;
use cmpq::bench::runner::{latency_suite, SuiteOptions};
use cmpq::bench::workload::PairConfig;
use cmpq::queue::Impl;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = SuiteOptions {
        total_ops: env_u64("BENCH_OPS", 40_000),
        rounds: env_u64("BENCH_ROUNDS", 2) as usize,
        warmup_rounds: 1,
        verbose: std::env::var("BENCH_VERBOSE").is_ok(),
        ..SuiteOptions::default()
    };
    let impls = [Impl::Cmp, Impl::Segmented, Impl::MsHp];
    let pairs = [
        PairConfig::symmetric(1),
        PairConfig::symmetric(4),
        PairConfig::symmetric(32),
        PairConfig::symmetric(64),
    ];
    eprintln!(
        "TABLES: {} impls × {:?} × {} rounds",
        impls.len(),
        pairs.iter().map(|p| p.label()).collect::<Vec<_>>(),
        opts.rounds
    );
    let cells = latency_suite(&impls, &pairs, &opts);
    let titles = [
        "Table 1 — Latency with no contention (1P1C, ns)",
        "Table 2 — Balanced contention (4P4C, ns)",
        "Table 3 — High contention (32P32C, ns)",
        "Extreme contention (64P64C, ns — §4.1 text)",
    ];
    for (p, title) in pairs.iter().zip(titles) {
        let sub: Vec<_> = cells.iter().filter(|c| c.pair == *p).cloned().collect();
        println!("{}", report::latency_table(title, &sub));
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/tables_latency.json", report::latency_json(&cells)).ok();
    eprintln!("wrote bench_results/tables_latency.json");
}
