//! FIG2 — Figure 2: performance retention under synthetic mixed load
//! (threads compute between queue ops, inducing cache pressure and
//! scheduling interference). Retention = loaded / baseline throughput.
//!
//! `cargo bench --bench retention` (env: `BENCH_OPS`, `BENCH_ROUNDS`,
//! `BENCH_INTENSITY`).

use cmpq::bench::report;
use cmpq::bench::runner::{retention_suite, SuiteOptions};
use cmpq::bench::workload::PairConfig;
use cmpq::queue::Impl;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = SuiteOptions {
        total_ops: env_u64("BENCH_OPS", 30_000),
        rounds: env_u64("BENCH_ROUNDS", 2) as usize,
        warmup_rounds: 1,
        verbose: std::env::var("BENCH_VERBOSE").is_ok(),
        ..SuiteOptions::default()
    };
    let intensity = env_u64("BENCH_INTENSITY", 8) as u32;
    let impls = [Impl::Cmp, Impl::Segmented, Impl::MsHp];
    // Figure 2 reports the paper sweep; 8P8C is its headline point.
    let pairs: Vec<PairConfig> = [1usize, 2, 4, 8, 16, 32]
        .into_iter()
        .map(PairConfig::symmetric)
        .collect();

    eprintln!(
        "FIG2: baseline vs synthetic(x{intensity}), {} impls × {} pairs",
        impls.len(),
        pairs.len()
    );
    let cells = retention_suite(&impls, &pairs, &opts, intensity);
    println!("{}", report::fig2_table(&cells));

    let series: Vec<(String, f64)> = cells
        .iter()
        .map(|c| {
            (
                format!("{} {}", c.pair.label(), c.imp.name()),
                c.retention_pct,
            )
        })
        .collect();
    println!("{}", report::bar_chart("Figure 2 (retention %)", &series, 48));

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig2_retention.json", report::retention_json(&cells)).ok();
    eprintln!("wrote bench_results/fig2_retention.json");
}
