//! `repro` — CLI launcher for the CMP reproduction.
//!
//! ```text
//! repro bench fig1      reproduce Figure 1 (+ throughput table)
//! repro bench tables    reproduce Tables 1–3 (latency)
//! repro bench fig2      reproduce Figure 2 (retention under load)
//! repro bench faults    FAULT experiment (stall/crash tolerance)
//! repro bench all       everything above
//! repro serve           run the inference pipeline on the AOT model
//! repro chaos           fault-injection run with conservation check
//! repro selftest        runtime numerics check against testvec.json
//! repro demo            quickstart walk-through
//! ```
//!
//! Common options: `--ops N --rounds R --threads 1,2,4 --impls a,b,c
//! --verbose --out-dir bench_results`.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpq::bench::faults::{cmp_stalled_consumer, ebr_stalled_reader, fault_table, hp_stalled_reader};
use cmpq::bench::report;
use cmpq::bench::runner::{latency_suite, retention_suite, throughput_suite, SuiteOptions};
use cmpq::bench::synthetic::LoadProfile;
use cmpq::bench::workload::PairConfig;
use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::queue::Impl;
use cmpq::runtime::client::artifacts_dir;
use cmpq::runtime::{ModelRuntime, TestVectors};
use cmpq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "chaos" => cmd_chaos(&args),
        "selftest" => cmd_selftest(&args),
        "demo" => cmd_demo(),
        _ => {
            eprintln!("{}", HELP);
            if cmd == "help" {
                0
            } else {
                eprintln!("unknown command: {cmd}");
                2
            }
        }
    };
    std::process::exit(code);
}

const HELP: &str = "repro — CMP queue reproduction (see README.md)\n\
commands:\n  \
bench <fig1|tables|fig2|faults|sharded|all> [--ops N] [--rounds R] [--threads 1,2,..] [--impls a,b] [--batch K] [--verbose]\n  \
bench --workload <spec.json> [--workload ..] [--workload-dir D] [--smoke] [--verbose]   run declarative workload specs (README Workloads)\n  \
bench sharded [--shards N] [--relaxed] [--max-rank-error K] [--ops N] [--threads 1,4]   rank error vs ops/s (DESIGN.md §13)\n  \
bench diff <old.json> <new.json> [--threshold-pct P]   compare two BENCH_throughput.json dumps\n  \
serve [--requests N] [--clients C] [--shards S] [--workers W] [--idle-ms N] [--async-workers] [--adaptive] [--metrics-port P] [--echo]\n  \
serve --tcp [--addr A] [--io-threads N] [--tenant-max-inflight T] [--requests N] [--clients C] [--adaptive] [--metrics-port P]\n  \
chaos [--requests N] [--clients C] [--seed S] [--p-panic P] [--p-delay P] [--delay-us U] [--max-inflight D]\n  \
chaos --tcp [--connections N] [--concurrency K] [--io-threads N] [--seed S] [--p-net P] [--p-disconnect P] [--p-stall P] [--read-timeout-ms M]\n  \
selftest [--artifacts DIR]\n  \
demo";

fn suite_options(args: &Args) -> SuiteOptions {
    SuiteOptions {
        total_ops: args.get_parse("ops", 50_000u64),
        rounds: args.get_parse("rounds", 3usize),
        warmup_rounds: args.get_parse("warmup", 1usize),
        load: LoadProfile::None,
        capacity_hint: args.get_parse("capacity", 1usize << 16),
        batch_size: args.get_parse("batch", 1usize),
        verbose: args.flag("verbose"),
        ..SuiteOptions::default()
    }
}

fn parse_impls(args: &Args) -> Vec<Impl> {
    match args.get_list::<String>("impls") {
        Some(names) => names
            .iter()
            .map(|n| Impl::parse(n).unwrap_or_else(|| panic!("unknown impl {n:?}")))
            .collect(),
        None => Impl::PAPER_SET.to_vec(),
    }
}

fn parse_pairs(args: &Args) -> Vec<PairConfig> {
    match args.get_list::<usize>("threads") {
        Some(ns) => ns.into_iter().map(PairConfig::symmetric).collect(),
        None => PairConfig::paper_sweep(),
    }
}

fn write_out(args: &Args, name: &str, content: &str) {
    let dir = args.get_or("out-dir", "bench_results");
    std::fs::create_dir_all(dir).expect("create out dir");
    let path = format!("{dir}/{name}");
    std::fs::write(&path, content).expect("write results");
    eprintln!("wrote {path}");
}

/// `repro bench diff <old.json> <new.json>`: compare two
/// `BENCH_throughput.json` perf-trajectory dumps and flag ops/s and
/// ops/CPU-s regressions beyond `--threshold-pct` (default 10%).
/// Exits nonzero when any row regressed, so CI (or a pre-merge check)
/// can gate on it.
fn cmd_bench_diff(args: &Args) -> i32 {
    let (Some(old_path), Some(new_path)) = (args.positional.get(2), args.positional.get(3))
    else {
        eprintln!("usage: repro bench diff <old.json> <new.json> [--threshold-pct P]");
        return 2;
    };
    let threshold: f64 = args.get_parse("threshold-pct", 10.0f64);
    let read = |p: &str| {
        std::fs::read_to_string(p).unwrap_or_else(|e| {
            eprintln!("cannot read {p}: {e}");
            std::process::exit(2);
        })
    };
    let (old, new) = (read(old_path), read(new_path));
    match report::diff_bench_json(&old, &new, threshold) {
        Ok(diff) => {
            print!("{}", diff.table());
            let n = diff.regressions();
            if n > 0 {
                eprintln!("bench diff: {n} row(s) regressed more than {threshold:.1}%");
                1
            } else {
                eprintln!("bench diff: no regressions beyond {threshold:.1}%");
                0
            }
        }
        Err(e) => {
            eprintln!("bench diff: {e}");
            2
        }
    }
}

/// `repro bench sharded [--shards N] [--relaxed] [--max-rank-error K]`:
/// the sharded fabric's ordering-quality axis (DESIGN.md §13). Runs
/// [`rank_error_trial`] over a [`ShardedCmp`] with windows sized from
/// a measured warmup rate and prints rank-error percentiles next to
/// throughput — strict should sit at ~0, relaxed under its bound.
fn cmd_bench_sharded(args: &Args) -> i32 {
    use cmpq::bench::workload::rank_error_trial;
    use cmpq::queue::ConcurrentQueue;
    use cmpq::{ShardMode, ShardedCmp, ShardedConfig};

    let shards: usize = args.get_parse("shards", 4usize);
    let max_rank_error: u64 = args.get_parse("max-rank-error", 4096u64);
    let mode = if args.flag("relaxed") {
        ShardMode::Relaxed { max_rank_error }
    } else {
        ShardMode::Strict
    };
    let ops: u64 = args.get_parse("ops", 50_000u64);
    let pairs: Vec<PairConfig> = args
        .get_list::<usize>("threads")
        .map(|ns| ns.into_iter().map(PairConfig::symmetric).collect())
        .unwrap_or_else(|| vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]);
    let pin = args.flag("pin");

    println!(
        "# Sharded fabric — {} mode, {shards} shards, {ops} ops{}",
        if mode.is_strict() { "strict" } else { "relaxed" },
        if pin { ", pinned" } else { "" }
    );
    println!(
        "{:<10}{:>14}{:>10}{:>10}{:>10}{:>12}",
        "config", "items/s", "rank p50", "rank p99", "rank max", "conserved"
    );
    for pair in pairs {
        let base = || {
            ShardedConfig::default()
                .with_shards(shards)
                .with_mode(mode)
                .with_pinning(pin)
        };
        let warm: Arc<dyn ConcurrentQueue<u64>> = Arc::new(ShardedCmp::with_config(base()));
        let rate = rank_error_trial(warm, pair, ops.min(20_000), false).items_per_sec;
        let q: Arc<dyn ConcurrentQueue<u64>> = Arc::new(ShardedCmp::with_config(
            base().sized_for_rate(rate.max(1.0) as u64, 0.5),
        ));
        let trial = rank_error_trial(q, pair, ops, false);
        println!(
            "{:<10}{:>14.0}{:>10}{:>10}{:>10}{:>12}",
            pair.label(),
            trial.items_per_sec,
            trial.stats.p50,
            trial.stats.p99,
            trial.stats.max,
            if trial.items == ops { "yes" } else { "NO" }
        );
        if trial.items != ops {
            eprintln!("bench sharded: conservation broken ({} != {ops})", trial.items);
            return 1;
        }
    }
    0
}

/// `repro bench --workload <spec.json> [--workload-dir D] [--smoke]`:
/// run declarative workload specs (README "Workloads") through the
/// generic driver and write the SLO rows to `BENCH_throughput.json` —
/// the same dump `cargo bench --bench throughput` produces from the
/// committed library, diffable with `repro bench diff`.
fn cmd_bench_workload(args: &Args) -> i32 {
    use cmpq::bench::runner::{run_workload, WorkloadRunOptions};
    use cmpq::bench::spec::{load_workload_dir, WorkloadSpec};

    let mut specs: Vec<WorkloadSpec> = Vec::new();
    if let Some(dir) = args.get("workload-dir") {
        match load_workload_dir(Path::new(dir)) {
            Ok(mut s) => specs.append(&mut s),
            Err(e) => {
                eprintln!("bench workload: {e}");
                return 2;
            }
        }
    }
    for path in args.get_all("workload") {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bench workload: cannot read {path}: {e}");
                return 2;
            }
        };
        match WorkloadSpec::parse(&text) {
            Ok(s) => specs.push(s),
            Err(e) => {
                eprintln!("bench workload: {path}: {e}");
                return 2;
            }
        }
    }
    if specs.is_empty() {
        eprintln!("bench workload: no specs (pass --workload <file> or --workload-dir <dir>)");
        return 2;
    }
    let opts = WorkloadRunOptions {
        smoke: args.flag("smoke"),
        verbose: args.flag("verbose"),
    };
    let mut rows = Vec::new();
    for mut spec in specs {
        spec.apply_env_overrides();
        eprintln!("-- workload {} --", spec.name);
        match run_workload(&spec, &opts) {
            Ok(mut r) => rows.append(&mut r),
            Err(e) => {
                eprintln!("bench workload: {e}");
                return 1;
            }
        }
    }
    println!("{}", report::slo_table(&rows));
    std::fs::write("BENCH_throughput.json", report::batch_throughput_json(&rows))
        .expect("write BENCH_throughput.json");
    eprintln!("wrote BENCH_throughput.json ({} rows)", rows.len());
    0
}

fn cmd_bench(args: &Args) -> i32 {
    if args.get("workload").is_some() || args.get("workload-dir").is_some() {
        return cmd_bench_workload(args);
    }
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    if what == "diff" {
        return cmd_bench_diff(args);
    }
    if what == "sharded" {
        return cmd_bench_sharded(args);
    }
    let impls = parse_impls(args);
    let pairs = parse_pairs(args);
    let opts = suite_options(args);

    let run_fig1 = || {
        eprintln!(
            "== FIG1: throughput sweep ({} impls × {} pairs) ==",
            impls.len(),
            pairs.len()
        );
        let cells = throughput_suite(&impls, &pairs, &opts);
        let table = report::fig1_table(&cells);
        println!("{table}");
        let series: Vec<(String, f64)> = cells
            .iter()
            .map(|c| (format!("{} {}", c.pair.label(), c.imp.name()), c.mean_ips))
            .collect();
        println!("{}", report::bar_chart("Figure 1 (items/sec)", &series, 48));
        write_out(args, "fig1_throughput.txt", &table);
        write_out(args, "fig1_throughput.json", &report::throughput_json(&cells));
    };
    let run_tables = || {
        // The paper's latency tables: 1P1C, 4P4C, 32P32C (+64P64C text).
        let latency_pairs: Vec<PairConfig> = args
            .get_list::<usize>("threads")
            .map(|ns| ns.into_iter().map(PairConfig::symmetric).collect())
            .unwrap_or_else(|| {
                vec![
                    PairConfig::symmetric(1),
                    PairConfig::symmetric(4),
                    PairConfig::symmetric(32),
                    PairConfig::symmetric(64),
                ]
            });
        eprintln!("== TABLES 1–3: latency ==");
        let cells = latency_suite(&impls, &latency_pairs, &opts);
        let mut all = String::new();
        for (i, p) in latency_pairs.iter().enumerate() {
            let sub: Vec<_> = cells.iter().filter(|c| c.pair == *p).cloned().collect();
            let title = match i {
                0 => format!("Table 1 — no contention ({})", p.label()),
                1 => format!("Table 2 — balanced contention ({})", p.label()),
                2 => format!("Table 3 — high contention ({})", p.label()),
                _ => format!("Extreme contention ({})", p.label()),
            };
            let t = report::latency_table(&title, &sub);
            println!("{t}");
            all.push_str(&t);
            all.push('\n');
        }
        write_out(args, "tables_latency.txt", &all);
        write_out(args, "tables_latency.json", &report::latency_json(&cells));
    };
    let run_fig2 = || {
        eprintln!("== FIG2: retention under synthetic load ==");
        let intensity = args.get_parse("intensity", 8u32);
        let cells = retention_suite(&impls, &pairs, &opts, intensity);
        let table = report::fig2_table(&cells);
        println!("{table}");
        write_out(args, "fig2_retention.txt", &table);
        write_out(args, "fig2_retention.json", &report::retention_json(&cells));
    };
    let run_faults = || {
        eprintln!("== FAULT: stalled/crashed participants ==");
        let churn = args.get_parse("ops", 50_000u64);
        let rows = vec![
            cmp_stalled_consumer(churn, 8),
            hp_stalled_reader(churn),
            ebr_stalled_reader(churn),
        ];
        let t = fault_table(&rows);
        println!("{t}");
        write_out(args, "faults.txt", &t);
    };

    match what {
        "fig1" => run_fig1(),
        "tables" => run_tables(),
        "fig2" => run_fig2(),
        "faults" => run_faults(),
        "all" => {
            run_fig1();
            run_tables();
            run_fig2();
            run_faults();
        }
        other => {
            eprintln!("unknown bench target {other:?} (fig1|tables|fig2|faults|sharded|all|diff)");
            return 2;
        }
    }
    0
}

fn model_factory(dir: &Path) -> EngineFactory {
    let dir = dir.to_path_buf();
    Arc::new(move || {
        let rt = ModelRuntime::load_from_artifacts(&dir)?;
        Ok(Box::new(rt) as Box<dyn InferenceEngine>)
    })
}

fn echo_factory() -> EngineFactory {
    Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features: 128,
            outputs: 16,
            scale: 1.0,
        }) as Box<dyn InferenceEngine>)
    })
}

fn cmd_serve(args: &Args) -> i32 {
    let dir = artifacts_dir();
    let use_echo = args.flag("echo")
        || !cfg!(feature = "pjrt")
        || !dir.join("model.hlo.txt").exists();
    let factory = if use_echo {
        eprintln!("serve: using echo engine (build artifacts for the real model)");
        echo_factory()
    } else {
        eprintln!("serve: loading AOT model from {}", dir.display());
        model_factory(&dir)
    };
    let mut cfg = ServerConfig {
        shards: args.get_parse("shards", 2usize),
        workers: args.get_parse("workers", 2usize),
        // Async worker mode (DESIGN.md §10): the workers become
        // executor tasks multiplexed over one host thread.
        async_workers: args.flag("async-workers"),
        ..ServerConfig::default()
    };
    if args.flag("adaptive") {
        // Arm the adaptive control plane (DESIGN.md §15) on every queue
        // in the pipeline; the Bernoulli trigger is what the live
        // reclamation probability feeds.
        cfg.queue_config = cfg
            .queue_config
            .with_trigger(cmpq::queue::cmp::ReclaimTrigger::Bernoulli)
            .with_adaptive();
        eprintln!("serve: adaptive control plane enabled");
    }
    if cfg.async_workers {
        eprintln!(
            "serve: async worker mode ({} tasks, 1 host thread)",
            cfg.workers
        );
    }
    if args.flag("tcp") {
        return cmd_serve_tcp(args, cfg, factory);
    }
    let server = Arc::new(Server::start(cfg, factory));

    // Optional live-metrics sidecar: `--metrics-port P` serves the
    // Prometheus text exposition at GET /metrics (port 0 = ephemeral,
    // printed below). Shut down before the server Arc is unwrapped.
    let metrics_http = args.get("metrics-port").map(|port| {
        use cmpq::net::metrics_http::{render_prometheus, MetricsServer, RenderFn};
        let render: RenderFn = {
            let server = server.clone();
            Arc::new(move || render_prometheus(&server, None))
        };
        let ms = MetricsServer::start(&format!("127.0.0.1:{port}"), render)
            .expect("bind metrics endpoint");
        eprintln!("serve: metrics on http://{}/metrics", ms.addr());
        ms
    });

    let n_requests: u64 = args.get_parse("requests", 512u64);
    let n_clients: usize = args.get_parse("clients", 8usize);
    let per_client = (n_requests / n_clients as u64).max(1);
    eprintln!("serve: {n_clients} clients × {per_client} requests");

    let t0 = Instant::now();
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                let mut rng = cmpq::util::XorShift64::new(c as u64 + 1);
                for _ in 0..per_client {
                    let features: Vec<f32> =
                        (0..128).map(|_| (rng.next_f64() as f32) - 0.5).collect();
                    let out = server
                        .submit(features)
                        .expect("admitted (no admission limit configured)")
                        .wait_timeout(Duration::from_secs(120))
                        .expect("request timed out");
                    assert!(!out.output.is_empty(), "inference failed");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client panicked");
    }
    let elapsed = t0.elapsed();
    let total = per_client * n_clients as u64;
    println!(
        "served {total} requests in {elapsed:.2?} -> {:.1} req/s",
        total as f64 / elapsed.as_secs_f64()
    );

    // Optional idle window: demonstrates the spin-to-sleep layer
    // (DESIGN.md §8) — with zero offered load every batcher and worker
    // parks, so the whole pipeline should sit near 0% CPU.
    let idle_ms: u64 = args.get_parse("idle-ms", 0u64);
    if idle_ms > 0 {
        eprintln!("serve: idling the pipeline for {idle_ms}ms (threads park)");
        let cpu0 = cmpq::util::cpu::process_cpu_seconds();
        std::thread::sleep(Duration::from_millis(idle_ms));
        if let (Some(a), Some(b)) = (cpu0, cmpq::util::cpu::process_cpu_seconds()) {
            let wall = idle_ms as f64 / 1000.0;
            println!(
                "idle window: {:.3} cpu-s over {wall:.3} wall-s ({:.1}% of one core)",
                b - a,
                100.0 * (b - a) / wall
            );
        } else {
            println!("idle window: CPU accounting unavailable on this platform");
        }
    }

    // The metrics thread holds a Server clone via its render closure;
    // join it before reclaiming unique ownership.
    if let Some(ms) = metrics_http {
        ms.shutdown();
    }
    let server = Arc::try_unwrap(server).ok().expect("all clients joined");
    let report = server.shutdown();
    println!("{}", report.metrics.report());
    0
}

/// `repro serve --tcp`: the same pipeline behind the TCP front end
/// (DESIGN.md §12), exercised by a fleet of blocking loopback clients
/// speaking the length-prefixed wire format.
fn cmd_serve_tcp(args: &Args, cfg: ServerConfig, factory: EngineFactory) -> i32 {
    use std::io::Write;
    use std::net::TcpStream;

    use cmpq::net::codec::{self, Status};
    use cmpq::net::listener::NetServer;
    use cmpq::net::NetConfig;

    let net_cfg = NetConfig {
        addr: args.get_or("addr", "127.0.0.1:0").to_string(),
        io_threads: args.get_parse("io-threads", 2usize),
        tenant_max_inflight: args.get_parse("tenant-max-inflight", 0usize),
        ..NetConfig::default()
    };
    let server = Server::start(cfg, factory);
    let net = match NetServer::start(net_cfg, server) {
        Ok(net) => net,
        Err(e) => {
            eprintln!("serve: cannot bind TCP front end: {e}");
            return 1;
        }
    };
    let addr = net.addr();
    eprintln!("serve: TCP front end on {addr}");

    // Live-metrics sidecar (also exports the socket-side counters).
    // Must shut down before `net.shutdown()`, which reclaims unique
    // ownership of the Server the render closure holds.
    let metrics_http = args.get("metrics-port").map(|port| {
        use cmpq::net::metrics_http::{render_prometheus, MetricsServer, RenderFn};
        let render: RenderFn = {
            let server = net.server_handle();
            let shared = net.shared_handle();
            Arc::new(move || render_prometheus(&server, Some(&shared)))
        };
        let ms = MetricsServer::start(&format!("127.0.0.1:{port}"), render)
            .expect("bind metrics endpoint");
        eprintln!("serve: metrics on http://{}/metrics", ms.addr());
        ms
    });

    let n_requests: u64 = args.get_parse("requests", 512u64);
    let n_clients: usize = args.get_parse("clients", 8usize);
    let per_client = (n_requests / n_clients as u64).max(1);
    eprintln!("serve: {n_clients} TCP clients × {per_client} requests");

    let t0 = Instant::now();
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                let mut rng = cmpq::util::XorShift64::new(c as u64 + 1);
                let mut buf = Vec::new();
                let (mut ok, mut busy) = (0u64, 0u64);
                for i in 0..per_client {
                    let req = codec::Request {
                        id: i + 1,
                        tenant: c as u32,
                        features: (0..128).map(|_| (rng.next_f64() as f32) - 0.5).collect(),
                    };
                    let mut wire = Vec::new();
                    codec::encode_request(&req, &mut wire);
                    stream.write_all(&wire).expect("write request");
                    let resp = codec::read_response_blocking(&mut stream, &mut buf)
                        .expect("server closed mid-request");
                    assert_eq!(resp.id, req.id, "replies are pipelined one at a time");
                    match resp.status {
                        Status::Ok => ok += 1,
                        Status::Busy => busy += 1,
                        other => panic!("unexpected reply status {other:?}"),
                    }
                }
                (ok, busy)
            })
        })
        .collect();
    let (mut ok, mut busy) = (0u64, 0u64);
    for c in clients {
        let (o, b) = c.join().expect("client panicked");
        ok += o;
        busy += b;
    }
    let elapsed = t0.elapsed();
    println!(
        "served {ok} requests over TCP in {elapsed:.2?} -> {:.1} req/s (busy={busy})",
        ok as f64 / elapsed.as_secs_f64()
    );
    println!("{}", net.metrics().report());
    if let Some(ms) = metrics_http {
        ms.shutdown();
    }
    let report = net.shutdown();
    println!("{}", report.metrics.report());
    println!(
        "net shutdown: conns_closed={} drained_replies={}",
        report.net_conns_closed, report.net_drained_replies
    );
    0
}

/// `repro chaos`: hammer the serving pipeline while fail points inject
/// worker panics and batcher delays, then check the conservation
/// invariant — every admitted request resolves (served, engine-failed,
/// or NACKed), zero strand. Exits nonzero on any stranded slot or a
/// `submitted != completed` mismatch.
fn cmd_chaos(args: &Args) -> i32 {
    use std::sync::atomic::Ordering;

    use cmpq::coordinator::request::InferError;
    use cmpq::coordinator::supervisor::SupervisorPolicy;
    use cmpq::util::failpoint as fp;

    if !fp::compiled_in() {
        eprintln!(
            "chaos: built without the `failpoints` feature — faults will not fire.\n\
             rebuild with `cargo run --features failpoints -- chaos` for a real run"
        );
    }
    if args.flag("tcp") {
        return tcp_chaos::run(args);
    }
    let n_requests: u64 = args.get_parse("requests", 10_000u64);
    let n_clients: usize = args.get_parse("clients", 4usize);
    let seed: u64 = args.get_parse("seed", 42u64);
    let p_panic: f64 = args.get_parse("p-panic", 0.01f64);
    let p_delay: f64 = args.get_parse("p-delay", 0.05f64);
    let delay_us: u64 = args.get_parse("delay-us", 200u64);

    fp::set_seed(seed);
    fp::arm("worker/pre-infer", fp::FailAction::Panic, p_panic);
    fp::arm("batcher/flush", fp::FailAction::Delay(delay_us), p_delay);

    // Injected panics are the point of the exercise; keep the default
    // hook's backtrace spew out of the report. Real (uninjected) panics
    // still print.
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<String>()
            .is_some_and(|s| s.contains("fail point"));
        if !injected {
            default_hook(info);
        }
    }));

    let cfg = ServerConfig {
        shards: args.get_parse("shards", 2usize),
        workers: args.get_parse("workers", 2usize),
        max_inflight: Some(args.get_parse("max-inflight", 4096usize)),
        // A chaos run injects panics on purpose — give the supervisor
        // an effectively unlimited restart budget so the run measures
        // conservation, not the (separately tested) degradation cap.
        supervisor: SupervisorPolicy {
            max_restarts: args.get_parse("max-restarts", 1_000_000u32),
            ..SupervisorPolicy::default()
        },
        ..ServerConfig::default()
    };
    eprintln!(
        "chaos: {n_requests} requests, {n_clients} clients, seed={seed}, \
         worker/pre-infer=panic:{p_panic}, batcher/flush=delay:{p_delay}:{delay_us}us"
    );
    let server = Arc::new(Server::start(cfg, echo_factory()));

    #[derive(Default)]
    struct Tally {
        ok: u64,
        engine_failed: u64,
        nacked: u64,
        deadline: u64,
        shed: u64,
        stranded: u64,
    }

    let per_client = (n_requests / n_clients as u64).max(1);
    let t0 = Instant::now();
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                let mut rng = cmpq::util::XorShift64::new(c as u64 + 1);
                let mut t = Tally::default();
                for _ in 0..per_client {
                    let features: Vec<f32> =
                        (0..128).map(|_| (rng.next_f64() as f32) - 0.5).collect();
                    let slot = match server.submit(features) {
                        Ok(slot) => slot,
                        Err(_) => {
                            t.shed += 1;
                            continue;
                        }
                    };
                    match slot.wait_timeout(Duration::from_secs(60)) {
                        None => t.stranded += 1,
                        Some(resp) => match resp.error {
                            None => t.ok += 1,
                            Some(InferError::Engine(_)) => t.engine_failed += 1,
                            Some(InferError::DeadlineExceeded) => t.deadline += 1,
                            Some(_) => t.nacked += 1,
                        },
                    }
                }
                t
            })
        })
        .collect();
    let mut tally = Tally::default();
    for c in clients {
        let t = c.join().expect("client panicked");
        tally.ok += t.ok;
        tally.engine_failed += t.engine_failed;
        tally.nacked += t.nacked;
        tally.deadline += t.deadline;
        tally.shed += t.shed;
        tally.stranded += t.stranded;
    }
    let elapsed = t0.elapsed();
    let report = server_shutdown(server);
    fp::disarm_all();

    println!(
        "chaos: {} requests in {elapsed:.2?}",
        per_client * n_clients as u64
    );
    println!(
        "  resolved ok={} engine_failed={} nacked={} deadline={} shed={} stranded={}",
        tally.ok, tally.engine_failed, tally.nacked, tally.deadline, tally.shed, tally.stranded
    );
    for (site, armed, hits, trips) in fp::snapshot() {
        println!("  fail point {site}: armed={armed} hits={hits} trips={trips}");
    }
    println!("  {}", report.metrics.report());
    println!(
        "  shutdown: worker_panics={} batcher_panics={} dead={}/{} drained_nacks={} degraded={}",
        report.worker_panics,
        report.batcher_panics,
        report.workers_dead,
        report.batchers_dead,
        report.drained_nacks,
        report.degraded
    );

    let submitted = report.metrics.submitted.load(Ordering::Relaxed);
    let completed = report.metrics.completed.load(Ordering::Relaxed);
    let mut code = 0;
    if tally.stranded > 0 {
        eprintln!("chaos FAILED: {} stranded slot(s)", tally.stranded);
        code = 1;
    }
    if submitted != completed {
        eprintln!(
            "chaos FAILED: conservation broken (submitted={submitted} completed={completed})"
        );
        code = 1;
    }
    if code == 0 {
        println!("chaos OK: conservation holds (submitted={submitted} == completed={completed})");
    }
    code
}

/// Unwrap the last `Arc` handle and shut the server down.
fn server_shutdown(server: Arc<Server>) -> cmpq::coordinator::server::ShutdownReport {
    Arc::try_unwrap(server).ok().expect("all clients joined").shutdown()
}

/// `repro chaos --tcp`: the network-resilience counterpart of `chaos`.
/// A seeded async client fleet (a couple of host threads, each
/// multiplexing hundreds of connections on the crate's executor) runs
/// thousands of short sessions against the TCP front end while fail
/// points inject read/write/accept faults server-side and the fleet
/// itself misbehaves on purpose: abrupt disconnects (half of them
/// mid-frame) and slow-loris stalls. A session is *stranded* if a
/// request got neither a reply nor an EOF before its deadline. Exits
/// nonzero on any stranded session, a `submitted != completed`
/// mismatch, or connections the fleet could not place.
mod tcp_chaos {
    use std::future::Future;
    use std::io::{self, Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::pin::Pin;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::task::{Context, Poll};
    use std::time::{Duration, Instant};

    use cmpq::coordinator::server::{Server, ServerConfig};
    use cmpq::coordinator::supervisor::SupervisorPolicy;
    use cmpq::net::codec::{self, Status};
    use cmpq::net::listener::NetServer;
    use cmpq::net::NetConfig;
    use cmpq::util::cli::Args;
    use cmpq::util::executor::{sleep_until, Executor, Reactor};
    use cmpq::util::failpoint as fp;
    use cmpq::util::XorShift64;

    #[derive(Default)]
    struct Tally {
        sessions: AtomicU64,
        ok: AtomicU64,
        busy: AtomicU64,
        error_replies: AtomicU64,
        timeout_notices: AtomicU64,
        eof_early: AtomicU64,
        disconnects_injected: AtomicU64,
        stalls: AtomicU64,
        connect_failures: AtomicU64,
        stranded: AtomicU64,
    }

    #[derive(Clone, Copy, PartialEq)]
    enum Behavior {
        /// Send k requests, wait for k replies (or EOF).
        Normal,
        /// Send requests (half the time cut mid-frame), close without
        /// reading — the abandon-in-flight path.
        Disconnect,
        /// Send a partial frame and hold — the slow-loris path; the
        /// session ends when the server's read deadline drains us.
        Stall,
    }

    /// One client connection, polled on the fleet's executor.
    struct Session {
        stream: TcpStream,
        reactor: Reactor,
        tally: Arc<Tally>,
        behavior: Behavior,
        out: Vec<u8>,
        out_pos: usize,
        expected: u64,
        received: u64,
        read_buf: Vec<u8>,
        deadline: Instant,
    }

    impl Session {
        fn bump(&self, c: &AtomicU64) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    impl Future for Session {
        type Output = ();

        fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
            let this = &mut *self;
            let now = Instant::now();
            // Send phase. A failed write means the server killed the
            // connection (injected fault or drain) — reply-or-EOF
            // holds, so the session is over, not stranded.
            while this.out_pos < this.out.len() {
                match this.stream.write(&this.out[this.out_pos..]) {
                    Ok(0) => {
                        this.tally.eof_early.fetch_add(1, Ordering::Relaxed);
                        return Poll::Ready(());
                    }
                    Ok(n) => this.out_pos += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        this.tally.eof_early.fetch_add(1, Ordering::Relaxed);
                        return Poll::Ready(());
                    }
                }
            }
            if this.behavior == Behavior::Disconnect && this.out_pos == this.out.len() {
                this.bump(&this.tally.disconnects_injected);
                return Poll::Ready(()); // drop closes without reading
            }
            // Read phase.
            let mut chunk = [0u8; 4096];
            loop {
                match this.stream.read(&mut chunk) {
                    Ok(0) => {
                        if this.behavior == Behavior::Stall {
                            this.bump(&this.tally.stalls);
                        } else if this.received < this.expected {
                            this.bump(&this.tally.eof_early);
                        }
                        return Poll::Ready(());
                    }
                    Ok(n) => {
                        this.read_buf.extend_from_slice(&chunk[..n]);
                        loop {
                            match codec::decode_response(&this.read_buf) {
                                Ok(Some((resp, used))) => {
                                    this.read_buf.drain(..used);
                                    match resp.status {
                                        Status::Ok => {
                                            this.bump(&this.tally.ok);
                                            this.received += 1;
                                        }
                                        Status::Busy => {
                                            this.bump(&this.tally.busy);
                                            this.received += 1;
                                        }
                                        Status::Error => {
                                            this.bump(&this.tally.error_replies);
                                            this.received += 1;
                                        }
                                        // Connection-level notice, not
                                        // a per-request reply.
                                        Status::Timeout => {
                                            this.bump(&this.tally.timeout_notices)
                                        }
                                    }
                                }
                                Ok(None) => break,
                                Err(_) => {
                                    this.bump(&this.tally.eof_early);
                                    return Poll::Ready(());
                                }
                            }
                        }
                        if this.behavior == Behavior::Normal && this.received >= this.expected {
                            return Poll::Ready(());
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        this.bump(&this.tally.eof_early);
                        return Poll::Ready(());
                    }
                }
            }
            if now >= this.deadline {
                // Neither replies nor EOF in time: the front end
                // wedged or lost us. This is the failure the run
                // exists to catch.
                this.bump(&this.tally.stranded);
                return Poll::Ready(());
            }
            this.reactor.register(cx);
            Poll::Pending
        }
    }

    /// Everything a session-runner task needs; one per client thread.
    struct Fleet {
        addr: SocketAddr,
        reactor: Reactor,
        tally: Arc<Tally>,
        remaining: Arc<AtomicU64>,
        seed: u64,
        p_disconnect: f64,
        p_stall: f64,
        session_deadline: Duration,
    }

    /// Claim one connection slot, or `false` when the target is met.
    fn claim(remaining: &AtomicU64) -> bool {
        loop {
            let cur = remaining.load(Ordering::Acquire);
            if cur == 0 {
                return false;
            }
            if remaining
                .compare_exchange(cur, cur - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    /// Build one session's outgoing bytes and expected-reply count.
    fn build_session(rng: &mut XorShift64, behavior: Behavior) -> (Vec<u8>, u64) {
        let mut out = Vec::new();
        if behavior == Behavior::Stall {
            // Five bytes of a frame that claims 24 more: a textbook
            // slow loris.
            out.extend_from_slice(&24u32.to_le_bytes());
            out.push(0);
            return (out, 0);
        }
        let k = 1 + (rng.next_u64() % 4);
        for i in 0..k {
            let req = codec::Request {
                id: i + 1,
                tenant: (rng.next_u64() % 16) as u32,
                features: (0..16).map(|_| (rng.next_f64() as f32) - 0.5).collect(),
            };
            codec::encode_request(&req, &mut out);
        }
        if behavior == Behavior::Disconnect {
            if rng.next_f64() < 0.5 {
                // Cut the last frame in half: the server is left
                // holding a partial frame when we vanish.
                let cut = out.len() - 10;
                out.truncate(cut);
            }
            return (out, 0);
        }
        (out, k)
    }

    /// One task: run sessions until the global connection target is
    /// met (or the server becomes unreachable).
    async fn session_runner(fleet: Arc<Fleet>, task_id: u64) {
        let mut rng = XorShift64::new(
            fleet
                .seed
                .wrapping_mul(0x9e3779b97f4a7c15)
                .wrapping_add(task_id)
                | 1,
        );
        // Stagger starts so thousands of connects don't hit the
        // listener backlog in one instant.
        let jitter = Duration::from_micros((task_id % 512) * 1500);
        sleep_until(Instant::now() + jitter).await;
        let mut consecutive_failures = 0u32;
        while claim(&fleet.remaining) {
            let stream = match TcpStream::connect_timeout(&fleet.addr, Duration::from_secs(5)) {
                Ok(s) => s,
                Err(_) => {
                    fleet.tally.connect_failures.fetch_add(1, Ordering::Relaxed);
                    fleet.remaining.fetch_add(1, Ordering::Release);
                    consecutive_failures += 1;
                    if consecutive_failures > 50 {
                        return; // server unreachable; leave slots unclaimed
                    }
                    sleep_until(Instant::now() + Duration::from_millis(50)).await;
                    continue;
                }
            };
            consecutive_failures = 0;
            if stream.set_nonblocking(true).is_err() {
                fleet.tally.connect_failures.fetch_add(1, Ordering::Relaxed);
                fleet.remaining.fetch_add(1, Ordering::Release);
                continue;
            }
            let _ = stream.set_nodelay(true);
            let r = rng.next_f64();
            let behavior = if r < fleet.p_disconnect {
                Behavior::Disconnect
            } else if r < fleet.p_disconnect + fleet.p_stall {
                Behavior::Stall
            } else {
                Behavior::Normal
            };
            let (out, expected) = build_session(&mut rng, behavior);
            fleet.tally.sessions.fetch_add(1, Ordering::Relaxed);
            Session {
                stream,
                reactor: fleet.reactor.clone(),
                tally: fleet.tally.clone(),
                behavior,
                out,
                out_pos: 0,
                expected,
                received: 0,
                read_buf: Vec::new(),
                deadline: Instant::now() + fleet.session_deadline,
            }
            .await;
        }
    }

    /// Small/fast echo engine for network chaos: the load is
    /// connection churn, not matmuls.
    fn chaos_echo() -> cmpq::coordinator::worker::EngineFactory {
        use cmpq::coordinator::worker::{EchoEngine, InferenceEngine};
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 8,
                features: 16,
                outputs: 4,
                scale: 1.0,
            }) as Box<dyn InferenceEngine>)
        })
    }

    pub fn run(args: &Args) -> i32 {
        let connections: u64 = args.get_parse("connections", 10_000u64);
        let concurrency: usize = args.get_parse("concurrency", 256usize);
        let client_threads: usize = args.get_parse("client-threads", 2usize).max(1);
        let io_threads: usize = args.get_parse("io-threads", 4usize);
        let seed: u64 = args.get_parse("seed", 42u64);
        let p_net: f64 = args.get_parse("p-net", 0.002f64);
        let p_accept: f64 = args.get_parse("p-accept", 0.01f64);
        let p_panic: f64 = args.get_parse("p-panic", 0.005f64);
        let p_disconnect: f64 = args.get_parse("p-disconnect", 0.08f64);
        let p_stall: f64 = args.get_parse("p-stall", 0.02f64);
        let read_timeout_ms: u64 = args.get_parse("read-timeout-ms", 300u64);

        fp::set_seed(seed);
        fp::arm("net/read", fp::FailAction::Error, p_net);
        fp::arm("net/write", fp::FailAction::Error, p_net);
        fp::arm("net/accept", fp::FailAction::Error, p_accept);
        fp::arm("worker/pre-infer", fp::FailAction::Panic, p_panic);

        // Same suppression as plain `chaos`: injected panics are the
        // point; keep their backtraces out of the report.
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .is_some_and(|s| s.contains("fail point"));
            if !injected {
                default_hook(info);
            }
        }));

        let cfg = ServerConfig {
            shards: args.get_parse("shards", 2usize),
            workers: args.get_parse("workers", 2usize),
            max_inflight: Some(args.get_parse("max-inflight", 4096usize)),
            supervisor: SupervisorPolicy {
                max_restarts: 1_000_000,
                ..SupervisorPolicy::default()
            },
            ..ServerConfig::default()
        };
        let net_cfg = NetConfig {
            io_threads,
            read_timeout: Duration::from_millis(read_timeout_ms),
            tenant_max_inflight: args.get_parse("tenant-max-inflight", 0usize),
            ..NetConfig::default()
        };
        eprintln!(
            "chaos --tcp: {connections} connections (≤{concurrency} concurrent) on \
             {io_threads} io threads, seed={seed}, net faults p={p_net}, accept p={p_accept}, \
             disconnect p={p_disconnect}, stall p={p_stall}"
        );
        let server = Server::start(cfg, chaos_echo());
        let net = match NetServer::start(net_cfg, server) {
            Ok(net) => net,
            Err(e) => {
                eprintln!("chaos --tcp: cannot bind: {e}");
                return 1;
            }
        };
        let addr = net.addr();

        let tally = Arc::new(Tally::default());
        let remaining = Arc::new(AtomicU64::new(connections));
        let per_thread_tasks = (concurrency / client_threads).max(1);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..client_threads)
            .map(|t| {
                let tally = tally.clone();
                let remaining = remaining.clone();
                std::thread::Builder::new()
                    .name(format!("chaos-client-{t}"))
                    .spawn(move || {
                        let fleet = Arc::new(Fleet {
                            addr,
                            reactor: Reactor::new(
                                Duration::from_micros(200),
                                Duration::from_millis(5),
                            ),
                            tally,
                            remaining,
                            seed,
                            p_disconnect,
                            p_stall,
                            session_deadline: Duration::from_secs(30),
                        });
                        let mut ex = Executor::new();
                        for i in 0..per_thread_tasks {
                            ex.spawn(session_runner(
                                fleet.clone(),
                                (t * per_thread_tasks + i) as u64,
                            ));
                        }
                        ex.run();
                    })
                    .expect("spawn chaos client thread")
            })
            .collect();
        for h in handles {
            h.join().expect("client thread panicked");
        }
        let elapsed = t0.elapsed();
        let unplaced = remaining.load(Ordering::Acquire);

        println!("{}", net.metrics().report());
        let report = net.shutdown();
        fp::disarm_all();

        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        println!(
            "chaos --tcp: {} sessions in {elapsed:.2?}",
            ld(&tally.sessions)
        );
        println!(
            "  client: ok={} busy={} error={} timeout_notices={} eof_early={} \
             disconnects={} stalls={} connect_failures={} stranded={}",
            ld(&tally.ok),
            ld(&tally.busy),
            ld(&tally.error_replies),
            ld(&tally.timeout_notices),
            ld(&tally.eof_early),
            ld(&tally.disconnects_injected),
            ld(&tally.stalls),
            ld(&tally.connect_failures),
            ld(&tally.stranded),
        );
        for (site, armed, hits, trips) in fp::snapshot() {
            println!("  fail point {site}: armed={armed} hits={hits} trips={trips}");
        }
        println!("  {}", report.metrics.report());
        println!(
            "  shutdown: conns_closed={} drained_replies={} worker_panics={} degraded={}",
            report.net_conns_closed,
            report.net_drained_replies,
            report.worker_panics,
            report.degraded
        );

        let submitted = report.metrics.submitted.load(Ordering::Relaxed);
        let completed = report.metrics.completed.load(Ordering::Relaxed);
        let stranded = ld(&tally.stranded);
        let mut code = 0;
        if stranded > 0 {
            eprintln!("chaos --tcp FAILED: {stranded} stranded session(s)");
            code = 1;
        }
        if submitted != completed {
            eprintln!(
                "chaos --tcp FAILED: conservation broken \
                 (submitted={submitted} completed={completed})"
            );
            code = 1;
        }
        if unplaced > 0 {
            eprintln!("chaos --tcp FAILED: {unplaced} connection(s) never placed");
            code = 1;
        }
        if code == 0 {
            println!(
                "chaos --tcp OK: {connections} connections, conservation holds \
                 (submitted={submitted} == completed={completed})"
            );
        }
        code
    }
}

fn cmd_selftest(args: &Args) -> i32 {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    eprintln!("selftest: artifacts at {}", dir.display());
    let rt = match ModelRuntime::load_from_artifacts(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("selftest: cannot load model: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    let tv = match TestVectors::load(&dir) {
        Ok(tv) => tv,
        Err(e) => {
            eprintln!("selftest: cannot load test vectors: {e:#}");
            return 1;
        }
    };
    let t0 = Instant::now();
    let out = rt.infer(&tv.input).expect("inference failed");
    let dt = t0.elapsed();
    match tv.check(&out) {
        Ok(()) => {
            println!(
                "selftest OK: output matches JAX within rtol={} ({} values, {dt:.2?}/batch)",
                tv.rtol,
                out.len()
            );
            0
        }
        Err(e) => {
            eprintln!("selftest FAILED: {e:#}");
            1
        }
    }
}

fn cmd_demo() -> i32 {
    use cmpq::{CmpQueue, ConcurrentQueue};
    println!("CMP queue demo — see examples/quickstart.rs for the full tour");
    let q: CmpQueue<String> = CmpQueue::new();
    for i in 0..5 {
        q.push(format!("msg-{i}")).unwrap();
    }
    while let Some(m) = q.pop() {
        println!("dequeued {m}");
    }
    println!("stats: {}", q.stats().summary());
    println!(
        "strict_fifo={} lock_free={}",
        q.is_strict_fifo(),
        q.is_lock_free()
    );
    0
}
