//! Request router: spreads incoming requests across per-shard CMP
//! queues (the fabric the paper motivates for many-thread inference
//! pipelines). Sharding bounds contention per queue instance while the
//! queues themselves stay coordination-free.
//!
//! The router can own its shards ([`Router::new`]) or ride a
//! [`ShardedCmp`] fabric ([`Router::over_fabric`], DESIGN.md §13):
//! both sides then share the same per-shard `CmpQueue` handles, so
//! batcher drains keep using the router's gauge-tracked paths while
//! affinity/steal consumers can block on the fabric facade. Routing
//! into a shared shard finishes with [`ShardedCmp::notify_stealers`]
//! so a fabric consumer parked on a different home shard still wakes.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::queue::cmp::{CmpConfig, CmpQueue};
use crate::queue::sharded::ShardedCmp;

use super::request::InferRequest;

/// Routing policy across shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Strict rotation — even spread, the default.
    RoundRobin,
    /// Pick the shard with the fewest in-flight requests (tracked with
    /// relaxed counters; approximate by design).
    LeastLoaded,
    /// `id % shards` — sticky per request id.
    HashId,
}

/// Sharded router over CMP queues.
pub struct Router {
    shards: Vec<Arc<CmpQueue<InferRequest>>>,
    policy: RoutePolicy,
    rr: AtomicU64,
    /// In-flight (routed − drained) per shard, for LeastLoaded.
    inflight: Vec<AtomicU64>,
    /// Shards taken out of rotation ([`Router::mark_dead`]) because
    /// their batcher was abandoned past the restart cap.
    dead: Vec<AtomicBool>,
    routed: AtomicU64,
    /// When routing over a [`ShardedCmp`] fabric, the facade handle —
    /// routed pushes must run its cross-shard notify.
    fabric: Option<Arc<ShardedCmp<InferRequest>>>,
}

impl Router {
    /// A router over `shards` fresh CMP queues (panics on `shards == 0`).
    pub fn new(shards: usize, policy: RoutePolicy, cfg: CmpConfig) -> Self {
        assert!(shards > 0);
        Router {
            shards: (0..shards)
                .map(|_| Arc::new(CmpQueue::with_config(cfg.clone())))
                .collect(),
            policy,
            rr: AtomicU64::new(0),
            inflight: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            dead: (0..shards).map(|_| AtomicBool::new(false)).collect(),
            routed: AtomicU64::new(0),
            fabric: None,
        }
    }

    /// A router that delegates to an existing [`ShardedCmp`] fabric:
    /// its shard queues *are* the fabric's shards (shared `Arc`s, no
    /// copy), so requests routed here are visible to fabric consumers
    /// (`pop_blocking` with affinity + steal) and vice versa. The
    /// router applies its own [`RoutePolicy`] — per-shard FIFO holds
    /// regardless of the fabric's [`crate::queue::sharded::ShardMode`],
    /// which is the contract the batcher drains rely on.
    pub fn over_fabric(fabric: Arc<ShardedCmp<InferRequest>>, policy: RoutePolicy) -> Self {
        let n = fabric.shard_count();
        Router {
            shards: (0..n).map(|i| fabric.shard_arc(i)).collect(),
            policy,
            rr: AtomicU64::new(0),
            inflight: (0..n).map(|_| AtomicU64::new(0)).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            routed: AtomicU64::new(0),
            fabric: Some(fabric),
        }
    }

    /// The fabric this router delegates to, if built with
    /// [`Router::over_fabric`].
    pub fn fabric(&self) -> Option<&Arc<ShardedCmp<InferRequest>>> {
        self.fabric.as_ref()
    }

    /// Number of shard queues.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Direct access to shard `i`'s queue (telemetry/tests).
    pub fn shard(&self, i: usize) -> &Arc<CmpQueue<InferRequest>> {
        &self.shards[i]
    }

    /// Total requests routed so far.
    pub fn routed(&self) -> u64 {
        self.routed.load(Ordering::Relaxed)
    }

    /// Approximate in-flight depth of shard `i`.
    pub fn inflight(&self, i: usize) -> u64 {
        self.inflight[i].load(Ordering::Relaxed)
    }

    /// Take shard `i` out of routing rotation — its batcher was
    /// abandoned past the restart cap, so anything routed there will
    /// only ever be NACKed by the dead-shard drain. Routing stops
    /// selecting the shard as long as any live shard remains;
    /// requests already queued (or raced in) are the drain's to
    /// resolve.
    pub fn mark_dead(&self, i: usize) {
        self.dead[i].store(true, Ordering::Release);
    }

    /// Whether shard `i` has been taken out of rotation.
    pub fn is_dead(&self, i: usize) -> bool {
        self.dead[i].load(Ordering::Acquire)
    }

    fn pick(&self, req: &InferRequest) -> usize {
        let n = self.shards.len();
        let first = match self.policy {
            RoutePolicy::RoundRobin => {
                (self.rr.fetch_add(1, Ordering::Relaxed) % n as u64) as usize
            }
            RoutePolicy::HashId => (req.id % n as u64) as usize,
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = u64::MAX;
                for (i, c) in self.inflight.iter().enumerate() {
                    if self.dead[i].load(Ordering::Relaxed) {
                        continue;
                    }
                    let l = c.load(Ordering::Relaxed);
                    if l < best_load {
                        best_load = l;
                        best = i;
                    }
                }
                best
            }
        };
        if !self.dead[first].load(Ordering::Relaxed) {
            return first;
        }
        // Dead shard: remap deterministically to the next live one
        // (keeps HashId sticky on its fallback too). With *every*
        // shard dead there is nothing better than `first` — the dead
        // shard's drain loop NACKs, so clients still get an explicit
        // error instead of a hung wait.
        for k in 1..n {
            let s = (first + k) % n;
            if !self.dead[s].load(Ordering::Relaxed) {
                return s;
            }
        }
        first
    }

    /// Route a request onto its shard queue. Returns the shard index,
    /// or the request back on rejection (bounded shard at capacity, or
    /// an injected `router/route` fault) so the caller can shed or NACK
    /// it — the pre-robustness version panicked here.
    ///
    /// The in-flight gauge and routed counter are incremented *before*
    /// the push (a concurrent drain of the just-pushed request must
    /// never observe a gauge it would wrap below zero) and rolled back
    /// on the rejection path, where no drain can have seen the request.
    pub fn route(&self, req: InferRequest) -> Result<usize, InferRequest> {
        crate::fail_point!("router/route", Err(req));
        let shard = self.pick(&req);
        self.inflight[shard].fetch_add(1, Ordering::Relaxed);
        self.routed.fetch_add(1, Ordering::Relaxed);
        match self.shards[shard].push(req) {
            Ok(()) => {
                if let Some(f) = &self.fabric {
                    f.notify_stealers();
                }
                Ok(shard)
            }
            Err(req) => {
                self.inflight[shard].fetch_sub(1, Ordering::Relaxed);
                self.routed.fetch_sub(1, Ordering::Relaxed);
                Err(req)
            }
        }
    }

    /// Route a whole batch of requests: pick a shard per request, group
    /// the batch by shard, and publish each group with one
    /// [`CmpQueue::push_batch`] — one cycle RMW and one tail CAS per
    /// shard instead of per request (batch fan-in, DESIGN.md §7).
    /// Relative order of requests that land on the same shard is
    /// preserved.
    ///
    /// Returns the requests of any group whose shard rejected its push
    /// (empty = everything routed); gauges are rolled back for those,
    /// as in [`Router::route`].
    pub fn route_many(&self, reqs: Vec<InferRequest>) -> Vec<InferRequest> {
        let n = reqs.len() as u64;
        let mut groups: Vec<Vec<InferRequest>> = Vec::new();
        groups.resize_with(self.shards.len(), Vec::new);
        for req in reqs {
            let shard = self.pick(&req);
            self.inflight[shard].fetch_add(1, Ordering::Relaxed);
            groups[shard].push(req);
        }
        self.routed.fetch_add(n, Ordering::Relaxed);
        let mut rejected = Vec::new();
        let mut published = false;
        for (shard, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let len = group.len() as u64;
            if let Err(group) = self.shards[shard].push_batch(group) {
                self.inflight[shard].fetch_sub(len, Ordering::Relaxed);
                self.routed.fetch_sub(len, Ordering::Relaxed);
                rejected.extend(group);
            } else {
                published = true;
            }
        }
        if published {
            if let Some(f) = &self.fabric {
                f.notify_stealers();
            }
        }
        rejected
    }

    /// Dequeue from shard `i` (batcher side). Decrements the in-flight
    /// gauge on success.
    pub fn drain_one(&self, i: usize) -> Option<InferRequest> {
        let r = self.shards[i].pop();
        if r.is_some() {
            self.inflight[i].fetch_sub(1, Ordering::Relaxed);
        }
        r
    }

    /// Dequeue up to `max` requests from shard `i` with one amortized
    /// batch claim, appending to `out`; returns the count (batch
    /// fan-out for the dynamic batcher).
    pub fn drain_many(&self, i: usize, max: usize, out: &mut Vec<InferRequest>) -> usize {
        let n = self.shards[i].pop_batch_into(max, out);
        if n > 0 {
            self.inflight[i].fetch_sub(n as u64, Ordering::Relaxed);
        }
        n
    }

    /// Like [`Router::drain_many`], but blocks — spin → yield →
    /// epoch-guarded park on the shard queue (DESIGN.md §8) — until
    /// requests arrive or `deadline` passes. Returns the number drained
    /// (0 = deadline hit while empty).
    pub fn drain_deadline(
        &self,
        i: usize,
        max: usize,
        out: &mut Vec<InferRequest>,
        deadline: Instant,
    ) -> usize {
        let n = self.shards[i].pop_deadline_batch(max, out, deadline);
        if n > 0 {
            self.inflight[i].fetch_sub(n as u64, Ordering::Relaxed);
        }
        n
    }

    /// Async [`Router::drain_many`]: await a run of 1..=`max` requests
    /// from shard `i` through the shard queue's waker-based
    /// [`CmpQueue::pop_async_batch`] (DESIGN.md §10), appending to
    /// `out`; returns the number drained. A routed request wakes the
    /// pending task directly — no batcher thread parks. Like the
    /// blocking drains, the in-flight gauge is decremented only for
    /// requests actually claimed.
    pub async fn drain_async(&self, i: usize, max: usize, out: &mut Vec<InferRequest>) -> usize {
        let run = self.shards[i].pop_async_batch(max).await;
        let n = run.len();
        if n > 0 {
            self.inflight[i].fetch_sub(n as u64, Ordering::Relaxed);
            out.extend(run);
        }
        n
    }

    /// Wake every consumer parked on any shard queue (shutdown path) —
    /// threads and pending async drains alike.
    pub fn wake_all(&self) {
        for shard in &self.shards {
            shard.wake_consumers();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ResponseSlot;
    use std::time::Instant;

    fn req(id: u64) -> InferRequest {
        InferRequest {
            id,
            tenant: 0,
            features: vec![0.0; 4],
            submitted_at: Instant::now(),
            deadline: None,
            slot: ResponseSlot::new(),
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let r = Router::new(4, RoutePolicy::RoundRobin, CmpConfig::default());
        let mut counts = [0u32; 4];
        for i in 0..100 {
            counts[r.route(req(i)).ok().unwrap()] += 1;
        }
        assert_eq!(counts, [25, 25, 25, 25]);
        assert_eq!(r.routed(), 100);
    }

    #[test]
    fn hash_id_is_sticky() {
        let r = Router::new(3, RoutePolicy::HashId, CmpConfig::default());
        assert_eq!(r.route(req(7)).ok(), Some(1));
        assert_eq!(r.route(req(7)).ok(), Some(1));
        assert_eq!(r.route(req(9)).ok(), Some(0));
    }

    #[test]
    fn least_loaded_balances_after_drain() {
        let r = Router::new(2, RoutePolicy::LeastLoaded, CmpConfig::default());
        // Both start at 0 → shard 0 wins, then 1, then even.
        let s1 = r.route(req(1)).ok().unwrap();
        let s2 = r.route(req(2)).ok().unwrap();
        assert_ne!(s1, s2, "second request must go to the other shard");
        // Drain shard s1 → next request prefers it again.
        assert!(r.drain_one(s1).is_some());
        assert_eq!(r.route(req(3)).ok(), Some(s1));
    }

    #[test]
    fn drain_preserves_fifo_per_shard() {
        let r = Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default());
        for i in 0..10 {
            r.route(req(i)).ok().unwrap();
        }
        for i in 0..10 {
            assert_eq!(r.drain_one(0).unwrap().id, i);
        }
        assert!(r.drain_one(0).is_none());
        assert_eq!(r.inflight(0), 0);
    }

    #[test]
    fn drain_many_claims_a_fifo_run() {
        let r = Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default());
        for i in 0..10 {
            r.route(req(i)).ok().unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(r.drain_many(0, 4, &mut out), 4);
        assert_eq!(r.inflight(0), 6);
        assert_eq!(r.drain_many(0, 100, &mut out), 6);
        assert_eq!(r.inflight(0), 0);
        let ids: Vec<u64> = out.iter().map(|q| q.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(r.drain_many(0, 4, &mut out), 0);
    }

    #[test]
    fn drain_deadline_parks_until_route() {
        let r = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let r2 = r.clone();
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            let deadline = Instant::now() + std::time::Duration::from_secs(20);
            let n = r2.drain_deadline(0, 8, &mut out, deadline);
            (n, out)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.route(req(7)).ok().unwrap();
        let (n, out) = h.join().unwrap();
        assert_eq!(n, 1, "woken by the routed request");
        assert_eq!(out[0].id, 7);
        assert_eq!(r.inflight(0), 0, "gauge decremented on the parked drain");
    }

    #[test]
    fn drain_async_woken_by_route() {
        use crate::util::block_on;
        let r = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let r2 = r.clone();
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            let n = block_on(r2.drain_async(0, 8, &mut out));
            (n, out)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.route(req(9)).ok().unwrap();
        let (n, out) = h.join().unwrap();
        assert_eq!(n, 1, "woken by the routed request");
        assert_eq!(out[0].id, 9);
        assert_eq!(r.inflight(0), 0, "gauge decremented by the async drain");
    }

    #[test]
    fn wake_all_unparks_empty_shards() {
        let r = Arc::new(Router::new(2, RoutePolicy::RoundRobin, CmpConfig::default()));
        let r2 = r.clone();
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            let deadline = Instant::now() + std::time::Duration::from_millis(300);
            r2.drain_deadline(1, 8, &mut out, deadline)
        });
        // Bounded observation: the drain may time out on its own on a
        // loaded box — the join assertion holds either way.
        let until = Instant::now() + std::time::Duration::from_secs(5);
        while r.shard(1).parked_consumers() == 0 && Instant::now() < until {
            std::thread::yield_now();
        }
        r.wake_all();
        assert_eq!(h.join().unwrap(), 0, "woken onto an empty shard");
    }

    #[test]
    fn dead_shards_are_skipped_by_routing() {
        let r = Router::new(3, RoutePolicy::RoundRobin, CmpConfig::default());
        r.mark_dead(1);
        assert!(r.is_dead(1));
        let mut counts = [0u32; 3];
        for i in 0..30 {
            counts[r.route(req(i)).ok().unwrap()] += 1;
        }
        assert_eq!(counts[1], 0, "dead shard out of rotation");
        assert_eq!(counts[0] + counts[2], 30);

        // HashId remaps deterministically to the next live shard.
        let r = Router::new(3, RoutePolicy::HashId, CmpConfig::default());
        r.mark_dead(1);
        assert_eq!(r.route(req(7)).ok(), Some(2), "7 % 3 == 1 is dead → 2");
        assert_eq!(r.route(req(7)).ok(), Some(2), "remap is sticky");

        // LeastLoaded never scans a dead shard, even at zero load.
        let r = Router::new(2, RoutePolicy::LeastLoaded, CmpConfig::default());
        r.mark_dead(0);
        for i in 0..4 {
            assert_eq!(r.route(req(i)).ok(), Some(1));
        }

        // All shards dead: requests still route somewhere (the dead
        // shard's drain loop NACKs them — explicit error, no hang).
        let r = Router::new(2, RoutePolicy::RoundRobin, CmpConfig::default());
        r.mark_dead(0);
        r.mark_dead(1);
        assert!(r.route(req(1)).is_ok(), "all-dead fallback still enqueues");
    }

    #[test]
    fn over_fabric_shares_shards_both_ways() {
        use crate::queue::sharded::{ShardMode, ShardedCmp, ShardedConfig};
        use crate::queue::ConcurrentQueue;
        let fabric: Arc<ShardedCmp<InferRequest>> = Arc::new(ShardedCmp::with_config(
            ShardedConfig::default()
                .with_shards(2)
                .with_mode(ShardMode::Relaxed { max_rank_error: 64 }),
        ));
        let r = Router::over_fabric(Arc::clone(&fabric), RoutePolicy::RoundRobin);
        assert_eq!(r.shard_count(), 2);
        assert!(r.fabric().is_some());

        // Router → fabric: routed requests are visible to fabric pops.
        for i in 0..4 {
            r.route(req(i)).ok().unwrap();
        }
        let mut seen = 0;
        while fabric.try_dequeue().is_some() {
            seen += 1;
        }
        assert_eq!(seen, 4, "fabric consumers see router-published work");

        // Fabric → router: facade enqueues land in router-drainable
        // shards (gauges only track router-routed work, by design).
        assert!(fabric.try_enqueue(req(9)).is_ok());
        let drained = (0..2).filter_map(|s| r.drain_one(s)).count();
        assert_eq!(drained, 1, "router drains fabric-published work");
    }

    #[test]
    fn over_fabric_route_wakes_cross_shard_consumer() {
        use crate::queue::sharded::{ShardMode, ShardedCmp, ShardedConfig};
        use crate::queue::ConcurrentQueue;
        let fabric: Arc<ShardedCmp<InferRequest>> = Arc::new(ShardedCmp::with_config(
            ShardedConfig::default()
                .with_shards(2)
                .with_mode(ShardMode::Relaxed { max_rank_error: 64 }),
        ));
        // Claim affinity slot 0 on this thread so the spawned consumer
        // registers slot 1 → home shard 1.
        assert!(fabric.try_dequeue().is_none());
        let consumer = {
            let fabric = Arc::clone(&fabric);
            std::thread::spawn(move || fabric.pop_blocking())
        };
        let until = Instant::now() + std::time::Duration::from_secs(5);
        while fabric.parked_consumers() == 0 && Instant::now() < until {
            std::thread::yield_now();
        }
        // First round-robin pick is shard 0 — the other shard from the
        // consumer's home. Without `notify_stealers` in `route`, the
        // parked consumer could sleep through this push.
        let r = Router::over_fabric(Arc::clone(&fabric), RoutePolicy::RoundRobin);
        r.route(req(42)).ok().unwrap();
        assert_eq!(consumer.join().unwrap().id, 42);
    }

    #[test]
    fn route_many_groups_by_shard_and_preserves_order() {
        let r = Router::new(3, RoutePolicy::HashId, CmpConfig::default());
        let rejected = r.route_many((0..30).map(req).collect());
        assert!(rejected.is_empty(), "unbounded shards accept everything");
        assert_eq!(r.routed(), 30);
        for shard in 0..3u64 {
            assert_eq!(r.inflight(shard as usize), 10);
            let mut out = Vec::new();
            r.drain_many(shard as usize, 64, &mut out);
            let ids: Vec<u64> = out.iter().map(|q| q.id).collect();
            let expect: Vec<u64> = (0..30).filter(|i| i % 3 == shard).collect();
            assert_eq!(ids, expect, "per-shard FIFO through batch fan-in");
        }
    }
}
