//! The serving pipeline: router → batchers → CMP work queue → workers.
//! Every hand-off is a CMP queue; the only blocking point is the
//! client-facing completion slot (by design — clients sleep, the
//! pipeline never does).
//!
//! Robustness (DESIGN.md §11): workers and batchers are supervised —
//! panics NACK the claimed requests and the stage respawns with backoff
//! up to a cap, past which the server *degrades* instead of wedging.
//! [`Server::submit`] sheds load above a configurable in-flight depth,
//! and [`Server::shutdown`] reports stage outcomes and NACKs every
//! still-queued request instead of stranding (or `.expect`-ing on a
//! panicked stage, as the pre-robustness version did).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::queue::cmp::CmpConfig;

use super::batcher::{batcher_loop, new_work_queue, BatchPolicy, WorkQueue};
use super::metrics::Metrics;
use super::request::{InferError, InferRequest, InferResponse, ResponseFuture, ResponseSlot};
use super::router::{RoutePolicy, Router};
use super::supervisor::{monitor_loop, supervised_worker_loop, Supervision, SupervisorPolicy};
use super::worker::{async_worker_loop, nack_batch, EngineFactory};

/// Pipeline configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Router shards (one batcher thread per shard).
    pub shards: usize,
    /// Model workers: threads in the default mode, async tasks on one
    /// host thread when [`ServerConfig::async_workers`] is set.
    pub workers: usize,
    /// How the router spreads requests across shards.
    pub route_policy: RoutePolicy,
    /// Dynamic-batching knobs (size/deadline flush).
    pub batch_policy: BatchPolicy,
    /// CMP configuration for every queue in the pipeline.
    pub queue_config: CmpConfig,
    /// Async worker mode (DESIGN.md §10): run the `workers` model
    /// workers as round-robin executor tasks multiplexed over a single
    /// OS thread, pulling work through the CMP queue's async dequeues
    /// — the N-consumer idle fleet costs one parked thread instead of
    /// N. Default `false` (one thread per worker).
    pub async_workers: bool,
    /// Admission-control depth: [`Server::submit`] returns
    /// [`SubmitError::Overloaded`] while `submitted − completed` is at
    /// or above this. `None` (default) admits everything — queue depth
    /// is unbounded, as before.
    ///
    /// Admission is all-or-nothing per call: [`Server::submit_batch`]
    /// is admitted only when the *entire* batch fits in the remaining
    /// headroom, so a single batch with more than `max_inflight`
    /// requests can never be admitted, even on an idle server. Split
    /// client batches below the limit (or raise it) when batching
    /// through a depth-limited server.
    pub max_inflight: Option<usize>,
    /// Deadline attached to every request relative to its submit time;
    /// batcher and worker NACK expired requests
    /// ([`InferError::DeadlineExceeded`]) before paying engine cost.
    /// `None` (default): requests never expire.
    pub default_deadline: Option<Duration>,
    /// Restart/backoff/stall policy for supervised workers and
    /// batchers.
    pub supervisor: SupervisorPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 2,
            workers: 2,
            route_policy: RoutePolicy::RoundRobin,
            batch_policy: BatchPolicy::default(),
            queue_config: CmpConfig::default(),
            async_workers: false,
            max_inflight: None,
            default_deadline: None,
            supervisor: SupervisorPolicy::default(),
        }
    }
}

/// Why [`Server::submit`] refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control shed the request: the in-flight depth is at
    /// [`ServerConfig::max_inflight`], or the router's shard queue
    /// rejected the push (bounded capacity / injected fault). The
    /// request was *not* enqueued; retry with backoff.
    Overloaded,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "server overloaded; request shed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Outcome of [`Server::shutdown`]: the metrics handle plus a summary
/// of everything that went wrong during the server's lifetime.
#[derive(Debug)]
pub struct ShutdownReport {
    /// Pipeline metrics (counters + latency histogram).
    pub metrics: Arc<Metrics>,
    /// Worker panics caught by supervision or observed at join.
    pub worker_panics: u64,
    /// Batcher panics caught by the restart wrapper or observed at join.
    pub batcher_panics: u64,
    /// Workers abandoned past the restart cap.
    pub workers_dead: u64,
    /// Batchers abandoned past the restart cap.
    pub batchers_dead: u64,
    /// Requests NACKed by the residual drain (left queued because a
    /// stage died or shutdown raced them in).
    pub drained_nacks: u64,
    /// Whether the server ended degraded (any stage abandoned).
    pub degraded: bool,
    /// Connections closed by the TCP front end over its lifetime
    /// (0 when the server ran without one — `Server::shutdown` itself
    /// never opens sockets; `NetServer::shutdown` fills these in).
    pub net_conns_closed: u64,
    /// Responses flushed to clients during the front end's graceful
    /// drain window (stop accepting → flush in-flight → close).
    pub net_drained_replies: u64,
}

impl ShutdownReport {
    /// `true` when nothing panicked, nothing died, and nothing had to
    /// be drain-NACKed.
    pub fn clean(&self) -> bool {
        self.worker_panics == 0
            && self.batcher_panics == 0
            && self.workers_dead == 0
            && self.batchers_dead == 0
            && self.drained_nacks == 0
            && !self.degraded
    }
}

/// A running pipeline. Submit requests with [`Server::submit`]; call
/// [`Server::shutdown`] to drain and join.
pub struct Server {
    router: Arc<Router>,
    work: WorkQueue,
    metrics: Arc<Metrics>,
    supervision: Arc<Supervision>,
    stop_batchers: Arc<AtomicBool>,
    stop_workers: Arc<AtomicBool>,
    batchers: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
    max_inflight: Option<usize>,
    default_deadline: Option<Duration>,
    next_id: AtomicU64,
}

impl Server {
    /// Start batcher and worker threads (each worker supervised:
    /// panics respawn it with backoff, up to
    /// [`SupervisorPolicy::max_restarts`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::time::Duration;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 2.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let server = Server::start(ServerConfig::default(), factory);
    /// let out = server
    ///     .infer_blocking(vec![1.0, 3.0], Duration::from_secs(20))
    ///     .expect("response");
    /// assert_eq!(out, vec![4.0]); // mean 2 × scale 2
    /// assert!(server.shutdown().clean());
    /// ```
    pub fn start(cfg: ServerConfig, engine_factory: EngineFactory) -> Self {
        let router = Arc::new(Router::new(
            cfg.shards,
            cfg.route_policy,
            cfg.queue_config.clone(),
        ));
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop_batchers = Arc::new(AtomicBool::new(false));
        let stop_workers = Arc::new(AtomicBool::new(false));
        let worker_slots = if cfg.async_workers {
            cfg.workers.max(1)
        } else {
            cfg.workers
        };
        let supervision = Arc::new(Supervision::new(worker_slots, cfg.supervisor.clone()));

        // One flag arms the whole control plane: the batcher's adaptive
        // flush deadline follows the queues' `CmpConfig::adaptive`.
        let adaptive = cfg.queue_config.adaptive;
        let batchers = (0..cfg.shards)
            .map(|shard| {
                let (r, w, s) = (router.clone(), work.clone(), stop_batchers.clone());
                let m = metrics.clone();
                let policy = cfg.batch_policy.clone();
                let restart = cfg.supervisor.clone();
                std::thread::Builder::new()
                    .name(format!("batcher-{shard}"))
                    .spawn(move || batcher_loop(r, shard, policy, adaptive, w, s, m, restart))
                    .expect("spawn batcher")
            })
            .collect();
        let workers = if cfg.async_workers {
            // One host thread, `workers` executor tasks (async mode).
            let (w, m, s) = (work.clone(), metrics.clone(), stop_workers.clone());
            let f = engine_factory.clone();
            let sup = supervision.clone();
            let host = std::thread::Builder::new()
                .name("workers-async".into())
                .spawn(move || async_worker_loop(w, f, m, s, worker_slots, sup))
                .expect("spawn async worker host");
            vec![host]
        } else {
            (0..cfg.workers)
                .map(|i| {
                    let (w, m, s) = (work.clone(), metrics.clone(), stop_workers.clone());
                    let f = engine_factory.clone();
                    let sup = supervision.clone();
                    std::thread::Builder::new()
                        .name(format!("worker-{i}"))
                        .spawn(move || supervised_worker_loop(i, w, f, m, s, sup))
                        .expect("spawn worker")
                })
                .collect()
        };
        let monitor = {
            let (sup, m, s) = (supervision.clone(), metrics.clone(), stop_workers.clone());
            Some(
                std::thread::Builder::new()
                    .name("worker-monitor".into())
                    .spawn(move || monitor_loop(sup, m, s))
                    .expect("spawn monitor"),
            )
        };

        Server {
            router,
            work,
            metrics,
            supervision,
            stop_batchers,
            stop_workers,
            batchers,
            workers,
            monitor,
            max_inflight: cfg.max_inflight,
            default_deadline: cfg.default_deadline,
            next_id: AtomicU64::new(1),
        }
    }

    /// Whether the in-flight depth (`submitted − completed`) is at the
    /// admission limit. Approximate under concurrency, exact enough for
    /// load shedding.
    fn over_depth(&self, adding: u64) -> bool {
        match self.max_inflight {
            None => false,
            Some(depth) => {
                let submitted = self.metrics.submitted.load(Ordering::Relaxed);
                let completed = self.metrics.completed.load(Ordering::Relaxed);
                submitted.saturating_sub(completed) + adding > depth as u64
            }
        }
    }

    /// Build a request carrying the server-default deadline.
    fn make_request(&self, features: Vec<f32>, slot: Arc<ResponseSlot>) -> InferRequest {
        let now = Instant::now();
        InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tenant: 0,
            features,
            submitted_at: now,
            deadline: self.default_deadline.map(|d| now + d),
            slot,
        }
    }

    /// Submit a request; returns the slot to wait on, or
    /// [`SubmitError::Overloaded`] when admission control sheds it
    /// (in-flight depth at [`ServerConfig::max_inflight`], or the
    /// shard queue rejected the push). A shed request was never
    /// enqueued and counts in [`Metrics::shed`], not
    /// [`Metrics::submitted`].
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::time::Duration;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 1.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let server = Server::start(ServerConfig::default(), factory);
    /// let slot = server.submit(vec![2.0, 4.0]).expect("admitted");
    /// let resp = slot.wait_timeout(Duration::from_secs(20)).expect("response");
    /// assert_eq!(resp.output, vec![3.0]); // mean of [2, 4]
    /// server.shutdown();
    /// ```
    pub fn submit(&self, features: Vec<f32>) -> Result<Arc<ResponseSlot>, SubmitError> {
        self.submit_for_tenant(features, 0)
    }

    /// [`Server::submit`] with the request stamped as belonging to
    /// `tenant` (the TCP ingress passes the wire frame's tenant id; 0
    /// means untagged). Admission control is identical — per-tenant
    /// *fairness* caps live in the ingress ([`crate::net`]), not here.
    pub fn submit_for_tenant(
        &self,
        features: Vec<f32>,
        tenant: u32,
    ) -> Result<Arc<ResponseSlot>, SubmitError> {
        if self.over_depth(1) {
            self.metrics.record_shed();
            return Err(SubmitError::Overloaded);
        }
        let slot = ResponseSlot::new();
        let mut req = self.make_request(features, slot.clone());
        req.tenant = tenant;
        // `submitted` is incremented *before* the route and rolled back
        // on rejection — mirroring `Router::route`'s inflight gauge —
        // so a worker completing the request at once can never make a
        // concurrent `over_depth` read `submitted < completed` and
        // transiently bypass admission control.
        self.metrics.record_submit();
        match self.router.route(req) {
            Ok(_) => Ok(slot),
            Err(_rejected) => {
                self.metrics.submitted.fetch_sub(1, Ordering::Relaxed);
                self.metrics.record_shed();
                Err(SubmitError::Overloaded)
            }
        }
    }

    /// Submit a whole batch of requests through the router's batch
    /// fan-in ([`Router::route_many`]): one CMP cycle RMW and one tail
    /// CAS per shard touched, instead of per request. Returns the slots
    /// in submission order, or [`SubmitError::Overloaded`] when the
    /// whole batch is shed at admission. Admission is all-or-nothing:
    /// the batch must fit entirely in the remaining
    /// [`ServerConfig::max_inflight`] headroom, so a batch larger than
    /// the depth itself is always shed (split it client-side).
    ///
    /// If a shard rejects its group after admission (bounded capacity /
    /// injected fault), those requests' slots resolve immediately with
    /// [`InferError::Rejected`] — the call still returns `Ok` and no
    /// slot strands.
    pub fn submit_batch(
        &self,
        features_list: Vec<Vec<f32>>,
    ) -> Result<Vec<Arc<ResponseSlot>>, SubmitError> {
        let wanted = features_list.len() as u64;
        if self.over_depth(wanted) {
            self.metrics.shed.fetch_add(wanted, Ordering::Relaxed);
            return Err(SubmitError::Overloaded);
        }
        let mut slots = Vec::with_capacity(features_list.len());
        let mut reqs = Vec::with_capacity(features_list.len());
        for features in features_list {
            let slot = ResponseSlot::new();
            reqs.push(self.make_request(features, slot.clone()));
            slots.push(slot);
        }
        let total = reqs.len() as u64;
        // Pre-increment for the whole batch, rolled back for rejected
        // groups — same `over_depth` race as `submit`: counting after
        // `route_many` would let a fast worker drive `completed` past
        // `submitted` and open the admission gate to concurrent
        // submitters.
        self.metrics.submitted.fetch_add(total, Ordering::Relaxed);
        let rejected = self.router.route_many(reqs);
        let n_rejected = rejected.len() as u64;
        for req in rejected {
            // Never enqueued: resolve the slot explicitly (no metrics
            // completion — the request was never submitted).
            let latency = req.submitted_at.elapsed();
            let nack = InferResponse::nack(req.id, latency, InferError::Rejected);
            req.slot.complete(nack);
        }
        self.metrics.submitted.fetch_sub(n_rejected, Ordering::Relaxed);
        self.metrics.shed.fetch_add(n_rejected, Ordering::Relaxed);
        Ok(slots)
    }

    /// Submit a request and await its response without blocking a
    /// thread: the returned future registers its waker in the
    /// response slot and is woken by the completing worker
    /// (DESIGN.md §10). Executor-agnostic — drive it with
    /// [`crate::util::executor::block_on`], spawn it on a
    /// [`crate::util::Executor`], or hand it to any runtime.
    ///
    /// The request is routed *before* this returns (submission itself
    /// is cheap and non-blocking); only the wait is deferred, so
    /// dropping the future abandons the wait, not the request. Shed
    /// requests return [`SubmitError::Overloaded`] immediately.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    /// use cmpq::util::executor::{block_on, Executor};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 2.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let cfg = ServerConfig { async_workers: true, ..ServerConfig::default() };
    /// let server = Arc::new(Server::start(cfg, factory));
    ///
    /// // One-off await:
    /// let resp = block_on(server.submit_async(vec![1.0, 3.0]).expect("admitted"));
    /// assert_eq!(resp.output, vec![4.0]); // mean 2 × scale 2
    ///
    /// // Or many concurrent in-flight requests on one client thread:
    /// let mut ex = Executor::new();
    /// for i in 0..8u32 {
    ///     let server = server.clone();
    ///     ex.spawn(async move {
    ///         let fut = server.submit_async(vec![i as f32, i as f32]).expect("admitted");
    ///         let r = fut.await;
    ///         assert_eq!(r.output, vec![i as f32 * 2.0]);
    ///     });
    /// }
    /// ex.run();
    /// Arc::try_unwrap(server).ok().unwrap().shutdown();
    /// ```
    pub fn submit_async(&self, features: Vec<f32>) -> Result<ResponseFuture, SubmitError> {
        Ok(self.submit(features)?.wait_async())
    }

    /// [`Server::submit_async`] with a tenant stamp (see
    /// [`Server::submit_for_tenant`]) — the TCP connection state
    /// machine's entry point: one future per in-flight wire request,
    /// polled inline by the connection task.
    pub fn submit_async_for_tenant(
        &self,
        features: Vec<f32>,
        tenant: u32,
    ) -> Result<ResponseFuture, SubmitError> {
        Ok(self.submit_for_tenant(features, tenant)?.wait_async())
    }

    /// Convenience: submit and block for the response. `None` on shed,
    /// timeout, or a NACK/engine failure (all of which deliver empty
    /// output).
    pub fn infer_blocking(&self, features: Vec<f32>, timeout: Duration) -> Option<Vec<f32>> {
        self.submit(features)
            .ok()?
            .wait_timeout(timeout)
            .map(|r| r.output)
    }

    /// Pipeline metrics (counters + end-to-end latency histogram).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The request router (telemetry/tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Worker supervision state (restart counts, heartbeats).
    pub fn supervision(&self) -> &Supervision {
        &self.supervision
    }

    /// Whether any supervised stage has been abandoned — the server
    /// still serves what it can, at reduced capacity.
    pub fn is_degraded(&self) -> bool {
        self.metrics.is_degraded()
    }

    /// Nodes retained by the work queue's CMP pool (telemetry).
    pub fn work_queue_footprint(&self) -> u64 {
        self.work.footprint_nodes()
    }

    /// The batcher→worker work queue (telemetry: the `/metrics`
    /// endpoint reads its stats, control report, and adaptive
    /// decisions from here).
    pub fn work_queue(&self) -> &crate::queue::cmp::CmpQueue<super::batcher::Batch> {
        &self.work
    }

    /// Drain-then-park shutdown: batchers stop first (flushing whatever
    /// is pending), then workers — each stage's parked threads are woken
    /// explicitly so shutdown never waits out a park slice. All queues
    /// are fully drained before the corresponding threads exit.
    ///
    /// A panicked stage is *reported* in the [`ShutdownReport`] instead
    /// of re-panicking the caller mid-drain, and a residual drain NACKs
    /// ([`InferError::ShuttingDown`]) anything a dead stage left queued
    /// — every submitted request resolves, whatever happened to the
    /// threads serving it.
    pub fn shutdown(mut self) -> ShutdownReport {
        self.stop_batchers.store(true, Ordering::Release);
        self.router.wake_all();
        for b in self.batchers.drain(..) {
            if b.join().is_err() {
                // Escaped the batcher's own supervision (it should not)
                // — count it rather than re-panic mid-shutdown.
                self.metrics.record_batcher_panic();
            }
        }
        self.stop_workers.store(true, Ordering::Release);
        self.work.wake_consumers();
        for w in self.workers.drain(..) {
            if w.join().is_err() {
                self.metrics.record_worker_panic();
            }
        }
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        // Residual drain: a dead batcher leaves requests on its shard,
        // a dead worker fleet leaves batches on the work queue. NACK
        // them all — conservation over stranding.
        let mut drained_nacks = 0u64;
        for i in 0..self.router.shard_count() {
            while let Some(req) = self.router.drain_one(i) {
                drained_nacks += 1;
                let latency = req.submitted_at.elapsed();
                if req.slot.complete(InferResponse::nack(
                    req.id,
                    latency,
                    InferError::ShuttingDown,
                )) {
                    self.metrics.record_nack(latency);
                }
            }
        }
        while let Some(batch) = self.work.pop() {
            drained_nacks += batch.requests.len() as u64;
            nack_batch(batch, &self.metrics, InferError::ShuttingDown);
        }
        ShutdownReport {
            worker_panics: self.metrics.worker_panics.load(Ordering::Relaxed),
            batcher_panics: self.metrics.batcher_panics.load(Ordering::Relaxed),
            workers_dead: self.metrics.workers_dead.load(Ordering::Relaxed),
            batchers_dead: self.metrics.batchers_dead.load(Ordering::Relaxed),
            drained_nacks,
            degraded: self.metrics.is_degraded(),
            net_conns_closed: 0,
            net_drained_replies: 0,
            metrics: self.metrics.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::{EchoEngine, InferenceEngine};

    fn echo_factory() -> EngineFactory {
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 4,
                features: 2,
                outputs: 1,
                scale: 2.0,
            }) as Box<dyn InferenceEngine>)
        })
    }

    #[test]
    fn end_to_end_pipeline_with_echo_engine() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 2,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let mut slots = Vec::new();
        for i in 0..50u32 {
            let slot = server.submit(vec![i as f32, i as f32]).expect("admitted");
            slots.push((i, slot));
        }
        for (i, s) in &slots {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![*i as f32 * 2.0]);
        }
        let report = server.shutdown();
        assert!(report.clean());
        assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 50);
        assert!(report.metrics.latency_summary().count >= 50);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let server = Server::start(
            ServerConfig {
                shards: 1,
                workers: 1,
                batch_policy: BatchPolicy {
                    max_batch: 64, // never fills → only drain flushes
                    max_wait: Duration::from_secs(30),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let slots: Vec<_> = (0..5)
            .map(|i| server.submit(vec![i as f32, 0.0]).expect("admitted"))
            .collect();
        let report = server.shutdown();
        for s in slots {
            assert!(s.try_take().is_some(), "drained at shutdown");
        }
        assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn batch_submit_end_to_end() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 2,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let feats: Vec<Vec<f32>> = (0..40u32).map(|i| vec![i as f32, i as f32]).collect();
        let slots = server.submit_batch(feats).expect("admitted");
        assert_eq!(slots.len(), 40);
        for (i, s) in slots.iter().enumerate() {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![i as f32 * 2.0]);
        }
        let report = server.shutdown();
        assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn async_workers_serve_end_to_end() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 3, // 3 tasks on one host thread
                async_workers: true,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let mut slots = Vec::new();
        for i in 0..30u32 {
            let slot = server.submit(vec![i as f32, i as f32]).expect("admitted");
            slots.push((i, slot));
        }
        for (i, s) in &slots {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![*i as f32 * 2.0]);
        }
        let report = server.shutdown();
        assert!(report.clean());
        assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn submit_async_resolves_concurrently() {
        use crate::util::Executor;
        let server = Arc::new(Server::start(
            ServerConfig {
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                async_workers: true,
                ..ServerConfig::default()
            },
            echo_factory(),
        ));
        // 16 requests in flight from one client thread, no blocking.
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut ex = Executor::new();
        for i in 0..16u32 {
            let server = server.clone();
            let done = done.clone();
            ex.spawn(async move {
                let fut = server.submit_async(vec![i as f32, i as f32]).expect("admitted");
                let r = fut.await;
                assert_eq!(r.output, vec![i as f32 * 2.0]);
                done.fetch_add(1, Ordering::Relaxed);
            });
        }
        ex.run();
        assert_eq!(done.load(Ordering::Relaxed), 16);
        let server = Arc::try_unwrap(server).ok().unwrap();
        let report = server.shutdown();
        assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn blocking_helper_roundtrip() {
        let server = Server::start(ServerConfig::default(), echo_factory());
        let out = server
            .infer_blocking(vec![3.0, 5.0], Duration::from_secs(20))
            .expect("response");
        assert_eq!(out, vec![8.0]); // mean 4 × scale 2
        server.shutdown();
    }

    /// Engine whose `infer` blocks until released (admission tests).
    struct GatedEngine {
        gate: Arc<AtomicBool>,
    }

    impl InferenceEngine for GatedEngine {
        fn batch_size(&self) -> usize {
            1
        }
        fn features_per_row(&self) -> usize {
            2
        }
        fn outputs_per_row(&self) -> usize {
            1
        }
        fn infer(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
            while !self.gate.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(vec![input[0]])
        }
    }

    #[test]
    fn overload_sheds_and_recovers() {
        let gate = Arc::new(AtomicBool::new(false));
        let factory: EngineFactory = {
            let gate = gate.clone();
            Arc::new(move || {
                Ok(Box::new(GatedEngine { gate: gate.clone() }) as Box<dyn InferenceEngine>)
            })
        };
        let server = Server::start(
            ServerConfig {
                shards: 1,
                workers: 1,
                max_inflight: Some(4),
                batch_policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            factory,
        );
        // Fill the admission window while the engine is gated shut.
        let admitted: Vec<_> = (0..4)
            .map(|i| server.submit(vec![i as f32, 0.0]).expect("under the limit"))
            .collect();
        assert!(
            matches!(server.submit(vec![9.0, 0.0]), Err(SubmitError::Overloaded)),
            "depth 4 reached"
        );
        assert!(server.metrics().shed.load(Ordering::Relaxed) >= 1);
        // Release the engine: admitted load completes, depth drops,
        // and new submits are admitted again.
        gate.store(true, Ordering::Release);
        for s in &admitted {
            assert!(s.wait_timeout(Duration::from_secs(30)).is_some());
        }
        let slot = server.submit(vec![7.0, 0.0]).expect("readmitted after drain");
        let served = slot.wait_timeout(Duration::from_secs(30)).expect("served");
        assert_eq!(served.output, vec![7.0]);
        let report = server.shutdown();
        assert_eq!(
            report.metrics.submitted.load(Ordering::Relaxed),
            report.metrics.completed.load(Ordering::Relaxed),
            "conservation"
        );
    }

    #[test]
    fn default_deadline_expires_to_nack() {
        let server = Server::start(
            ServerConfig {
                shards: 1,
                workers: 1,
                // Already expired at submit: triaged at the first
                // checkpoint (batcher flush), never reaches the engine.
                default_deadline: Some(Duration::ZERO),
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let slot = server.submit(vec![1.0, 1.0]).expect("admitted");
        let resp = slot.wait_timeout(Duration::from_secs(20)).expect("resolved");
        assert_eq!(resp.error, Some(InferError::DeadlineExceeded));
        let report = server.shutdown();
        assert_eq!(report.metrics.deadline_expired.load(Ordering::Relaxed), 1);
        let completed = report.metrics.completed.load(Ordering::Relaxed);
        assert_eq!(completed, 1, "conservation");
    }

    /// Engine that panics on the first `infer` across all instances
    /// (the flag outlives the engine, so the respawned worker's fresh
    /// engine serves normally).
    struct PanicOnceEngine {
        tripped: Arc<AtomicBool>,
    }

    impl InferenceEngine for PanicOnceEngine {
        fn batch_size(&self) -> usize {
            4
        }
        fn features_per_row(&self) -> usize {
            2
        }
        fn outputs_per_row(&self) -> usize {
            1
        }
        fn infer(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
            if !self.tripped.swap(true, Ordering::SeqCst) {
                panic!("first inference panics");
            }
            Ok(vec![input[0]; 4])
        }
    }

    #[test]
    fn supervised_worker_restarts_after_panic() {
        let tripped = Arc::new(AtomicBool::new(false));
        let factory: EngineFactory = {
            let tripped = tripped.clone();
            Arc::new(move || {
                Ok(Box::new(PanicOnceEngine {
                    tripped: tripped.clone(),
                }) as Box<dyn InferenceEngine>)
            })
        };
        let server = Server::start(
            ServerConfig {
                shards: 1,
                workers: 1,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            factory,
        );
        // First request: the engine panics mid-batch → NACK, never a
        // strand, and the supervisor respawns the worker.
        let s1 = server.submit(vec![1.0, 1.0]).expect("admitted");
        let r1 = s1
            .wait_timeout(Duration::from_secs(30))
            .expect("nack, not strand");
        assert_eq!(r1.error, Some(InferError::WorkerPanicked));
        // Second request: served by the respawned worker.
        let s2 = server.submit(vec![5.0, 5.0]).expect("admitted");
        let r2 = s2
            .wait_timeout(Duration::from_secs(30))
            .expect("served after respawn");
        assert_eq!(r2.output, vec![5.0]);
        assert!(
            !server.is_degraded(),
            "one panic is inside the restart budget"
        );
        let report = server.shutdown();
        assert_eq!(report.worker_panics, 1);
        assert!(!report.clean(), "the panic is reported");
        assert_eq!(report.metrics.worker_restarts.load(Ordering::Relaxed), 1);
        assert_eq!(
            report.metrics.submitted.load(Ordering::Relaxed),
            report.metrics.completed.load(Ordering::Relaxed),
            "conservation across the panic"
        );
    }
}
