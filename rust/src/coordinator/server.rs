//! The serving pipeline: router → batchers → CMP work queue → workers.
//! Every hand-off is a CMP queue; the only blocking point is the
//! client-facing completion slot (by design — clients sleep, the
//! pipeline never does).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::queue::cmp::CmpConfig;

use super::batcher::{batcher_loop, new_work_queue, BatchPolicy, WorkQueue};
use super::metrics::Metrics;
use super::request::{InferRequest, ResponseFuture, ResponseSlot};
use super::router::{RoutePolicy, Router};
use super::worker::{async_worker_loop, worker_loop, EngineFactory};

/// Pipeline configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Router shards (one batcher thread per shard).
    pub shards: usize,
    /// Model workers: threads in the default mode, async tasks on one
    /// host thread when [`ServerConfig::async_workers`] is set.
    pub workers: usize,
    /// How the router spreads requests across shards.
    pub route_policy: RoutePolicy,
    /// Dynamic-batching knobs (size/deadline flush).
    pub batch_policy: BatchPolicy,
    /// CMP configuration for every queue in the pipeline.
    pub queue_config: CmpConfig,
    /// Async worker mode (DESIGN.md §10): run the `workers` model
    /// workers as round-robin executor tasks multiplexed over a single
    /// OS thread, pulling work through the CMP queue's async dequeues
    /// — the N-consumer idle fleet costs one parked thread instead of
    /// N. Default `false` (one thread per worker).
    pub async_workers: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 2,
            workers: 2,
            route_policy: RoutePolicy::RoundRobin,
            batch_policy: BatchPolicy::default(),
            queue_config: CmpConfig::default(),
            async_workers: false,
        }
    }
}

/// A running pipeline. Submit requests with [`Server::submit`]; call
/// [`Server::shutdown`] to drain and join.
pub struct Server {
    router: Arc<Router>,
    work: WorkQueue,
    metrics: Arc<Metrics>,
    stop_batchers: Arc<AtomicBool>,
    stop_workers: Arc<AtomicBool>,
    batchers: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Server {
    /// Start batcher and worker threads.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::time::Duration;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 2.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let server = Server::start(ServerConfig::default(), factory);
    /// let out = server
    ///     .infer_blocking(vec![1.0, 3.0], Duration::from_secs(20))
    ///     .expect("response");
    /// assert_eq!(out, vec![4.0]); // mean 2 × scale 2
    /// server.shutdown();
    /// ```
    pub fn start(cfg: ServerConfig, engine_factory: EngineFactory) -> Self {
        let router = Arc::new(Router::new(
            cfg.shards,
            cfg.route_policy,
            cfg.queue_config.clone(),
        ));
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop_batchers = Arc::new(AtomicBool::new(false));
        let stop_workers = Arc::new(AtomicBool::new(false));

        let batchers = (0..cfg.shards)
            .map(|shard| {
                let (r, w, s) = (router.clone(), work.clone(), stop_batchers.clone());
                let policy = cfg.batch_policy.clone();
                std::thread::Builder::new()
                    .name(format!("batcher-{shard}"))
                    .spawn(move || batcher_loop(r, shard, policy, w, s))
                    .expect("spawn batcher")
            })
            .collect();
        let workers = if cfg.async_workers {
            // One host thread, `workers` executor tasks (async mode).
            let (w, m, s) = (work.clone(), metrics.clone(), stop_workers.clone());
            let f = engine_factory.clone();
            let tasks = cfg.workers.max(1);
            let host = std::thread::Builder::new()
                .name("workers-async".into())
                .spawn(move || async_worker_loop(w, f, m, s, tasks))
                .expect("spawn async worker host");
            vec![host]
        } else {
            (0..cfg.workers)
                .map(|i| {
                    let (w, m, s) = (work.clone(), metrics.clone(), stop_workers.clone());
                    let f = engine_factory.clone();
                    std::thread::Builder::new()
                        .name(format!("worker-{i}"))
                        .spawn(move || worker_loop(w, f, m, s))
                        .expect("spawn worker")
                })
                .collect()
        };

        Server {
            router,
            work,
            metrics,
            stop_batchers,
            stop_workers,
            batchers,
            workers,
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit a request; returns the slot to wait on.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::time::Duration;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 1.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let server = Server::start(ServerConfig::default(), factory);
    /// let slot = server.submit(vec![2.0, 4.0]);
    /// let resp = slot.wait_timeout(Duration::from_secs(20)).expect("response");
    /// assert_eq!(resp.output, vec![3.0]); // mean of [2, 4]
    /// server.shutdown();
    /// ```
    pub fn submit(&self, features: Vec<f32>) -> Arc<ResponseSlot> {
        let slot = ResponseSlot::new();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            features,
            submitted_at: std::time::Instant::now(),
            slot: slot.clone(),
        };
        self.metrics.record_submit();
        self.router.route(req);
        slot
    }

    /// Submit a whole batch of requests through the router's batch
    /// fan-in ([`Router::route_many`]): one CMP cycle RMW and one tail
    /// CAS per shard touched, instead of per request. Returns the slots
    /// in submission order.
    pub fn submit_batch(&self, features_list: Vec<Vec<f32>>) -> Vec<Arc<ResponseSlot>> {
        let mut slots = Vec::with_capacity(features_list.len());
        let mut reqs = Vec::with_capacity(features_list.len());
        for features in features_list {
            let slot = ResponseSlot::new();
            reqs.push(InferRequest {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                features,
                submitted_at: std::time::Instant::now(),
                slot: slot.clone(),
            });
            self.metrics.record_submit();
            slots.push(slot);
        }
        self.router.route_many(reqs);
        slots
    }

    /// Submit a request and await its response without blocking a
    /// thread: the returned future registers its waker in the
    /// response slot and is woken by the completing worker
    /// (DESIGN.md §10). Executor-agnostic — drive it with
    /// [`crate::util::executor::block_on`], spawn it on a
    /// [`crate::util::Executor`], or hand it to any runtime.
    ///
    /// The request is routed *before* this returns (submission itself
    /// is cheap and non-blocking); only the wait is deferred, so
    /// dropping the future abandons the wait, not the request.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use cmpq::coordinator::server::{Server, ServerConfig};
    /// use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
    /// use cmpq::util::executor::{block_on, Executor};
    ///
    /// let factory: EngineFactory = Arc::new(|| {
    ///     Ok(Box::new(EchoEngine { batch: 4, features: 2, outputs: 1, scale: 2.0 })
    ///         as Box<dyn InferenceEngine>)
    /// });
    /// let cfg = ServerConfig { async_workers: true, ..ServerConfig::default() };
    /// let server = Arc::new(Server::start(cfg, factory));
    ///
    /// // One-off await:
    /// let resp = block_on(server.submit_async(vec![1.0, 3.0]));
    /// assert_eq!(resp.output, vec![4.0]); // mean 2 × scale 2
    ///
    /// // Or many concurrent in-flight requests on one client thread:
    /// let mut ex = Executor::new();
    /// for i in 0..8u32 {
    ///     let server = server.clone();
    ///     ex.spawn(async move {
    ///         let r = server.submit_async(vec![i as f32, i as f32]).await;
    ///         assert_eq!(r.output, vec![i as f32 * 2.0]);
    ///     });
    /// }
    /// ex.run();
    /// Arc::try_unwrap(server).ok().unwrap().shutdown();
    /// ```
    pub fn submit_async(&self, features: Vec<f32>) -> ResponseFuture {
        self.submit(features).wait_async()
    }

    /// Convenience: submit and block for the response.
    pub fn infer_blocking(&self, features: Vec<f32>, timeout: Duration) -> Option<Vec<f32>> {
        self.submit(features).wait_timeout(timeout).map(|r| r.output)
    }

    /// Pipeline metrics (counters + end-to-end latency histogram).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The request router (telemetry/tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Nodes retained by the work queue's CMP pool (telemetry).
    pub fn work_queue_footprint(&self) -> u64 {
        self.work.footprint_nodes()
    }

    /// Drain-then-park shutdown: batchers stop first (flushing whatever
    /// is pending), then workers — each stage's parked threads are woken
    /// explicitly so shutdown never waits out a park slice. All queues
    /// are fully drained before the corresponding threads exit.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.stop_batchers.store(true, Ordering::Release);
        self.router.wake_all();
        for b in self.batchers.drain(..) {
            b.join().expect("batcher panicked");
        }
        self.stop_workers.store(true, Ordering::Release);
        self.work.wake_consumers();
        for w in self.workers.drain(..) {
            w.join().expect("worker panicked");
        }
        self.metrics.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::{EchoEngine, InferenceEngine};

    fn echo_factory() -> EngineFactory {
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 4,
                features: 2,
                outputs: 1,
                scale: 2.0,
            }) as Box<dyn InferenceEngine>)
        })
    }

    #[test]
    fn end_to_end_pipeline_with_echo_engine() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 2,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let mut slots = Vec::new();
        for i in 0..50u32 {
            slots.push((i, server.submit(vec![i as f32, i as f32])));
        }
        for (i, s) in &slots {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![*i as f32 * 2.0]);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 50);
        assert!(metrics.latency_summary().count >= 50);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let server = Server::start(
            ServerConfig {
                shards: 1,
                workers: 1,
                batch_policy: BatchPolicy {
                    max_batch: 64, // never fills → only drain flushes
                    max_wait: Duration::from_secs(30),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let slots: Vec<_> = (0..5).map(|i| server.submit(vec![i as f32, 0.0])).collect();
        let metrics = server.shutdown();
        for s in slots {
            assert!(s.try_take().is_some(), "drained at shutdown");
        }
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn batch_submit_end_to_end() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 2,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let feats: Vec<Vec<f32>> = (0..40u32).map(|i| vec![i as f32, i as f32]).collect();
        let slots = server.submit_batch(feats);
        assert_eq!(slots.len(), 40);
        for (i, s) in slots.iter().enumerate() {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![i as f32 * 2.0]);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn async_workers_serve_end_to_end() {
        let server = Server::start(
            ServerConfig {
                shards: 2,
                workers: 3, // 3 tasks on one host thread
                async_workers: true,
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(),
        );
        let mut slots = Vec::new();
        for i in 0..30u32 {
            slots.push((i, server.submit(vec![i as f32, i as f32])));
        }
        for (i, s) in &slots {
            let r = s.wait_timeout(Duration::from_secs(20)).expect("response");
            assert_eq!(r.output, vec![*i as f32 * 2.0]);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn submit_async_resolves_concurrently() {
        use crate::util::Executor;
        let server = Arc::new(Server::start(
            ServerConfig {
                batch_policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                async_workers: true,
                ..ServerConfig::default()
            },
            echo_factory(),
        ));
        // 16 requests in flight from one client thread, no blocking.
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut ex = Executor::new();
        for i in 0..16u32 {
            let server = server.clone();
            let done = done.clone();
            ex.spawn(async move {
                let r = server.submit_async(vec![i as f32, i as f32]).await;
                assert_eq!(r.output, vec![i as f32 * 2.0]);
                done.fetch_add(1, Ordering::Relaxed);
            });
        }
        ex.run();
        assert_eq!(done.load(Ordering::Relaxed), 16);
        let server = Arc::try_unwrap(server).ok().expect("executor done");
        let metrics = server.shutdown();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn blocking_helper_roundtrip() {
        let server = Server::start(ServerConfig::default(), echo_factory());
        let out = server
            .infer_blocking(vec![3.0, 5.0], Duration::from_secs(20))
            .expect("response");
        assert_eq!(out, vec![8.0]); // mean 4 × scale 2
        server.shutdown();
    }
}
