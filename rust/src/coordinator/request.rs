//! Request/response types and the completion slot clients wait on.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// An inference request flowing through the CMP fabric.
pub struct InferRequest {
    /// Server-assigned request id.
    pub id: u64,
    /// Flattened feature row (`features_per_row` elements).
    pub features: Vec<f32>,
    /// When the client submitted (end-to-end latency anchor).
    pub submitted_at: Instant,
    /// Completion slot the client blocks on.
    pub slot: Arc<ResponseSlot>,
}

/// An inference result.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request this responds to.
    pub id: u64,
    /// Flattened output row (logits).
    pub output: Vec<f32>,
    /// Submit → complete latency.
    pub latency: Duration,
    /// Size of the batch this request rode in (telemetry).
    pub batch_size: usize,
}

/// One-shot completion slot (std-only oneshot channel: Mutex+Condvar).
#[derive(Default)]
pub struct ResponseSlot {
    inner: Mutex<Option<InferResponse>>,
    cv: Condvar,
}

impl ResponseSlot {
    /// An empty slot, shared between the submitting client and the
    /// worker that will complete it.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Complete the slot (worker side). Later completions are ignored —
    /// a slot completes exactly once.
    pub fn complete(&self, resp: InferResponse) {
        let mut g = self.inner.lock().unwrap();
        if g.is_none() {
            *g = Some(resp);
            self.cv.notify_all();
        }
    }

    /// Block until completed.
    pub fn wait(&self) -> InferResponse {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Block with a timeout; `None` on expiry.
    pub fn wait_timeout(&self, dur: Duration) -> Option<InferResponse> {
        let deadline = Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.is_none() {
                return None;
            }
        }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<InferResponse> {
        self.inner.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64) -> InferResponse {
        InferResponse {
            id,
            output: vec![1.0],
            latency: Duration::from_micros(5),
            batch_size: 8,
        }
    }

    #[test]
    fn complete_then_wait() {
        let s = ResponseSlot::new();
        s.complete(resp(1));
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn wait_blocks_until_complete() {
        let s = ResponseSlot::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.wait().id);
        std::thread::sleep(Duration::from_millis(5));
        s.complete(resp(7));
        assert_eq!(h.join().unwrap(), 7);
    }

    #[test]
    fn double_complete_keeps_first() {
        let s = ResponseSlot::new();
        s.complete(resp(1));
        s.complete(resp(2));
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn timeout_expires() {
        let s = ResponseSlot::new();
        assert!(s.wait_timeout(Duration::from_millis(5)).is_none());
        s.complete(resp(3));
        assert_eq!(s.wait_timeout(Duration::from_millis(5)).unwrap().id, 3);
    }

    #[test]
    fn try_take_is_nonblocking() {
        let s = ResponseSlot::new();
        assert!(s.try_take().is_none());
        s.complete(resp(4));
        assert_eq!(s.try_take().unwrap().id, 4);
        assert!(s.try_take().is_none(), "taken once");
    }
}
