//! Request/response types and the completion slot clients wait on —
//! blocking ([`ResponseSlot::wait`]) or async
//! ([`ResponseSlot::wait_async`], the surface behind
//! [`crate::coordinator::server::Server::submit_async`]).

use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

/// An inference request flowing through the CMP fabric.
pub struct InferRequest {
    /// Server-assigned request id.
    pub id: u64,
    /// Flattened feature row (`features_per_row` elements).
    pub features: Vec<f32>,
    /// When the client submitted (end-to-end latency anchor).
    pub submitted_at: Instant,
    /// Completion slot the client blocks on.
    pub slot: Arc<ResponseSlot>,
}

/// An inference result.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request this responds to.
    pub id: u64,
    /// Flattened output row (logits).
    pub output: Vec<f32>,
    /// Submit → complete latency.
    pub latency: Duration,
    /// Size of the batch this request rode in (telemetry).
    pub batch_size: usize,
}

/// One-shot completion slot (std-only oneshot channel: Mutex+Condvar
/// for blocking waiters, plus registered [`Waker`]s for async ones).
#[derive(Default)]
pub struct ResponseSlot {
    inner: Mutex<SlotInner>,
    cv: Condvar,
}

/// Guarded slot state: the response (until taken) and the wakers of
/// tasks pending in [`ResponseFuture`].
#[derive(Default)]
struct SlotInner {
    resp: Option<InferResponse>,
    wakers: Vec<Waker>,
}

impl ResponseSlot {
    /// An empty slot, shared between the submitting client and the
    /// worker that will complete it.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Complete the slot (worker side). Later completions are ignored —
    /// a slot completes exactly once. Wakes blocking and async waiters
    /// alike.
    pub fn complete(&self, resp: InferResponse) {
        let mut g = self.inner.lock().unwrap();
        if g.resp.is_none() {
            g.resp = Some(resp);
            let wakers = std::mem::take(&mut g.wakers);
            drop(g);
            self.cv.notify_all();
            for w in wakers {
                w.wake();
            }
        }
    }

    /// Block until completed.
    pub fn wait(&self) -> InferResponse {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.resp.take() {
                return r;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Block with a timeout; `None` on expiry.
    pub fn wait_timeout(&self, dur: Duration) -> Option<InferResponse> {
        let deadline = Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.resp.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.resp.is_none() {
                return None;
            }
        }
    }

    /// Await completion without blocking a thread: the returned future
    /// registers its waker in the slot and resolves when a worker
    /// completes it. The response is *taken* — with several futures
    /// (or a concurrent [`ResponseSlot::wait`]) on one slot, exactly
    /// one waiter receives it; the rest keep waiting.
    pub fn wait_async(self: &Arc<Self>) -> ResponseFuture {
        ResponseFuture { slot: self.clone() }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<InferResponse> {
        self.inner.lock().unwrap().resp.take()
    }

    /// Poll-protocol core of [`ResponseFuture`]: take the response or
    /// register `waker` (deduplicated against already-registered
    /// clones of itself).
    fn poll_take(&self, cx: &mut Context<'_>) -> Poll<InferResponse> {
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.resp.take() {
            return Poll::Ready(r);
        }
        if !g.wakers.iter().any(|w| w.will_wake(cx.waker())) {
            g.wakers.push(cx.waker().clone());
        }
        Poll::Pending
    }
}

/// Future returned by [`ResponseSlot::wait_async`] (and
/// [`crate::coordinator::server::Server::submit_async`]): resolves to
/// the [`InferResponse`] once a worker completes the slot.
///
/// The registration lives under the slot's mutex, so waker storage and
/// response publication cannot race: a completion either finds the
/// waker (and wakes it) or the next poll finds the response. Dropping
/// a pending future abandons only this waiter — the request itself
/// stays in flight and the worker's completion is kept in the slot for
/// any other waiter (a stale waker left behind is woken harmlessly).
pub struct ResponseFuture {
    slot: Arc<ResponseSlot>,
}

impl Future for ResponseFuture {
    type Output = InferResponse;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<InferResponse> {
        self.slot.poll_take(cx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64) -> InferResponse {
        InferResponse {
            id,
            output: vec![1.0],
            latency: Duration::from_micros(5),
            batch_size: 8,
        }
    }

    #[test]
    fn complete_then_wait() {
        let s = ResponseSlot::new();
        s.complete(resp(1));
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn wait_blocks_until_complete() {
        let s = ResponseSlot::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.wait().id);
        std::thread::sleep(Duration::from_millis(5));
        s.complete(resp(7));
        assert_eq!(h.join().unwrap(), 7);
    }

    #[test]
    fn double_complete_keeps_first() {
        let s = ResponseSlot::new();
        s.complete(resp(1));
        s.complete(resp(2));
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn timeout_expires() {
        let s = ResponseSlot::new();
        assert!(s.wait_timeout(Duration::from_millis(5)).is_none());
        s.complete(resp(3));
        assert_eq!(s.wait_timeout(Duration::from_millis(5)).unwrap().id, 3);
    }

    #[test]
    fn try_take_is_nonblocking() {
        let s = ResponseSlot::new();
        assert!(s.try_take().is_none());
        s.complete(resp(4));
        assert_eq!(s.try_take().unwrap().id, 4);
        assert!(s.try_take().is_none(), "taken once");
    }

    #[test]
    fn wait_async_resolves_on_complete() {
        use crate::util::executor::block_on;
        let s = ResponseSlot::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || block_on(s2.wait_async()).id);
        std::thread::sleep(Duration::from_millis(10));
        s.complete(resp(11));
        assert_eq!(h.join().unwrap(), 11);
    }

    #[test]
    fn wait_async_after_complete_is_immediate() {
        use crate::util::executor::block_on;
        let s = ResponseSlot::new();
        s.complete(resp(9));
        assert_eq!(block_on(s.wait_async()).id, 9);
        assert!(s.try_take().is_none(), "the future took it");
    }
}
