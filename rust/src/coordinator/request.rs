//! Request/response types and the completion slot clients wait on —
//! blocking ([`ResponseSlot::wait`]) or async
//! ([`ResponseSlot::wait_async`], the surface behind
//! [`crate::coordinator::server::Server::submit_async`]).

use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

/// An inference request flowing through the CMP fabric.
pub struct InferRequest {
    /// Server-assigned request id.
    pub id: u64,
    /// Tenant the request belongs to (0 = untagged). Stamped by the
    /// TCP ingress from the wire frame; in-process submissions default
    /// to 0. Carried through the pipeline for per-tenant accounting.
    pub tenant: u32,
    /// Flattened feature row (`features_per_row` elements).
    pub features: Vec<f32>,
    /// When the client submitted (end-to-end latency anchor).
    pub submitted_at: Instant,
    /// Absolute deadline, if any: past it the pipeline NACKs with
    /// [`InferError::DeadlineExceeded`] instead of paying engine cost.
    pub deadline: Option<Instant>,
    /// Completion slot the client blocks on.
    pub slot: Arc<ResponseSlot>,
}

impl InferRequest {
    /// Whether the request's deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why a request was NACKed instead of answered. Every submitted
/// request resolves as a response or one of these — the serving stack's
/// conservation invariant (DESIGN.md §11) is that nothing strands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// The worker processing the batch panicked before completing it.
    WorkerPanicked,
    /// The batcher owning the request's shard panicked while it was
    /// held in a partially-formed batch — or was abandoned past its
    /// restart cap, in which case the dead shard's drain loop resolves
    /// everything routed there with this error.
    BatcherPanicked,
    /// The engine returned an error for the batch (message attached).
    Engine(String),
    /// The request's deadline passed before an engine saw it.
    DeadlineExceeded,
    /// A queue rejected the request after admission (bounded capacity
    /// exhausted, or an injected routing fault).
    Rejected,
    /// The server shut down while the request was still queued.
    ShuttingDown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::WorkerPanicked => write!(f, "worker panicked mid-batch"),
            InferError::BatcherPanicked => write!(f, "batcher panicked holding the request"),
            InferError::Engine(msg) => write!(f, "engine error: {msg}"),
            InferError::DeadlineExceeded => write!(f, "deadline exceeded before inference"),
            InferError::Rejected => write!(f, "queue rejected the request"),
            InferError::ShuttingDown => write!(f, "server shut down with the request queued"),
        }
    }
}

impl std::error::Error for InferError {}

/// An inference result — or, when [`InferResponse::error`] is set, an
/// explicit NACK carrying why the request could not be served.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request this responds to.
    pub id: u64,
    /// Flattened output row (logits). Empty on NACKs.
    pub output: Vec<f32>,
    /// Submit → complete latency.
    pub latency: Duration,
    /// Size of the batch this request rode in (telemetry).
    pub batch_size: usize,
    /// `None` for a served response; `Some` for an explicit NACK.
    pub error: Option<InferError>,
}

impl InferResponse {
    /// Whether this is a served response rather than a NACK.
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// Build a NACK: empty output, `batch_size` 0, `error` set.
    pub fn nack(id: u64, latency: Duration, error: InferError) -> Self {
        InferResponse {
            id,
            output: Vec::new(),
            latency,
            batch_size: 0,
            error: Some(error),
        }
    }
}

/// One-shot completion slot (std-only oneshot channel: Mutex+Condvar
/// for blocking waiters, plus registered [`Waker`]s for async ones).
#[derive(Default)]
pub struct ResponseSlot {
    inner: Mutex<SlotInner>,
    cv: Condvar,
}

/// Guarded slot state: the response (until taken) and the wakers of
/// tasks pending in [`ResponseFuture`].
#[derive(Default)]
struct SlotInner {
    resp: Option<InferResponse>,
    wakers: Vec<Waker>,
}

impl ResponseSlot {
    /// An empty slot, shared between the submitting client and the
    /// worker that will complete it.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Complete the slot (worker side). Later completions are ignored —
    /// a slot completes exactly once. Wakes blocking and async waiters
    /// alike. Returns `true` iff this call stored the response, so NACK
    /// paths racing a real completion know whether to count it.
    pub fn complete(&self, resp: InferResponse) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.resp.is_some() {
            return false;
        }
        g.resp = Some(resp);
        let wakers = std::mem::take(&mut g.wakers);
        drop(g);
        self.cv.notify_all();
        for w in wakers {
            w.wake();
        }
        true
    }

    /// Block until completed.
    pub fn wait(&self) -> InferResponse {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.resp.take() {
                return r;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Block with a timeout; `None` on expiry.
    pub fn wait_timeout(&self, dur: Duration) -> Option<InferResponse> {
        let deadline = Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.resp.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.resp.is_none() {
                return None;
            }
        }
    }

    /// Await completion without blocking a thread: the returned future
    /// registers its waker in the slot and resolves when a worker
    /// completes it. The response is *taken* — with several futures
    /// (or a concurrent [`ResponseSlot::wait`]) on one slot, exactly
    /// one waiter receives it; the rest keep waiting.
    pub fn wait_async(self: &Arc<Self>) -> ResponseFuture {
        ResponseFuture { slot: self.clone() }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<InferResponse> {
        self.inner.lock().unwrap().resp.take()
    }

    /// Poll-protocol core of [`ResponseFuture`]: take the response or
    /// register `waker` (deduplicated against already-registered
    /// clones of itself).
    fn poll_take(&self, cx: &mut Context<'_>) -> Poll<InferResponse> {
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.resp.take() {
            return Poll::Ready(r);
        }
        if !g.wakers.iter().any(|w| w.will_wake(cx.waker())) {
            g.wakers.push(cx.waker().clone());
        }
        Poll::Pending
    }
}

/// Future returned by [`ResponseSlot::wait_async`] (and
/// [`crate::coordinator::server::Server::submit_async`]): resolves to
/// the [`InferResponse`] once a worker completes the slot.
///
/// The registration lives under the slot's mutex, so waker storage and
/// response publication cannot race: a completion either finds the
/// waker (and wakes it) or the next poll finds the response. Dropping
/// a pending future abandons only this waiter — the request itself
/// stays in flight and the worker's completion is kept in the slot for
/// any other waiter (a stale waker left behind is woken harmlessly).
pub struct ResponseFuture {
    slot: Arc<ResponseSlot>,
}

impl Future for ResponseFuture {
    type Output = InferResponse;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<InferResponse> {
        self.slot.poll_take(cx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64) -> InferResponse {
        InferResponse {
            id,
            output: vec![1.0],
            latency: Duration::from_micros(5),
            batch_size: 8,
            error: None,
        }
    }

    #[test]
    fn complete_then_wait() {
        let s = ResponseSlot::new();
        assert!(s.complete(resp(1)));
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn wait_blocks_until_complete() {
        let s = ResponseSlot::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.wait().id);
        std::thread::sleep(Duration::from_millis(5));
        s.complete(resp(7));
        assert_eq!(h.join().unwrap(), 7);
    }

    #[test]
    fn double_complete_keeps_first() {
        let s = ResponseSlot::new();
        assert!(s.complete(resp(1)));
        assert!(!s.complete(resp(2)), "second complete reports a loss");
        assert_eq!(s.wait().id, 1);
    }

    #[test]
    fn nack_shape_and_expiry() {
        let n = InferResponse::nack(5, Duration::from_micros(1), InferError::WorkerPanicked);
        assert!(!n.is_ok());
        assert!(n.output.is_empty());
        assert_eq!(n.error, Some(InferError::WorkerPanicked));
        assert!(resp(5).is_ok());

        let now = Instant::now();
        let req = InferRequest {
            id: 1,
            tenant: 0,
            features: vec![],
            submitted_at: now,
            deadline: Some(now),
            slot: ResponseSlot::new(),
        };
        assert!(req.expired(now));
        let open = InferRequest {
            id: 2,
            tenant: 0,
            features: vec![],
            submitted_at: now,
            deadline: None,
            slot: ResponseSlot::new(),
        };
        assert!(!open.expired(now + Duration::from_secs(3600)));
    }

    #[test]
    fn timeout_expires() {
        let s = ResponseSlot::new();
        assert!(s.wait_timeout(Duration::from_millis(5)).is_none());
        s.complete(resp(3));
        assert_eq!(s.wait_timeout(Duration::from_millis(5)).unwrap().id, 3);
    }

    #[test]
    fn try_take_is_nonblocking() {
        let s = ResponseSlot::new();
        assert!(s.try_take().is_none());
        s.complete(resp(4));
        assert_eq!(s.try_take().unwrap().id, 4);
        assert!(s.try_take().is_none(), "taken once");
    }

    #[test]
    fn wait_async_resolves_on_complete() {
        use crate::util::executor::block_on;
        let s = ResponseSlot::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || block_on(s2.wait_async()).id);
        std::thread::sleep(Duration::from_millis(10));
        s.complete(resp(11));
        assert_eq!(h.join().unwrap(), 11);
    }

    #[test]
    fn wait_async_after_complete_is_immediate() {
        use crate::util::executor::block_on;
        let s = ResponseSlot::new();
        s.complete(resp(9));
        assert_eq!(block_on(s.wait_async()).id, 9);
        assert!(s.try_take().is_none(), "the future took it");
    }
}
