//! Dynamic batcher: collects requests from a router shard into model-
//! sized batches, flushing on size or deadline — the standard serving
//! trade-off between padding waste and tail latency. Batches travel to
//! workers over another CMP queue (the whole pipeline is CMP fabric).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::queue::cmp::{CmpConfig, CmpQueue};
use crate::runtime::adaptive::{flush_wait_for, Ewma};
use crate::util::Backoff;

use super::metrics::Metrics;
use super::request::{InferError, InferRequest, InferResponse};
use super::router::Router;
use super::supervisor::{restart_backoff, sleep_observing_stop, SupervisorPolicy};
use super::worker::nack_batch;

/// A batch headed to a worker.
pub struct Batch {
    /// The requests riding in this batch, in arrival order.
    pub requests: Vec<InferRequest>,
    /// When the batch was sealed (queueing-delay telemetry).
    pub formed_at: Instant,
}

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush when this many requests are collected (model batch size).
    pub max_batch: usize,
    /// Flush a non-empty partial batch after this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The work queue between batchers and workers.
pub type WorkQueue = Arc<CmpQueue<Batch>>;

/// A fresh work queue with the default CMP configuration.
pub fn new_work_queue() -> WorkQueue {
    Arc::new(CmpQueue::with_config(CmpConfig::default()))
}

/// Longest single park on an idle shard with no partial batch pending.
/// A routed request (or `Server::shutdown`'s wake) ends the park
/// immediately; the slice only bounds stop-latency.
const BATCHER_PARK: Duration = Duration::from_millis(50);

/// Smoothing factor for the observed-batch-fill EWMA that drives the
/// adaptive flush deadline ([`flush_wait_for`]): a couple of full
/// batches shrink the deadline, a couple of starved ones restore it.
const FILL_ALPHA: f64 = 0.25;

/// Run one batcher loop over `shard` of `router`, publishing batches to
/// `work`. Returns when `stop` is set *and* the shard is drained.
///
/// Requests are pulled with [`Router::drain_many`] — one amortized CMP
/// batch claim fills as much of the pending model batch as the shard
/// can supply, instead of one dequeue (and one pair of global RMWs) per
/// request.
///
/// When the shard runs dry the loop escalates through [`Backoff`] and
/// then parks on the shard queue's eventcount
/// ([`Router::drain_deadline`]): with a partial batch pending it sleeps
/// only until that batch's flush deadline, otherwise for a bounded
/// slice. Arriving requests wake it immediately either way, so tail
/// latency is unchanged while idle shards cost no CPU (DESIGN.md §8).
///
/// The loop is supervised: a panic inside a collection pass NACKs the
/// partial batch it was holding ([`InferError::BatcherPanicked`] —
/// claimed requests never strand) and the pass restarts with
/// exponential backoff, up to `restart.max_restarts`. Past the cap the
/// shard's batcher is abandoned and the server degrades
/// ([`Metrics::record_batcher_dead`]): the shard leaves routing
/// rotation ([`Router::mark_dead`]) and this thread becomes a drain
/// loop that NACKs anything still routed there — a dead shard costs
/// clients an explicit error, never a hung wait.
///
/// With `adaptive` set (derived from the server's
/// `ServerConfig::queue_config`, so one flag arms the whole control
/// plane) the flush deadline is tuned online: an EWMA of batch fill
/// observed at each flush feeds [`flush_wait_for`], shrinking the
/// deadline when batches fill on their own and restoring the full
/// `max_wait` when the shard is starved. With it unset the fixed
/// `policy.max_wait` schedule is unchanged.
#[allow(clippy::too_many_arguments)] // supervision wiring: every arg is load-bearing
pub fn batcher_loop(
    router: Arc<Router>,
    shard: usize,
    policy: BatchPolicy,
    adaptive: bool,
    work: WorkQueue,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    restart: SupervisorPolicy,
) {
    // Lives outside the catch so a panicking pass's partial batch
    // survives to be NACKed instead of vanishing with the stack frame.
    let mut pending: Vec<InferRequest> = Vec::with_capacity(policy.max_batch);
    let mut restarts: u64 = 0;
    loop {
        let pass = catch_unwind(AssertUnwindSafe(|| {
            batcher_core(
                &router,
                shard,
                &policy,
                adaptive,
                &work,
                &stop,
                &metrics,
                &mut pending,
            )
        }));
        match pass {
            Ok(()) => return,
            Err(_) => {
                metrics.record_batcher_panic();
                for req in pending.drain(..) {
                    let latency = req.submitted_at.elapsed();
                    if req.slot.complete(InferResponse::nack(
                        req.id,
                        latency,
                        InferError::BatcherPanicked,
                    )) {
                        metrics.record_nack(latency);
                    }
                }
                if stop.load(Ordering::Acquire) {
                    // Shutdown's residual drain owns whatever is still
                    // queued on the shard.
                    return;
                }
                restarts += 1;
                if restarts > restart.max_restarts as u64 {
                    metrics.record_batcher_dead();
                    router.mark_dead(shard);
                    eprintln!(
                        "batcher {shard}: abandoned after {} restarts — shard out of \
                         rotation, draining to NACKs; server degraded",
                        restarts - 1
                    );
                    dead_shard_drain(&router, shard, &stop, &metrics);
                    return;
                }
                sleep_observing_stop(restart_backoff(&restart, restarts), &stop);
            }
        }
    }
}

/// Terminal loop for a shard whose batcher was abandoned past the
/// restart cap. The shard is already out of `pick` rotation
/// ([`Router::mark_dead`]), but requests routed before the mark — or
/// routed anyway because every shard is dead — must still resolve, so
/// this drains the shard and NACKs each request
/// ([`InferError::BatcherPanicked`]) until `stop` is set and the shard
/// is empty. Without it, traffic landing on the dead shard would sit
/// queued until shutdown's residual drain — a hung client for the full
/// wait timeout, exactly what the robustness layer promises never
/// happens.
fn dead_shard_drain(router: &Router, shard: usize, stop: &AtomicBool, metrics: &Metrics) {
    let mut reqs: Vec<InferRequest> = Vec::new();
    loop {
        let deadline = Instant::now() + BATCHER_PARK;
        let got = router.drain_deadline(shard, 64, &mut reqs, deadline);
        for req in reqs.drain(..) {
            let latency = req.submitted_at.elapsed();
            if req.slot.complete(InferResponse::nack(
                req.id,
                latency,
                InferError::BatcherPanicked,
            )) {
                metrics.record_nack(latency);
            }
        }
        if got == 0 && stop.load(Ordering::Acquire) && router.inflight(shard) == 0 {
            return;
        }
    }
}

/// One supervised collection pass (the pre-supervision `batcher_loop`
/// body). Returns on drain-then-exit; panics propagate to the wrapper.
#[allow(clippy::too_many_arguments)] // supervision wiring: every arg is load-bearing
fn batcher_core(
    router: &Router,
    shard: usize,
    policy: &BatchPolicy,
    adaptive: bool,
    work: &WorkQueue,
    stop: &AtomicBool,
    metrics: &Metrics,
    pending: &mut Vec<InferRequest>,
) {
    let mut window_start: Option<Instant> = if pending.is_empty() {
        None
    } else {
        Some(Instant::now())
    };
    // Observed batch fill at flush time; local to the pass, so a
    // supervisor restart re-learns the regime instead of trusting
    // pre-panic history.
    let mut fill = Ewma::new(FILL_ALPHA);
    let mut idle = Backoff::new();
    loop {
        // Effective flush deadline for this iteration: the configured
        // knob on the fixed path, fill-feedback-scaled when adaptive.
        let max_wait = if adaptive {
            flush_wait_for(policy.max_wait, fill.value().unwrap_or(0.0))
        } else {
            policy.max_wait
        };
        // `pending` is always below max_batch here (flushed on fill).
        let room = policy.max_batch - pending.len();
        let got = if idle.is_yielding() {
            // Spin budget spent: park until requests arrive, the flush
            // deadline of the pending partial batch, or the backstop
            // slice — whichever comes first (the backstop also bounds
            // how stale a `stop` observation can get).
            let backstop = Instant::now() + BATCHER_PARK;
            let deadline = match window_start {
                Some(t) => (t + max_wait).min(backstop),
                None => backstop,
            };
            router.drain_deadline(shard, room, pending, deadline)
        } else {
            router.drain_many(shard, room, pending)
        };
        if got > 0 {
            idle.reset();
            if window_start.is_none() {
                window_start = Some(Instant::now());
            }
            if pending.len() >= policy.max_batch {
                observe_fill(&mut fill, pending.len(), policy, max_wait, metrics);
                flush(pending, work, metrics);
                window_start = None;
            }
        } else {
            let expired = window_start
                .map(|t| t.elapsed() >= max_wait)
                .unwrap_or(false);
            if !pending.is_empty() && expired {
                observe_fill(&mut fill, pending.len(), policy, max_wait, metrics);
                flush(pending, work, metrics);
                window_start = None;
            } else if stop.load(Ordering::Acquire) {
                // Drain-then-exit: flush whatever is left (no fill
                // observation — a shutdown remnant says nothing about
                // the arrival regime).
                if router.inflight(shard) == 0 {
                    if !pending.is_empty() {
                        flush(pending, work, metrics);
                    }
                    return;
                }
            } else {
                idle.spin();
            }
        }
    }
}

/// Fold one sealed batch's fill into the EWMA and publish the batcher
/// control gauges ([`Metrics::set_batch_window`]). Runs once per flush,
/// never on the per-request path.
fn observe_fill(
    fill: &mut Ewma,
    sealed: usize,
    policy: &BatchPolicy,
    max_wait: Duration,
    metrics: &Metrics,
) {
    let observed = fill.observe(sealed as f64 / policy.max_batch.max(1) as f64);
    metrics.set_batch_window(observed, max_wait);
}

fn flush(pending: &mut Vec<InferRequest>, work: &WorkQueue, metrics: &Metrics) {
    crate::fail_point!("batcher/flush");
    // Deadline triage at batch-seal time: expired requests are NACKed
    // here instead of riding to a worker (it re-checks for requests
    // that expire in the work queue).
    let now = Instant::now();
    let mut requests = Vec::with_capacity(pending.len());
    for req in pending.drain(..) {
        if req.expired(now) {
            let latency = req.submitted_at.elapsed();
            if req.slot.complete(InferResponse::nack(
                req.id,
                latency,
                InferError::DeadlineExceeded,
            )) {
                metrics.record_deadline_nack(latency);
            }
        } else {
            requests.push(req);
        }
    }
    if requests.is_empty() {
        return;
    }
    let batch = Batch {
        requests,
        formed_at: now,
    };
    if let Err(batch) = work.push(batch) {
        // Unreachable with the default unbounded work queue; reachable
        // with a bounded capacity or an injected fault. Either way the
        // requests resolve with an explicit error, never strand.
        nack_batch(batch, metrics, InferError::Rejected);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ResponseSlot;
    use crate::coordinator::router::RoutePolicy;
    use crate::queue::cmp::CmpConfig;

    fn req(id: u64) -> InferRequest {
        InferRequest {
            id,
            tenant: 0,
            features: vec![0.0; 2],
            submitted_at: Instant::now(),
            deadline: None,
            slot: ResponseSlot::new(),
        }
    }

    fn spawn_batcher(
        router: &Arc<Router>,
        policy: BatchPolicy,
    ) -> (WorkQueue, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        spawn_batcher_mode(router, policy, false)
    }

    fn spawn_batcher_mode(
        router: &Arc<Router>,
        policy: BatchPolicy,
        adaptive: bool,
    ) -> (WorkQueue, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let work = new_work_queue();
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let router = router.clone();
            let work = work.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                batcher_loop(
                    router,
                    0,
                    policy,
                    adaptive,
                    work,
                    stop,
                    Arc::new(Metrics::new()),
                    SupervisorPolicy::default(),
                )
            })
        };
        (work, stop, h)
    }

    #[test]
    fn full_batches_flush_on_size() {
        let router = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let (work, stop, h) = spawn_batcher(
            &router,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(10), // deadline never fires
            },
        );
        for i in 0..8 {
            router.route(req(i)).ok().unwrap();
        }
        // Two full batches must appear without the deadline.
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < 2 && Instant::now() < deadline {
            if let Some(b) = work.pop() {
                got.push(b);
            } else {
                std::thread::yield_now();
            }
        }
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].requests.len(), 4);
        assert_eq!(got[1].requests.len(), 4);
        // FIFO preserved through router + batcher.
        let ids: Vec<u64> = got
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        stop.store(true, Ordering::Release);
        h.join().unwrap();
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let router = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let (work, stop, h) = spawn_batcher(
            &router,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
        );
        for i in 0..3 {
            router.route(req(i)).ok().unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        let batch = loop {
            if let Some(b) = work.pop() {
                break b;
            }
            assert!(Instant::now() < deadline, "deadline flush never happened");
            std::thread::yield_now();
        };
        assert_eq!(batch.requests.len(), 3, "partial batch after max_wait");
        stop.store(true, Ordering::Release);
        h.join().unwrap();
    }

    #[test]
    fn adaptive_batcher_flushes_full_and_partial() {
        // Same contract as the fixed path: full batches seal on size,
        // partials on deadline — adaptivity only moves the deadline
        // within (0, max_wait], never past it.
        let router = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let (work, stop, h) = spawn_batcher_mode(
            &router,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(5),
            },
            true,
        );
        for i in 0..6 {
            router.route(req(i)).ok().unwrap();
        }
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.iter().map(|b: &Batch| b.requests.len()).sum::<usize>() < 6 {
            assert!(Instant::now() < deadline, "adaptive batcher stalled");
            match work.pop() {
                Some(b) => got.push(b),
                None => std::thread::yield_now(),
            }
        }
        assert_eq!(got[0].requests.len(), 4, "first batch seals on size");
        let ids: Vec<u64> = got
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        assert_eq!(ids, (0..6).collect::<Vec<_>>(), "FIFO preserved");
        stop.store(true, Ordering::Release);
        h.join().unwrap();
    }

    #[test]
    fn observe_fill_publishes_gauges() {
        let metrics = Metrics::new();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        };
        let mut fill = Ewma::new(FILL_ALPHA);
        observe_fill(&mut fill, 8, &policy, policy.max_wait, &metrics);
        assert_eq!(metrics.batch_fill_permille.load(Ordering::Relaxed), 1000);
        assert_eq!(metrics.batch_wait_us.load(Ordering::Relaxed), 2000);
        // A starved flush drags the EWMA down: 1.0 + 0.25 × (0.25 − 1.0).
        observe_fill(&mut fill, 2, &policy, policy.max_wait, &metrics);
        assert_eq!(metrics.batch_fill_permille.load(Ordering::Relaxed), 813);
    }

    #[test]
    fn stop_drains_remaining() {
        let router = Arc::new(Router::new(1, RoutePolicy::RoundRobin, CmpConfig::default()));
        let (work, stop, h) = spawn_batcher(
            &router,
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(10),
            },
        );
        for i in 0..5 {
            router.route(req(i)).ok().unwrap();
        }
        std::thread::sleep(Duration::from_millis(10));
        stop.store(true, Ordering::Release);
        h.join().unwrap();
        let b = work.pop().expect("drain flush");
        assert_eq!(b.requests.len(), 5);
    }
}
