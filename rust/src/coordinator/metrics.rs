//! Pipeline metrics: counters plus an end-to-end latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::bench::latency::{Histogram, LatencySummary};

/// Shared pipeline metrics (cheap counters, mutex-guarded histogram —
/// recorded once per *batch*, not per queue op).
#[derive(Default)]
pub struct Metrics {
    /// Requests accepted by the server.
    pub submitted: AtomicU64,
    /// Responses delivered (including failures).
    pub completed: AtomicU64,
    /// Model invocations executed.
    pub batches: AtomicU64,
    /// Sum of padded rows (batch capacity − real requests).
    pub padding_rows: AtomicU64,
    /// Failed inferences (responses completed with empty output).
    pub failures: AtomicU64,
    latency: Mutex<Histogram>,
}

impl Metrics {
    /// Fresh all-zero metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one accepted request.
    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one model invocation carrying `real` requests out of
    /// `capacity` rows.
    pub fn record_batch(&self, real: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padding_rows
            .fetch_add((capacity - real) as u64, Ordering::Relaxed);
    }

    /// Count one delivered response and record its end-to-end latency.
    pub fn record_complete(&self, latency: Duration, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failures.fetch_add(1, Ordering::Relaxed);
        }
        self.latency
            .lock()
            .unwrap()
            .record(latency.as_nanos() as u64);
    }

    /// Summary of the end-to-end latency histogram.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary::from_histogram(&self.latency.lock().unwrap())
    }

    /// Padding overhead ratio: padded rows / total rows.
    pub fn padding_ratio(&self) -> f64 {
        let pads = self.padding_rows.load(Ordering::Relaxed) as f64;
        let real = self.completed.load(Ordering::Relaxed) as f64;
        if pads + real == 0.0 {
            0.0
        } else {
            pads / (pads + real)
        }
    }

    /// One-line human-readable summary of every counter.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        format!(
            "submitted={} completed={} failures={} batches={} padding_ratio={:.3} \
             latency: avg={:.1}us p50={}us p99={}us",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padding_ratio(),
            s.avg_ns / 1000.0,
            s.p50_ns / 1000,
            s.p99_ns / 1000,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_batch(6, 8);
        m.record_complete(Duration::from_micros(100), true);
        m.record_complete(Duration::from_micros(300), false);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failures.load(Ordering::Relaxed), 1);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.padding_rows.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn padding_ratio_math() {
        let m = Metrics::new();
        assert_eq!(m.padding_ratio(), 0.0);
        m.record_batch(6, 8); // 2 pads
        m.record_complete(Duration::from_micros(1), true);
        m.record_complete(Duration::from_micros(1), true);
        // 2 pads vs 2 real → 0.5
        assert!((m.padding_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn latency_summary_reflects_records() {
        let m = Metrics::new();
        m.record_complete(Duration::from_nanos(1000), true);
        m.record_complete(Duration::from_nanos(3000), true);
        let s = m.latency_summary();
        assert_eq!(s.count, 2);
        assert!((s.avg_ns - 2000.0).abs() < 1.0);
    }

    #[test]
    fn report_contains_fields() {
        let m = Metrics::new();
        m.record_submit();
        let r = m.report();
        assert!(r.contains("submitted=1"));
        assert!(r.contains("latency:"));
    }
}
