//! Pipeline metrics: counters plus an end-to-end latency histogram.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::bench::latency::{Histogram, LatencySummary};

/// Shared pipeline metrics (cheap counters, mutex-guarded histogram —
/// recorded once per *batch*, not per queue op).
///
/// Conservation invariant: every request counted in `submitted`
/// eventually shows up in `completed` — served, engine-failed, or
/// NACKed. Requests shed at admission (`shed`) are counted in neither.
#[derive(Default)]
pub struct Metrics {
    /// Requests accepted by the server (admitted *and* routed).
    pub submitted: AtomicU64,
    /// Responses delivered (including failures and NACKs).
    pub completed: AtomicU64,
    /// Model invocations executed.
    pub batches: AtomicU64,
    /// Sum of padded rows (batch capacity − real requests).
    pub padding_rows: AtomicU64,
    /// Failed inferences (engine returned an error for the batch).
    pub failures: AtomicU64,
    /// Requests resolved with an explicit [`crate::coordinator::request::InferError`]
    /// NACK (worker/batcher panic, queue rejection, shutdown drain).
    pub nacks: AtomicU64,
    /// Requests NACKed specifically for an expired deadline (also
    /// counted in `nacks`).
    pub deadline_expired: AtomicU64,
    /// Requests refused at admission (`Overloaded`) — never submitted.
    pub shed: AtomicU64,
    /// Requests refused at the network edge by per-tenant admission
    /// (a tenant over its in-flight cap) — a subset of `shed`; they
    /// never reached `Server::submit`.
    pub shed_tenant: AtomicU64,
    /// Worker panics caught by supervision (or observed at shutdown).
    pub worker_panics: AtomicU64,
    /// Worker respawns performed by the supervisor.
    pub worker_restarts: AtomicU64,
    /// Workers abandoned after exhausting their restart cap.
    pub workers_dead: AtomicU64,
    /// Batcher panics caught by the restart wrapper.
    pub batcher_panics: AtomicU64,
    /// Batchers abandoned after exhausting their restart cap.
    pub batchers_dead: AtomicU64,
    /// Gauge: workers currently running but not heartbeating (wedged).
    pub workers_stalled: AtomicU64,
    /// Gauge: EWMA of observed batch fill at flush time, in permille
    /// (0–1000). Written by batchers after every flush; last writer
    /// wins across shards, which is fine for a coarse control signal.
    pub batch_fill_permille: AtomicU64,
    /// Gauge: effective batcher flush deadline in microseconds (equals
    /// `BatchPolicy::max_wait` on the fixed path; shrinks under the
    /// adaptive control plane when batches run full).
    pub batch_wait_us: AtomicU64,
    /// Latched once any stage is abandoned: the server still serves
    /// what it can, but at reduced capacity.
    degraded: AtomicBool,
    latency: Mutex<Histogram>,
}

impl Metrics {
    /// Fresh all-zero metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one accepted request.
    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one model invocation carrying `real` requests out of
    /// `capacity` rows.
    pub fn record_batch(&self, real: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padding_rows
            .fetch_add((capacity - real) as u64, Ordering::Relaxed);
    }

    /// Count one delivered response and record its end-to-end latency.
    pub fn record_complete(&self, latency: Duration, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failures.fetch_add(1, Ordering::Relaxed);
        }
        self.latency
            .lock()
            .unwrap()
            .record(latency.as_nanos() as u64);
    }

    /// Count one NACK delivery (the slot resolved with an error).
    /// Completed++ so conservation holds; `failures` stays engine-only.
    pub fn record_nack(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.nacks.fetch_add(1, Ordering::Relaxed);
        self.latency
            .lock()
            .unwrap()
            .record(latency.as_nanos() as u64);
    }

    /// Count one deadline-expiry NACK (a `record_nack` plus the
    /// dedicated counter).
    pub fn record_deadline_nack(&self, latency: Duration) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
        self.record_nack(latency);
    }

    /// Count one request refused at admission.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one per-tenant admission refusal at the network edge — a
    /// [`Metrics::record_shed`] plus the dedicated counter, keeping one
    /// conservation ledger across both shedding layers.
    pub fn record_tenant_shed(&self) {
        self.shed_tenant.fetch_add(1, Ordering::Relaxed);
        self.record_shed();
    }

    /// Count one caught worker panic.
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one supervisor-driven worker respawn.
    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker abandoned past its restart cap; latches
    /// degraded mode.
    pub fn record_worker_dead(&self) {
        self.workers_dead.fetch_add(1, Ordering::Relaxed);
        self.degraded.store(true, Ordering::Relaxed);
    }

    /// Count one caught batcher panic.
    pub fn record_batcher_panic(&self) {
        self.batcher_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a batcher abandoned past its restart cap; latches
    /// degraded mode.
    pub fn record_batcher_dead(&self) {
        self.batchers_dead.fetch_add(1, Ordering::Relaxed);
        self.degraded.store(true, Ordering::Relaxed);
    }

    /// Update the wedged-worker gauge (set by the supervisor monitor).
    pub fn set_stalled(&self, n: u64) {
        self.workers_stalled.store(n, Ordering::Relaxed);
    }

    /// Update the batcher control gauges: smoothed flush fill (0.0–1.0)
    /// and the effective flush deadline currently in force.
    pub fn set_batch_window(&self, fill: f64, wait: Duration) {
        let permille = (fill.clamp(0.0, 1.0) * 1000.0).round() as u64;
        self.batch_fill_permille.store(permille, Ordering::Relaxed);
        self.batch_wait_us
            .store(wait.as_micros() as u64, Ordering::Relaxed);
    }

    /// Whether any stage has been abandoned (reduced capacity).
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Summary of the end-to-end latency histogram.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary::from_histogram(&self.latency.lock().unwrap())
    }

    /// Padding overhead ratio: padded rows / total rows.
    pub fn padding_ratio(&self) -> f64 {
        let pads = self.padding_rows.load(Ordering::Relaxed) as f64;
        let real = self.completed.load(Ordering::Relaxed) as f64;
        if pads + real == 0.0 {
            0.0
        } else {
            pads / (pads + real)
        }
    }

    /// One-line human-readable summary of every counter.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let mut out = format!(
            "submitted={} completed={} failures={} nacks={} shed={} batches={} \
             padding_ratio={:.3} latency: avg={:.1}us p50={}us p99={}us",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.nacks.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padding_ratio(),
            s.avg_ns / 1000.0,
            s.p50_ns / 1000,
            s.p99_ns / 1000,
        );
        let tenant_shed = self.shed_tenant.load(Ordering::Relaxed);
        if tenant_shed > 0 {
            out.push_str(&format!(" shed_tenant={tenant_shed}"));
        }
        let panics = self.worker_panics.load(Ordering::Relaxed)
            + self.batcher_panics.load(Ordering::Relaxed);
        if panics > 0 || self.is_degraded() {
            out.push_str(&format!(
                " | health: worker_panics={} restarts={} workers_dead={} \
                 batcher_panics={} batchers_dead={} stalled={} degraded={}",
                self.worker_panics.load(Ordering::Relaxed),
                self.worker_restarts.load(Ordering::Relaxed),
                self.workers_dead.load(Ordering::Relaxed),
                self.batcher_panics.load(Ordering::Relaxed),
                self.batchers_dead.load(Ordering::Relaxed),
                self.workers_stalled.load(Ordering::Relaxed),
                self.is_degraded(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_batch(6, 8);
        m.record_complete(Duration::from_micros(100), true);
        m.record_complete(Duration::from_micros(300), false);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failures.load(Ordering::Relaxed), 1);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.padding_rows.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn padding_ratio_math() {
        let m = Metrics::new();
        assert_eq!(m.padding_ratio(), 0.0);
        m.record_batch(6, 8); // 2 pads
        m.record_complete(Duration::from_micros(1), true);
        m.record_complete(Duration::from_micros(1), true);
        // 2 pads vs 2 real → 0.5
        assert!((m.padding_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn latency_summary_reflects_records() {
        let m = Metrics::new();
        m.record_complete(Duration::from_nanos(1000), true);
        m.record_complete(Duration::from_nanos(3000), true);
        let s = m.latency_summary();
        assert_eq!(s.count, 2);
        assert!((s.avg_ns - 2000.0).abs() < 1.0);
    }

    #[test]
    fn report_contains_fields() {
        let m = Metrics::new();
        m.record_submit();
        let r = m.report();
        assert!(r.contains("submitted=1"));
        assert!(r.contains("latency:"));
        assert!(!r.contains("health:"), "healthy runs omit the health tail");
    }

    #[test]
    fn nacks_preserve_conservation() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_complete(Duration::from_micros(10), true);
        m.record_nack(Duration::from_micros(20));
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.nacks.load(Ordering::Relaxed), 1);
        assert_eq!(m.failures.load(Ordering::Relaxed), 0, "nack is not an engine failure");
        assert_eq!(m.latency_summary().count, 2, "nack latency recorded");
    }

    #[test]
    fn deadline_nack_counts_both() {
        let m = Metrics::new();
        m.record_deadline_nack(Duration::from_micros(5));
        assert_eq!(m.nacks.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tenant_shed_counts_into_shed() {
        let m = Metrics::new();
        m.record_tenant_shed();
        m.record_shed();
        assert_eq!(m.shed.load(Ordering::Relaxed), 2, "one ledger");
        assert_eq!(m.shed_tenant.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("shed_tenant=1"));
    }

    #[test]
    fn degraded_latches_and_reports() {
        let m = Metrics::new();
        assert!(!m.is_degraded());
        m.record_worker_panic();
        m.record_worker_restart();
        m.record_worker_dead();
        assert!(m.is_degraded());
        let r = m.report();
        assert!(r.contains("health:"));
        assert!(r.contains("workers_dead=1"));
        assert!(r.contains("degraded=true"));
        m.record_batcher_dead();
        assert_eq!(m.batchers_dead.load(Ordering::Relaxed), 1);
        m.set_stalled(3);
        assert_eq!(m.workers_stalled.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn batch_window_gauges_clamp_and_convert() {
        let m = Metrics::new();
        m.set_batch_window(0.75, Duration::from_millis(2));
        assert_eq!(m.batch_fill_permille.load(Ordering::Relaxed), 750);
        assert_eq!(m.batch_wait_us.load(Ordering::Relaxed), 2000);
        m.set_batch_window(1.7, Duration::from_micros(500));
        assert_eq!(m.batch_fill_permille.load(Ordering::Relaxed), 1000);
        assert_eq!(m.batch_wait_us.load(Ordering::Relaxed), 500);
    }
}
