//! Inference-serving pipeline over CMP queues — the paper's motivating
//! "AI era" workload (§1): request router → dynamic batcher → model
//! workers → response path, with CMP queues as the only inter-thread
//! fabric. Workers execute the AOT-compiled JAX/Pallas model through
//! [`crate::runtime`]; Python is never on the request path.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod supervisor;
pub mod worker;
