//! Model workers: pull batches off the CMP work queue, assemble the
//! padded model input, run inference, complete each request's slot.
//!
//! Workers are generic over an [`InferenceEngine`] so the pipeline is
//! testable without artifacts; production workers use
//! [`crate::runtime::ModelRuntime`] (each worker owns its own PJRT
//! executable — `PjRtLoadedExecutable` is not `Send`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batch, WorkQueue};
use super::metrics::Metrics;
use super::request::{InferError, InferResponse, ResponseSlot};
use super::supervisor::{restart_backoff, Supervision, WorkerState};
use crate::util::executor::sleep_until;
use crate::util::{Backoff, Executor};

/// Something that can run a fixed-shape batched inference.
pub trait InferenceEngine {
    /// Rows per model invocation.
    fn batch_size(&self) -> usize;
    /// Features per row.
    fn features_per_row(&self) -> usize;
    /// Outputs per row.
    fn outputs_per_row(&self) -> usize;
    /// Run one full batch: input is `batch_size × features_per_row`.
    fn infer(&self, input: &[f32]) -> Result<Vec<f32>>;
}

/// Per-worker engine constructor (runs on the worker thread because
/// PJRT executables are not `Send`).
pub type EngineFactory = Arc<dyn Fn() -> Result<Box<dyn InferenceEngine>> + Send + Sync>;

impl InferenceEngine for crate::runtime::ModelRuntime {
    fn batch_size(&self) -> usize {
        crate::runtime::ModelRuntime::batch_size(self)
    }

    fn features_per_row(&self) -> usize {
        crate::runtime::ModelRuntime::features_per_row(self)
    }

    fn outputs_per_row(&self) -> usize {
        crate::runtime::ModelRuntime::outputs_per_row(self)
    }

    fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        crate::runtime::ModelRuntime::infer(self, input)
    }
}

/// A trivial engine for tests and the no-artifacts demo path: output
/// row = `scale ×` mean of the input row, replicated.
pub struct EchoEngine {
    /// Rows per model invocation.
    pub batch: usize,
    /// Features per input row.
    pub features: usize,
    /// Outputs per row (the mean is replicated across them).
    pub outputs: usize,
    /// Multiplier applied to each row's mean.
    pub scale: f32,
}

impl InferenceEngine for EchoEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn features_per_row(&self) -> usize {
        self.features
    }

    fn outputs_per_row(&self) -> usize {
        self.outputs
    }

    fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.batch * self.outputs);
        for row in 0..self.batch {
            let s: f32 =
                input[row * self.features..(row + 1) * self.features].iter().sum();
            let mean = s / self.features as f32;
            out.extend(std::iter::repeat(mean * self.scale).take(self.outputs));
        }
        Ok(out)
    }
}

/// How many queued batches a worker claims per amortized work-queue
/// dequeue (one cursor/frontier RMW pair for the whole run).
const WORK_POP_BATCH: usize = 4;

/// Longest single park on the empty work queue. A push (or
/// `Server::shutdown`'s explicit wake) ends the park immediately; the
/// slice only bounds stop-latency if a wake were ever missed.
const WORKER_PARK: Duration = Duration::from_millis(100);

/// Build an engine through `factory`. Carries the
/// `worker/engine-build` fail point so chaos runs can exercise the
/// supervisor's build-failure path.
pub(crate) fn build_engine(factory: &EngineFactory) -> Result<Box<dyn InferenceEngine>> {
    crate::fail_point!(
        "worker/engine-build",
        Err(anyhow::anyhow!("injected engine-build failure"))
    );
    factory()
}

/// NACK every request in `batch` with `err` (idempotently — requests
/// already completed are skipped and not double-counted). Shared by
/// the panic paths of worker, batcher and shutdown drain.
pub(crate) fn nack_batch(batch: Batch, metrics: &Metrics, err: InferError) {
    for req in batch.requests {
        let latency = req.submitted_at.elapsed();
        if req
            .slot
            .complete(InferResponse::nack(req.id, latency, err.clone()))
        {
            metrics.record_nack(latency);
        }
    }
}

/// Run `batch` under `catch_unwind`: on panic, every request in the
/// batch that the engine had not already answered is NACKed with
/// [`InferError::WorkerPanicked`], then the payload is returned so the
/// caller decides whether to respawn (supervised) or propagate
/// (unsupervised). A claimed request never strands behind a panic
/// boundary (DESIGN.md §11).
pub(crate) fn run_batch_protected(
    engine: &dyn InferenceEngine,
    batch: Batch,
    metrics: &Metrics,
    sup: Option<(&Supervision, usize)>,
) -> std::result::Result<(), Box<dyn std::any::Any + Send>> {
    let meta: Vec<(u64, Instant, Arc<ResponseSlot>)> = batch
        .requests
        .iter()
        .map(|r| (r.id, r.submitted_at, r.slot.clone()))
        .collect();
    match catch_unwind(AssertUnwindSafe(|| run_batch(engine, batch, metrics, sup))) {
        Ok(()) => Ok(()),
        Err(payload) => {
            for (id, submitted_at, slot) in meta {
                let latency = submitted_at.elapsed();
                if slot.complete(InferResponse::nack(id, latency, InferError::WorkerPanicked)) {
                    metrics.record_nack(latency);
                }
            }
            Err(payload)
        }
    }
}

/// Drain claimed batches one at a time, stamping a heartbeat (when
/// supervised) before each so a long multi-batch drain does not read
/// as a stall; on a panic inside any batch, NACK every *other*
/// still-claimed batch and re-raise the panic — the claims die with
/// the worker pass, but the requests do not.
fn drain_inbox(
    inbox: &mut Vec<Batch>,
    engine: &dyn InferenceEngine,
    metrics: &Metrics,
    sup: Option<(&Supervision, usize)>,
) {
    while !inbox.is_empty() {
        if let Some((s, i)) = sup {
            s.beat(i);
        }
        let batch = inbox.remove(0);
        if let Err(payload) = run_batch_protected(engine, batch, metrics, sup) {
            for rest in inbox.drain(..) {
                nack_batch(rest, metrics, InferError::WorkerPanicked);
            }
            std::panic::resume_unwind(payload);
        }
    }
}

/// The consume loop shared by the supervised and unsupervised workers:
/// claim batches until `stop` is set and the queue is empty, stamping a
/// heartbeat (when supervised) every iteration — the park slice bounds
/// the idle beat interval to [`WORKER_PARK`], well inside the default
/// stall threshold. Under load the beat also lands between claimed
/// batches and between model-batch chunks (see `drain_inbox` /
/// `run_batch`), so only a *single engine invocation* longer than
/// `stall_after` reads as a stall — which is exactly the wedged-engine
/// condition the gauge exists to catch.
///
/// Panics propagate out of this function *after* every claimed request
/// has been NACKed (see [`run_batch_protected`]).
pub(crate) fn worker_core(
    work: &WorkQueue,
    engine: &dyn InferenceEngine,
    metrics: &Metrics,
    stop: &AtomicBool,
    sup: Option<(&Supervision, usize)>,
) {
    let mut inbox: Vec<Batch> = Vec::with_capacity(WORK_POP_BATCH);
    let mut idle = Backoff::new();
    loop {
        if let Some((s, i)) = sup {
            s.beat(i);
        }
        if work.pop_batch_into(WORK_POP_BATCH, &mut inbox) > 0 {
            idle.reset();
            drain_inbox(&mut inbox, engine, metrics, sup);
        } else if stop.load(Ordering::Acquire) {
            // Re-probe once after observing `stop`: anything claimed
            // here must still be processed before exiting.
            if work.pop_batch_into(1, &mut inbox) == 0 {
                return;
            }
            drain_inbox(&mut inbox, engine, metrics, sup);
        } else if idle.is_yielding() {
            // Park (lost-wakeup-safe): a push wakes us at once; the
            // deadline keeps `stop` observed within WORKER_PARK.
            let deadline = Instant::now() + WORKER_PARK;
            if work.pop_deadline_batch(WORK_POP_BATCH, &mut inbox, deadline) > 0 {
                idle.reset();
                drain_inbox(&mut inbox, engine, metrics, sup);
            }
        } else {
            idle.spin();
        }
    }
}

/// Worker loop: consume batches until `stop` is set and the queue is
/// empty. Oversized batches (more requests than the model batch) are
/// split into multiple invocations; undersized ones are zero-padded.
/// Queued batches are claimed [`WORK_POP_BATCH`] at a time through the
/// CMP batch-dequeue path.
///
/// The empty-queue path escalates through [`Backoff`] (spin → yield)
/// and, once [`Backoff::is_yielding`] reports the spin budget spent,
/// parks on the work queue's eventcount (DESIGN.md §8) — an idle worker
/// fleet sleeps in the kernel instead of burning cores.
///
/// This is the *unsupervised* entry point: an engine panic still NACKs
/// every claimed request first, but then propagates and kills the
/// thread. [`crate::coordinator::supervisor::supervised_worker_loop`]
/// wraps the same core with catch-and-respawn.
pub fn worker_loop(
    work: WorkQueue,
    factory: EngineFactory,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let engine = build_engine(&factory).expect("engine construction failed");
    worker_core(&work, &*engine, &metrics, &stop, None);
}

/// Async worker host (DESIGN.md §10): multiplex `tasks` worker tasks
/// over *one* OS thread with a round-robin [`Executor`], instead of
/// one thread per worker. Each task owns its own engine (PJRT
/// executables are not `Send`; all tasks live on this thread) and
/// pulls work with [`crate::queue::cmp::CmpQueue::pop_deadline_async`]
/// — a pending task costs no CPU, a push wakes it through its
/// registered waker, and the bounded deadline slice keeps `stop`
/// observed within [`WORKER_PARK`] even if no work ever arrives. Each
/// awaited claim is followed by one amortized [`WORK_POP_BATCH`]-wide
/// batch dequeue, so a loaded queue pays the same per-run RMW cost as
/// the thread loop.
///
/// Returns when `stop` is set and the queue is drained (same
/// drain-then-exit contract as [`worker_loop`]). Inference itself runs
/// synchronously inside the task — the executor interleaves tasks at
/// their await points, so this mode trades per-batch parallelism for
/// an N× smaller idle thread fleet; size `tasks` accordingly.
pub fn async_worker_loop(
    work: WorkQueue,
    factory: EngineFactory,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    tasks: usize,
    sup: Arc<Supervision>,
) {
    let mut ex = Executor::new();
    for t in 0..tasks.max(1) {
        let work = work.clone();
        let factory = factory.clone();
        let metrics = metrics.clone();
        let stop = stop.clone();
        let sup = sup.clone();
        ex.spawn(async move {
            // `engine` is None whenever the previous one is suspect
            // (mid-batch panic) or not yet built; the loop head
            // rebuilds it under the same restart budget the threaded
            // supervisor uses, backing off via the shared timer so the
            // other tasks on this executor keep running.
            let mut engine: Option<Box<dyn InferenceEngine>> = None;
            let mut inbox: Vec<Batch> = Vec::with_capacity(WORK_POP_BATCH);
            loop {
                if engine.is_none() {
                    match catch_unwind(AssertUnwindSafe(|| build_engine(&factory))) {
                        Ok(Ok(e)) => {
                            engine = Some(e);
                            sup.set_state(t, WorkerState::Running);
                        }
                        Ok(Err(e)) => {
                            eprintln!("async worker {t}: engine construction failed: {e:#}");
                            if !async_respawn_gate(t, &sup, &metrics, &stop).await {
                                return;
                            }
                            continue;
                        }
                        Err(_) => {
                            metrics.record_worker_panic();
                            if !async_respawn_gate(t, &sup, &metrics, &stop).await {
                                return;
                            }
                            continue;
                        }
                    }
                }
                let eng = engine.take().expect("built above");
                sup.beat(t);
                let deadline = Instant::now() + WORKER_PARK;
                match work.pop_deadline_async(deadline).await {
                    Some(batch) => {
                        // Amortized follow-up, as in `worker_loop`:
                        // claim a run of the remaining queued batches
                        // with one cursor/frontier RMW pair instead of
                        // one awaited dequeue each.
                        work.pop_batch_into(WORK_POP_BATCH - 1, &mut inbox);
                        inbox.insert(0, batch);
                        let mut panicked = false;
                        while !inbox.is_empty() {
                            sup.beat(t);
                            let b = inbox.remove(0);
                            if run_batch_protected(&*eng, b, &metrics, Some((sup.as_ref(), t)))
                                .is_err()
                            {
                                // NACK the rest of the claim and drop
                                // the suspect engine; the loop head
                                // rebuilds (or gives up at the cap).
                                for rest in inbox.drain(..) {
                                    nack_batch(rest, &metrics, InferError::WorkerPanicked);
                                }
                                metrics.record_worker_panic();
                                panicked = true;
                                break;
                            }
                        }
                        if panicked {
                            if !async_respawn_gate(t, &sup, &metrics, &stop).await {
                                return;
                            }
                        } else {
                            engine = Some(eng);
                        }
                    }
                    None => {
                        if stop.load(Ordering::Acquire) {
                            // Re-probe once after observing `stop`:
                            // anything claimed here must still be
                            // processed before exiting.
                            match work.pop() {
                                Some(batch) => {
                                    if run_batch_protected(
                                        &*eng,
                                        batch,
                                        &metrics,
                                        Some((sup.as_ref(), t)),
                                    )
                                    .is_err()
                                    {
                                        // Shutting down anyway: the
                                        // requests were NACKed; the
                                        // residual drain owns the rest.
                                        metrics.record_worker_panic();
                                        sup.set_state(t, WorkerState::Exited);
                                        return;
                                    }
                                    engine = Some(eng);
                                }
                                None => {
                                    sup.set_state(t, WorkerState::Exited);
                                    return;
                                }
                            }
                        } else {
                            engine = Some(eng);
                        }
                    }
                }
            }
        });
    }
    ex.run();
}

/// Restart bookkeeping shared by the async task's failure paths
/// (engine-build failure and mid-batch panic). Returns `false` when
/// the task must exit — `stop` was set, or the restart cap was hit
/// (slot marked Dead, server degraded); on `true` the caller re-enters
/// its build path after an awaited exponential backoff.
async fn async_respawn_gate(
    t: usize,
    sup: &Supervision,
    metrics: &Metrics,
    stop: &AtomicBool,
) -> bool {
    if stop.load(Ordering::Acquire) {
        sup.set_state(t, WorkerState::Exited);
        return false;
    }
    let n = sup.note_restart(t);
    if n > sup.policy().max_restarts as u64 {
        sup.set_state(t, WorkerState::Dead);
        metrics.record_worker_dead();
        eprintln!(
            "async worker {t}: abandoned after {} restarts — server degraded",
            n - 1
        );
        return false;
    }
    metrics.record_worker_restart();
    sup.set_state(t, WorkerState::Starting);
    sleep_until(Instant::now() + restart_backoff(sup.policy(), n)).await;
    true
}

fn run_batch(
    engine: &dyn InferenceEngine,
    batch: Batch,
    metrics: &Metrics,
    sup: Option<(&Supervision, usize)>,
) {
    let cap = engine.batch_size();
    let fpr = engine.features_per_row();
    let opr = engine.outputs_per_row();

    // Deadline triage before paying any engine cost: expired requests
    // are NACKed here (the cheapest point past the queue) and the rest
    // proceed.
    let now = Instant::now();
    let mut live = Vec::with_capacity(batch.requests.len());
    for req in batch.requests {
        if req.expired(now) {
            let latency = req.submitted_at.elapsed();
            if req.slot.complete(InferResponse::nack(
                req.id,
                latency,
                InferError::DeadlineExceeded,
            )) {
                metrics.record_deadline_nack(latency);
            }
        } else {
            live.push(req);
        }
    }

    for chunk in live.chunks(cap) {
        // Beat per model invocation: an oversized batch split into many
        // chunks stays visibly alive; only one `infer` call exceeding
        // `stall_after` can trip the stall gauge.
        if let Some((s, i)) = sup {
            s.beat(i);
        }
        crate::fail_point!("worker/pre-infer");
        let mut input = vec![0.0f32; cap * fpr];
        for (row, req) in chunk.iter().enumerate() {
            let n = req.features.len().min(fpr);
            input[row * fpr..row * fpr + n].copy_from_slice(&req.features[..n]);
        }
        metrics.record_batch(chunk.len(), cap);
        match engine.infer(&input) {
            Ok(out) => {
                for (row, req) in chunk.iter().enumerate() {
                    let latency = req.submitted_at.elapsed();
                    req.slot.complete(InferResponse {
                        id: req.id,
                        output: out[row * opr..(row + 1) * opr].to_vec(),
                        latency,
                        batch_size: chunk.len(),
                        error: None,
                    });
                    metrics.record_complete(latency, true);
                }
            }
            Err(e) => {
                eprintln!("worker: inference failed: {e:#}");
                for req in chunk {
                    let latency = req.submitted_at.elapsed();
                    req.slot.complete(InferResponse {
                        id: req.id,
                        output: Vec::new(),
                        latency,
                        batch_size: chunk.len(),
                        error: Some(InferError::Engine(format!("{e:#}"))),
                    });
                    metrics.record_complete(latency, false);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::new_work_queue;
    use crate::coordinator::request::{InferRequest, ResponseSlot};
    use std::time::Instant;

    fn echo_factory() -> EngineFactory {
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 4,
                features: 2,
                outputs: 3,
                scale: 10.0,
            }) as Box<dyn InferenceEngine>)
        })
    }

    fn req(id: u64, f: Vec<f32>) -> (InferRequest, Arc<ResponseSlot>) {
        let slot = ResponseSlot::new();
        (
            InferRequest {
                id,
                tenant: 0,
                features: f,
                submitted_at: Instant::now(),
                deadline: None,
                slot: slot.clone(),
            },
            slot,
        )
    }

    #[test]
    fn worker_completes_requests_with_engine_output() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let (w, m, s) = (work.clone(), metrics.clone(), stop.clone());
            std::thread::spawn(move || worker_loop(w, echo_factory(), m, s))
        };
        let (r1, s1) = req(1, vec![1.0, 3.0]); // mean 2 → 20
        let (r2, s2) = req(2, vec![4.0, 6.0]); // mean 5 → 50
        work.push(Batch {
            requests: vec![r1, r2],
            formed_at: Instant::now(),
        })
        .ok()
        .unwrap();
        let o1 = s1.wait();
        let o2 = s2.wait();
        assert_eq!(o1.output, vec![20.0, 20.0, 20.0]);
        assert_eq!(o2.output, vec![50.0, 50.0, 50.0]);
        assert_eq!(o1.batch_size, 2);
        stop.store(true, Ordering::Release);
        h.join().unwrap();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
        assert!(metrics.padding_ratio() > 0.0, "2 real rows in a 4-batch");
    }

    #[test]
    fn oversized_batch_is_split() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let (w, m, s) = (work.clone(), metrics.clone(), stop.clone());
            std::thread::spawn(move || worker_loop(w, echo_factory(), m, s))
        };
        let mut slots = Vec::new();
        let mut requests = Vec::new();
        for i in 0..10 {
            let (r, s) = req(i, vec![i as f32, i as f32]);
            requests.push(r);
            slots.push(s);
        }
        work.push(Batch {
            requests,
            formed_at: Instant::now(),
        })
        .ok()
        .unwrap();
        for (i, s) in slots.iter().enumerate() {
            let o = s.wait();
            assert_eq!(o.output[0], i as f32 * 10.0);
        }
        stop.store(true, Ordering::Release);
        h.join().unwrap();
        // 10 requests with engine batch 4 → 3 model invocations.
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn async_worker_loop_completes_requests() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let sup = Arc::new(Supervision::new(3, Default::default()));
        let h = {
            let (w, m, s, sv) = (work.clone(), metrics.clone(), stop.clone(), sup.clone());
            // 3 worker tasks multiplexed over one host thread.
            std::thread::spawn(move || async_worker_loop(w, echo_factory(), m, s, 3, sv))
        };
        let mut slots = Vec::new();
        for i in 0..6 {
            let (r, s) = req(i, vec![i as f32, i as f32]);
            work.push(Batch {
                requests: vec![r],
                formed_at: Instant::now(),
            })
            .ok()
            .unwrap();
            slots.push(s);
        }
        for (i, s) in slots.iter().enumerate() {
            assert_eq!(s.wait().output[0], i as f32 * 10.0);
        }
        stop.store(true, Ordering::Release);
        // Tasks observe `stop` within one WORKER_PARK slice (the same
        // bound as the thread loop); the wake is just a nudge.
        work.wake_consumers();
        h.join().unwrap();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 6);
    }

    /// Engine that panics on every `infer` call.
    struct PanickingEngine;

    impl InferenceEngine for PanickingEngine {
        fn batch_size(&self) -> usize {
            4
        }
        fn features_per_row(&self) -> usize {
            2
        }
        fn outputs_per_row(&self) -> usize {
            1
        }
        fn infer(&self, _input: &[f32]) -> Result<Vec<f32>> {
            panic!("engine exploded");
        }
    }

    #[test]
    fn panicking_engine_nacks_every_claimed_request() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let (w, m, s) = (work.clone(), metrics.clone(), stop.clone());
            let factory: EngineFactory =
                Arc::new(|| Ok(Box::new(PanickingEngine) as Box<dyn InferenceEngine>));
            std::thread::spawn(move || worker_loop(w, factory, m, s))
        };
        let (r1, s1) = req(1, vec![1.0, 1.0]);
        let (r2, s2) = req(2, vec![2.0, 2.0]);
        work.push(Batch {
            requests: vec![r1, r2],
            formed_at: Instant::now(),
        })
        .ok()
        .unwrap();
        // Both slots must resolve as NACKs, not strand.
        let o1 = s1.wait_timeout(Duration::from_secs(30)).expect("nack, not strand");
        let o2 = s2.wait_timeout(Duration::from_secs(30)).expect("nack, not strand");
        assert_eq!(o1.error, Some(InferError::WorkerPanicked));
        assert_eq!(o2.error, Some(InferError::WorkerPanicked));
        assert!(o1.output.is_empty());
        // Unsupervised loop: the panic propagates after the NACKs.
        assert!(h.join().is_err(), "worker_loop re-raises the panic");
        assert_eq!(metrics.nacks.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 2, "conservation");
        stop.store(true, Ordering::Release);
    }

    #[test]
    fn expired_deadlines_are_nacked_before_inference() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let (w, m, s) = (work.clone(), metrics.clone(), stop.clone());
            std::thread::spawn(move || worker_loop(w, echo_factory(), m, s))
        };
        let (mut r1, s1) = req(1, vec![6.0, 6.0]);
        r1.deadline = Some(Instant::now() - Duration::from_millis(1)); // already past
        let (r2, s2) = req(2, vec![4.0, 4.0]);
        work.push(Batch {
            requests: vec![r1, r2],
            formed_at: Instant::now(),
        })
        .ok()
        .unwrap();
        let o1 = s1.wait_timeout(Duration::from_secs(30)).expect("resolved");
        let o2 = s2.wait_timeout(Duration::from_secs(30)).expect("resolved");
        assert_eq!(o1.error, Some(InferError::DeadlineExceeded));
        assert_eq!(o2.output, vec![40.0, 40.0, 40.0], "live request still served");
        stop.store(true, Ordering::Release);
        h.join().unwrap();
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn short_feature_rows_are_zero_padded() {
        let work = new_work_queue();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let (w, m, s) = (work.clone(), metrics.clone(), stop.clone());
            std::thread::spawn(move || worker_loop(w, echo_factory(), m, s))
        };
        let (r, s) = req(1, vec![8.0]); // one of two features → mean 4
        work.push(Batch {
            requests: vec![r],
            formed_at: Instant::now(),
        })
        .ok()
        .unwrap();
        assert_eq!(s.wait().output[0], 40.0);
        stop.store(true, Ordering::Release);
        h.join().unwrap();
    }
}
