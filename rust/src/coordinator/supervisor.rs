//! Worker supervision: respawn panicked workers with exponential
//! backoff, cap restarts, detect wedged (non-panicking) workers through
//! heartbeats, and surface all of it via [`Metrics`].
//!
//! The design mirrors the paper's "protection paradox" argument
//! (§2.3.1/§3.6) one layer up: the CMP queue already tolerates crashed
//! or stalled *participants* with bounded retention, so the coordinator
//! must tolerate crashed or stalled *workers* without stranding
//! requests. Two rules make that composable (DESIGN.md §11):
//!
//! 1. **No claim is held across a panic boundary.** A worker claims
//!    batches from the work queue, and every claimed request is either
//!    answered or NACKed before the panic propagates to the supervisor
//!    — the queue-layer protection window never has to cover a dead
//!    coordinator thread.
//! 2. **Restarts are bounded.** A persistently-crashing worker (bad
//!    engine, poisoned input pattern) is abandoned after
//!    [`SupervisorPolicy::max_restarts`] attempts and the server enters
//!    a *degraded* mode that is observable ([`Metrics::is_degraded`])
//!    instead of an invisible hot crash-loop.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::WorkQueue;
use super::metrics::Metrics;
use super::worker::{build_engine, worker_core, EngineFactory};

/// Restart and health-monitoring policy for supervised stages.
#[derive(Debug, Clone)]
pub struct SupervisorPolicy {
    /// Respawns allowed per worker before it is abandoned (degraded
    /// mode). The count resets never — a flaky-but-recovering worker
    /// budget, not a rate.
    pub max_restarts: u32,
    /// First restart delay; doubles per consecutive restart.
    pub backoff_base: Duration,
    /// Ceiling on the restart delay.
    pub backoff_cap: Duration,
    /// A Running worker whose last heartbeat is older than this is
    /// reported as stalled (wedged in the engine, not panicked).
    ///
    /// Size this above the worst-case *single* engine invocation:
    /// workers beat between claimed batches and between model-batch
    /// chunks, but cannot beat inside `InferenceEngine::infer`, so one
    /// legitimate inference longer than this reads as a (transient)
    /// stall — the gauge clears on the next beat. In async worker mode
    /// every task shares one host thread, so one task wedged in its
    /// engine stalls the *other* tasks' beats too and the gauge can
    /// briefly report the whole fleet.
    pub stall_after: Duration,
    /// How often the monitor thread re-evaluates heartbeats.
    pub monitor_period: Duration,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        SupervisorPolicy {
            max_restarts: 8,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(100),
            stall_after: Duration::from_secs(1),
            monitor_period: Duration::from_millis(20),
        }
    }
}

/// Lifecycle of one supervised worker slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum WorkerState {
    /// Spawned, engine not yet built.
    Starting = 0,
    /// In the consume loop, heartbeating.
    Running = 1,
    /// Returned cleanly (stop observed, queue drained).
    Exited = 2,
    /// Abandoned after exhausting the restart cap.
    Dead = 3,
}

/// Health record for one worker slot; all fields are written by the
/// worker/supervisor and read by the monitor, so everything is atomic.
struct WorkerHealth {
    /// Milliseconds since [`Supervision::epoch`] of the last beat,
    /// plus 1 so that 0 means "never beat".
    heartbeat_ms: AtomicU64,
    restarts: AtomicU64,
    state: AtomicU8,
}

/// Shared supervision state: one [`WorkerHealth`] per worker slot plus
/// the policy. Owned by the server, shared with worker threads and the
/// monitor.
pub struct Supervision {
    epoch: Instant,
    policy: SupervisorPolicy,
    workers: Vec<WorkerHealth>,
}

impl Supervision {
    /// Supervision state for `n` worker slots.
    pub fn new(n: usize, policy: SupervisorPolicy) -> Self {
        Supervision {
            epoch: Instant::now(),
            policy,
            workers: (0..n)
                .map(|_| WorkerHealth {
                    heartbeat_ms: AtomicU64::new(0),
                    restarts: AtomicU64::new(0),
                    state: AtomicU8::new(WorkerState::Starting as u8),
                })
                .collect(),
        }
    }

    /// The restart/stall policy in force.
    pub fn policy(&self) -> &SupervisorPolicy {
        &self.policy
    }

    /// Number of supervised worker slots.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Stamp worker `i`'s heartbeat (called every loop iteration; the
    /// park slice bounds the beat interval well under `stall_after`).
    pub fn beat(&self, i: usize) {
        let ms = self.epoch.elapsed().as_millis() as u64 + 1;
        self.workers[i].heartbeat_ms.store(ms, Ordering::Relaxed);
    }

    /// Worker `i`'s lifecycle state.
    pub fn state(&self, i: usize) -> WorkerState {
        match self.workers[i].state.load(Ordering::Relaxed) {
            0 => WorkerState::Starting,
            1 => WorkerState::Running,
            2 => WorkerState::Exited,
            _ => WorkerState::Dead,
        }
    }

    /// Set worker `i`'s lifecycle state.
    pub fn set_state(&self, i: usize, s: WorkerState) {
        self.workers[i].state.store(s as u8, Ordering::Relaxed);
    }

    /// Count a respawn of worker `i`; returns the new total.
    pub fn note_restart(&self, i: usize) -> u64 {
        self.workers[i].restarts.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Respawns of worker `i` so far.
    pub fn restarts(&self, i: usize) -> u64 {
        self.workers[i].restarts.load(Ordering::Relaxed)
    }

    /// Workers abandoned past the restart cap.
    pub fn dead_count(&self) -> u64 {
        self.workers
            .iter()
            .filter(|w| w.state.load(Ordering::Relaxed) == WorkerState::Dead as u8)
            .count() as u64
    }

    /// Running workers whose heartbeat is older than
    /// [`SupervisorPolicy::stall_after`] — wedged, not panicked.
    pub fn stalled(&self) -> u64 {
        let now_ms = self.epoch.elapsed().as_millis() as u64 + 1;
        let limit = self.policy.stall_after.as_millis() as u64;
        self.workers
            .iter()
            .filter(|w| {
                let beat = w.heartbeat_ms.load(Ordering::Relaxed);
                w.state.load(Ordering::Relaxed) == WorkerState::Running as u8
                    && beat != 0
                    && now_ms.saturating_sub(beat) > limit
            })
            .count() as u64
    }
}

/// Backoff before restart attempt `attempt` (1-based): `base × 2^(n−1)`
/// capped at `backoff_cap`.
pub(crate) fn restart_backoff(policy: &SupervisorPolicy, attempt: u64) -> Duration {
    let shift = (attempt.saturating_sub(1)).min(16) as u32;
    policy
        .backoff_base
        .saturating_mul(1u32 << shift)
        .min(policy.backoff_cap)
}

/// Sleep up to `dur`, in slices, returning early once `stop` is set —
/// a backing-off supervisor must not delay shutdown.
pub(crate) fn sleep_observing_stop(dur: Duration, stop: &AtomicBool) {
    const SLICE: Duration = Duration::from_millis(5);
    let deadline = Instant::now() + dur;
    while !stop.load(Ordering::Acquire) {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        std::thread::sleep((deadline - now).min(SLICE));
    }
}

/// Supervised worker slot `idx`: run the worker loop under
/// `catch_unwind`, respawning on panic (and on engine-build failure)
/// with exponential backoff until the restart cap is hit, at which
/// point the slot is marked [`WorkerState::Dead`] and the server
/// degrades. Claimed requests are NACKed *inside* the worker core
/// before the panic reaches this frame (rule 1 above), so respawning
/// never races a stranded slot.
pub fn supervised_worker_loop(
    idx: usize,
    work: WorkQueue,
    factory: EngineFactory,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    sup: Arc<Supervision>,
) {
    loop {
        let attempt = catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<()> {
            let engine = build_engine(&factory)?;
            sup.set_state(idx, WorkerState::Running);
            sup.beat(idx);
            worker_core(&work, &*engine, &metrics, &stop, Some((&sup, idx)));
            Ok(())
        }));
        match attempt {
            Ok(Ok(())) => {
                sup.set_state(idx, WorkerState::Exited);
                return;
            }
            Ok(Err(e)) => {
                eprintln!("worker {idx}: engine construction failed: {e:#}");
            }
            Err(_) => {
                metrics.record_worker_panic();
            }
        }
        if stop.load(Ordering::Acquire) {
            // Shutdown is in progress; the residual drain NACKs
            // whatever this worker would have claimed.
            sup.set_state(idx, WorkerState::Exited);
            return;
        }
        let restarts = sup.note_restart(idx);
        if restarts > sup.policy().max_restarts as u64 {
            sup.set_state(idx, WorkerState::Dead);
            metrics.record_worker_dead();
            eprintln!(
                "worker {idx}: abandoned after {} restarts — server degraded",
                restarts - 1
            );
            return;
        }
        metrics.record_worker_restart();
        sup.set_state(idx, WorkerState::Starting);
        sleep_observing_stop(restart_backoff(sup.policy(), restarts), &stop);
    }
}

/// Monitor thread: periodically publish the wedged-worker count to the
/// [`Metrics::workers_stalled`] gauge until `stop` is set.
pub fn monitor_loop(sup: Arc<Supervision>, metrics: Arc<Metrics>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        metrics.set_stalled(sup.stalled());
        sleep_observing_stop(sup.policy().monitor_period, &stop);
    }
    metrics.set_stalled(sup.stalled());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = SupervisorPolicy {
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(10),
            ..SupervisorPolicy::default()
        };
        assert_eq!(restart_backoff(&p, 1), Duration::from_millis(1));
        assert_eq!(restart_backoff(&p, 2), Duration::from_millis(2));
        assert_eq!(restart_backoff(&p, 3), Duration::from_millis(4));
        assert_eq!(restart_backoff(&p, 4), Duration::from_millis(8));
        assert_eq!(restart_backoff(&p, 5), Duration::from_millis(10), "capped");
        assert_eq!(restart_backoff(&p, 60), Duration::from_millis(10), "shift clamped");
    }

    #[test]
    fn sleep_observing_stop_exits_early() {
        let stop = AtomicBool::new(false);
        let t0 = Instant::now();
        sleep_observing_stop(Duration::from_millis(5), &stop);
        assert!(t0.elapsed() >= Duration::from_millis(5));
        stop.store(true, Ordering::Release);
        let t1 = Instant::now();
        sleep_observing_stop(Duration::from_secs(10), &stop);
        assert!(t1.elapsed() < Duration::from_secs(1), "stop short-circuits");
    }

    #[test]
    fn state_machine_round_trips() {
        let sup = Supervision::new(2, SupervisorPolicy::default());
        assert_eq!(sup.worker_count(), 2);
        assert_eq!(sup.state(0), WorkerState::Starting);
        sup.set_state(0, WorkerState::Running);
        assert_eq!(sup.state(0), WorkerState::Running);
        sup.set_state(0, WorkerState::Dead);
        sup.set_state(1, WorkerState::Exited);
        assert_eq!(sup.dead_count(), 1);
        assert_eq!(sup.note_restart(1), 1);
        assert_eq!(sup.note_restart(1), 2);
        assert_eq!(sup.restarts(1), 2);
        assert_eq!(sup.restarts(0), 0);
    }

    #[test]
    fn stall_detection_needs_running_and_old_beat() {
        let sup = Supervision::new(
            1,
            SupervisorPolicy {
                stall_after: Duration::from_millis(20),
                ..SupervisorPolicy::default()
            },
        );
        // Never beat → not stalled even when Running.
        sup.set_state(0, WorkerState::Running);
        assert_eq!(sup.stalled(), 0);
        sup.beat(0);
        assert_eq!(sup.stalled(), 0, "fresh beat");
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(sup.stalled(), 1, "beat aged past stall_after");
        sup.beat(0);
        assert_eq!(sup.stalled(), 0, "recovered");
        std::thread::sleep(Duration::from_millis(40));
        sup.set_state(0, WorkerState::Exited);
        assert_eq!(sup.stalled(), 0, "only Running workers count");
    }
}
