//! PJRT model runtime: load an AOT HLO-text artifact, compile it once
//! on the CPU PJRT client, execute batches from the Rust hot path.
//!
//! `PjRtLoadedExecutable` is not `Send` (raw PJRT handles), so each
//! worker thread constructs its own `ModelRuntime` (see
//! [`crate::coordinator::worker`]'s engine factory). Compilation cost
//! is paid once per worker at startup, never per request.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A compiled model artifact ready for execution.
///
/// Only available with the `pjrt` cargo feature (which expects a
/// vendored `xla` crate); without it a stub with the same API is
/// compiled whose `load*` constructors report the feature as missing,
/// so the serving pipeline, CLI, and tests build everywhere and the
/// artifact-gated tests skip exactly as they do on a fresh checkout.
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    exe: xla::PjRtLoadedExecutable,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Load an HLO-text artifact with explicit shapes.
    pub fn load(hlo_path: &Path, input_shape: Vec<usize>, output_shape: Vec<usize>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(ModelRuntime {
            exe,
            input_shape,
            output_shape,
        })
    }

    /// Load the serving model described by `artifacts/meta.json`.
    pub fn load_from_artifacts(dir: &Path) -> Result<Self> {
        let meta = Meta::load(dir)?;
        Self::load(
            &meta.model_path,
            meta.model_input_shape,
            meta.model_output_shape,
        )
    }

    /// Elements per input batch.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Elements per output batch.
    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Model batch size (leading input dimension).
    pub fn batch_size(&self) -> usize {
        self.input_shape[0]
    }

    /// Per-row feature width.
    pub fn features_per_row(&self) -> usize {
        self.input_len() / self.batch_size()
    }

    /// Per-row output width.
    pub fn outputs_per_row(&self) -> usize {
        self.output_len() / self.batch_size()
    }

    /// Input tensor shape (leading dimension = batch).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Output tensor shape (leading dimension = batch).
    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Execute one batch: `input.len()` must equal [`Self::input_len`].
    /// Returns the flattened output tensor.
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        if input.len() != self.input_len() {
            bail!(
                "input length {} != expected {} (shape {:?})",
                input.len(),
                self.input_len(),
                self.input_shape
            );
        }
        let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("PJRT execute")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching output buffer")?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = out.to_tuple1().context("untupling output")?;
        let v = out.to_vec::<f32>().context("reading output literal")?;
        if v.len() != self.output_len() {
            bail!(
                "output length {} != expected {} (shape {:?})",
                v.len(),
                self.output_len(),
                self.output_shape
            );
        }
        Ok(v)
    }
}

/// Stub runtime for builds without the `pjrt` feature: identical
/// surface, but construction always fails. Never instantiated, so the
/// execution methods are unreachable by construction.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(
        _hlo_path: &Path,
        _input_shape: Vec<usize>,
        _output_shape: Vec<usize>,
    ) -> Result<Self> {
        bail!("cmpq was built without the `pjrt` feature; the PJRT runtime is unavailable")
    }

    /// Always fails (after validating that `meta.json` parses, so
    /// configuration errors surface first).
    pub fn load_from_artifacts(dir: &Path) -> Result<Self> {
        let _ = Meta::load(dir)?;
        bail!("cmpq was built without the `pjrt` feature; the PJRT runtime is unavailable")
    }

    /// Elements per input batch.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Elements per output batch.
    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Model batch size (leading input dimension).
    pub fn batch_size(&self) -> usize {
        self.input_shape[0]
    }

    /// Per-row feature width.
    pub fn features_per_row(&self) -> usize {
        self.input_len() / self.batch_size()
    }

    /// Per-row output width.
    pub fn outputs_per_row(&self) -> usize {
        self.output_len() / self.batch_size()
    }

    /// Input tensor shape (leading dimension = batch).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Output tensor shape (leading dimension = batch).
    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn infer(&self, _input: &[f32]) -> Result<Vec<f32>> {
        bail!("cmpq was built without the `pjrt` feature; the PJRT runtime is unavailable")
    }
}

/// Parsed `artifacts/meta.json`.
pub struct Meta {
    /// Path to the serving model's HLO-text artifact.
    pub model_path: PathBuf,
    /// Serving model input shape.
    pub model_input_shape: Vec<usize>,
    /// Serving model output shape.
    pub model_output_shape: Vec<usize>,
    /// Path to the synthetic-load kernel's HLO-text artifact.
    pub synthload_path: PathBuf,
    /// Synthetic-load kernel input shape.
    pub synthload_shape: Vec<usize>,
}

impl Meta {
    /// Parse `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let j = Json::parse(&raw).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let model = j.get("model").context("meta.json missing `model`")?;
        let synth = j.get("synthload").context("meta.json missing `synthload`")?;
        let field = |o: &Json, k: &str| -> Result<Vec<usize>> {
            o.get(k)
                .and_then(|v| v.as_usize_vec())
                .with_context(|| format!("meta.json missing {k}"))
        };
        Ok(Meta {
            model_path: dir.join(
                model
                    .get("path")
                    .and_then(|p| p.as_str())
                    .context("model.path")?,
            ),
            model_input_shape: field(model, "input_shape")?,
            model_output_shape: field(model, "output_shape")?,
            synthload_path: dir.join(
                synth
                    .get("path")
                    .and_then(|p| p.as_str())
                    .context("synthload.path")?,
            ),
            synthload_shape: field(synth, "input_shape")?,
        })
    }
}

/// Parsed `artifacts/testvec.json` — seeded input + expected output for
/// the Rust-side end-to-end numerics check.
pub struct TestVectors {
    /// Input tensor shape.
    pub input_shape: Vec<usize>,
    /// Expected output tensor shape.
    pub output_shape: Vec<usize>,
    /// Flattened seeded input.
    pub input: Vec<f32>,
    /// Flattened expected output (from JAX).
    pub expected: Vec<f32>,
    /// Relative tolerance for [`TestVectors::check`].
    pub rtol: f64,
}

impl TestVectors {
    /// Parse `<dir>/testvec.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(dir.join("testvec.json"))
            .with_context(|| format!("reading {}/testvec.json", dir.display()))?;
        let j = Json::parse(&raw).map_err(|e| anyhow::anyhow!("testvec.json: {e}"))?;
        let vecf = |k: &str| -> Result<Vec<f32>> {
            j.get(k)
                .and_then(|v| v.as_f32_vec())
                .with_context(|| format!("testvec.json missing {k}"))
        };
        let vecu = |k: &str| -> Result<Vec<usize>> {
            j.get(k)
                .and_then(|v| v.as_usize_vec())
                .with_context(|| format!("testvec.json missing {k}"))
        };
        Ok(TestVectors {
            input_shape: vecu("input_shape")?,
            output_shape: vecu("output_shape")?,
            input: vecf("input")?,
            expected: vecf("expected")?,
            rtol: j.get("rtol").and_then(|v| v.as_f64()).unwrap_or(1e-4),
        })
    }

    /// Relative-tolerance comparison against `actual`.
    pub fn check(&self, actual: &[f32]) -> Result<()> {
        if actual.len() != self.expected.len() {
            bail!("length mismatch: {} vs {}", actual.len(), self.expected.len());
        }
        for (i, (&a, &e)) in actual.iter().zip(self.expected.iter()).enumerate() {
            let tol = self.rtol * e.abs().max(1.0) as f64;
            if ((a - e).abs() as f64) > tol {
                bail!("mismatch at {i}: got {a}, expected {e} (tol {tol})");
            }
        }
        Ok(())
    }
}

/// Default artifacts directory: `$CMPQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CMPQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime integration tests that need the artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
    // Here: pure parsing logic.

    #[test]
    fn testvec_check_passes_within_tol() {
        let tv = TestVectors {
            input_shape: vec![1, 2],
            output_shape: vec![1, 2],
            input: vec![0.0, 0.0],
            expected: vec![1.0, -2.0],
            rtol: 1e-3,
        };
        tv.check(&[1.0005, -2.001]).unwrap();
        assert!(tv.check(&[1.1, -2.0]).is_err());
        assert!(tv.check(&[1.0]).is_err(), "length mismatch");
    }

    #[test]
    fn artifacts_dir_env_override() {
        // Note: set/remove env var carefully (process-global).
        std::env::set_var("CMPQ_ARTIFACTS", "/tmp/xyz");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/xyz"));
        std::env::remove_var("CMPQ_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }
}
