//! Online control laws for the adaptive runtime plane (DESIGN.md §15).
//!
//! Three fixed knobs become observed feedback loops, all **off by
//! default** behind `CmpConfig::adaptive` (the coordinator derives the
//! batcher's flag from its `ServerConfig::queue_config`, so one switch
//! arms the whole control plane):
//!
//! 1. **Spin vs park** — a per-consumer EWMA of inter-arrival gaps
//!    ([`GapTracker`]) feeds [`spin_budget_for`]: tight gaps keep the
//!    full spin phase (parking would only add wakeup latency), wide
//!    gaps shed spin steps until the consumer parks immediately.
//! 2. **Reclamation probability** — window occupancy feeds
//!    [`reclaim_p_for`]: a near-empty protection window reclaims
//!    eagerly (tight window, small footprint), a hot window backs off
//!    and lets the amortized batch grow (the paper's lazy-reclamation
//!    argument).
//! 3. **Batcher deadline** — observed batch fill feeds
//!    [`flush_wait_for`]: full batches flush on a short deadline
//!    (waiting buys nothing), starved batchers stretch toward the
//!    configured maximum.
//!
//! Every law here is a **pure function** over observed state, kept out
//! of the lock-free fast path: observations happen only on the blocking
//! wait path, inside reclamation passes, and at batch-flush edges, and
//! the resulting decisions are published through plain relaxed atomics
//! ([`QueueAdaptive`]) that hot-path readers sample once per wait.
//! Nothing in this module touches the model-check shims, so enabling
//! adaptivity cannot perturb the §9 enumerated state spaces.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Spin steps the *fixed* path performs before parking
/// (`Backoff::is_yielding` flips after this many `spin()` calls); the
/// adaptive budget ranges over `0..=MAX_SPIN_STEPS`, so a budget of
/// `MAX_SPIN_STEPS` reproduces the fixed schedule exactly.
pub const MAX_SPIN_STEPS: u32 = 7;

/// Inter-arrival gap (ns) at or below which the full spin budget is
/// kept: a wakeup that will arrive within ~4 µs is cheaper to spin for
/// than to park and pay a futex round trip.
pub const FULL_SPIN_GAP_NS: u64 = 4_096;

/// Gap observations are clamped to this (1 s): a consumer waking from a
/// long idle night should re-learn the current regime in a few
/// arrivals, not drag a multi-minute outlier through the EWMA forever.
pub const GAP_CAP_NS: u64 = 1_000_000_000;

/// Smoothing factor for the inter-arrival EWMA: small enough to ride
/// out single stragglers, large enough to flip regimes within ~a dozen
/// arrivals.
pub const GAP_ALPHA: f64 = 0.25;

/// Exponentially weighted moving average with explicit priming: the
/// first observation *becomes* the value (no bias toward a synthetic
/// zero start), every later one folds in with weight `alpha`.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: f64,
    primed: bool,
}

impl Ewma {
    /// A fresh, unprimed estimator. `alpha` is clamped to `(0, 1]`.
    pub fn new(alpha: f64) -> Self {
        Self {
            alpha: alpha.clamp(f64::MIN_POSITIVE, 1.0),
            value: 0.0,
            primed: false,
        }
    }

    /// Fold in one observation and return the updated estimate.
    pub fn observe(&mut self, sample: f64) -> f64 {
        if self.primed {
            self.value += self.alpha * (sample - self.value);
        } else {
            self.value = sample;
            self.primed = true;
        }
        self.value
    }

    /// Current estimate, `None` until the first observation.
    pub fn value(&self) -> Option<f64> {
        self.primed.then_some(self.value)
    }

    /// The smoothing factor this estimator was built with.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

/// Map a smoothed inter-arrival gap to a spin budget (number of
/// `Backoff::spin` steps before parking).
///
/// Monotone non-increasing in the gap: gaps at or below
/// [`FULL_SPIN_GAP_NS`] keep all [`MAX_SPIN_STEPS`] steps, and every
/// doubling beyond it sheds one step, reaching an immediate park
/// (budget 0) at ~64× the full-spin gap (~262 µs). Faster arrivals can
/// therefore never move a consumer *toward* parking — the monotonicity
/// property pinned by `tests/adaptive_control.rs`.
pub fn spin_budget_for(gap_ns: u64) -> u32 {
    if gap_ns <= FULL_SPIN_GAP_NS {
        return MAX_SPIN_STEPS;
    }
    // gap > FULL_SPIN_GAP_NS ⇒ ratio ≥ 1 ⇒ ilog2 well-defined.
    let shed = (gap_ns / FULL_SPIN_GAP_NS).ilog2() + 1;
    MAX_SPIN_STEPS.saturating_sub(shed)
}

/// Occupancy at or below which reclamation runs at its most eager
/// (4× the configured base probability).
pub const RECLAIM_EAGER_OCC: f64 = 0.25;
/// Most-eager multiplier on the base Bernoulli probability.
pub const RECLAIM_MAX_SCALE: f64 = 4.0;
/// Laziest multiplier, reached when the window is fully occupied.
pub const RECLAIM_MIN_SCALE: f64 = 0.25;

/// Map protection-window occupancy (`nodes in use / window`, clamped
/// to `[0, 1]`) to a live reclamation probability.
///
/// Low occupancy ⇒ eager reclamation (up to [`RECLAIM_MAX_SCALE`]× the
/// base `p`, capped at 1.0): the window is mostly slack, so trimming it
/// tight is cheap and keeps the node footprint minimal. High occupancy
/// ⇒ lazy reclamation (down to [`RECLAIM_MIN_SCALE`]×): the queue is
/// hot, passes would find little to free, and the amortized batch
/// should be allowed to grow. Monotone non-increasing in occupancy.
pub fn reclaim_p_for(base_p: f64, occupancy: f64) -> f64 {
    let occ = occupancy.clamp(0.0, 1.0);
    let scale = if occ <= RECLAIM_EAGER_OCC {
        RECLAIM_MAX_SCALE
    } else {
        let t = (occ - RECLAIM_EAGER_OCC) / (1.0 - RECLAIM_EAGER_OCC);
        RECLAIM_MAX_SCALE + t * (RECLAIM_MIN_SCALE - RECLAIM_MAX_SCALE)
    };
    (base_p * scale).clamp(0.0, 1.0)
}

/// Batch fill at which the flush deadline starts shrinking; below it
/// the batcher waits the full configured `max_wait`.
pub const FLUSH_FULL_FILL: f64 = 0.5;
/// Floor on the deadline scale, so a saturated batcher still coalesces
/// a little instead of degenerating to per-item flushes.
pub const FLUSH_MIN_SCALE: f64 = 0.25;

/// Map observed batch fill (`batch len / max_batch`, clamped to
/// `[0, 1]`) to an effective flush deadline.
///
/// Starved batchers (fill below [`FLUSH_FULL_FILL`]) keep the full
/// `max_wait` — waiting is how they coalesce at all. As fill rises the
/// deadline shrinks linearly to [`FLUSH_MIN_SCALE`]` × max_wait`:
/// batches that fill on their own gain nothing from waiting out the
/// clock, so latency is returned to the caller.
pub fn flush_wait_for(max_wait: Duration, fill: f64) -> Duration {
    let f = fill.clamp(0.0, 1.0);
    let scale = if f <= FLUSH_FULL_FILL {
        1.0
    } else {
        let t = (f - FLUSH_FULL_FILL) / (1.0 - FLUSH_FULL_FILL);
        1.0 + t * (FLUSH_MIN_SCALE - 1.0)
    };
    max_wait.mul_f64(scale)
}

/// Per-consumer inter-arrival observer: timestamps successive arrivals
/// and maintains the smoothed gap that drives [`spin_budget_for`].
///
/// Lives in consumer thread-locals — observing an arrival is two
/// subtractions and a multiply, with no shared-state traffic; only the
/// resulting estimate is published (see [`QueueAdaptive::record_gap`]).
#[derive(Debug, Clone)]
pub struct GapTracker {
    last: Option<Instant>,
    ewma: Ewma,
}

impl GapTracker {
    /// A fresh tracker with no arrivals observed.
    pub fn new() -> Self {
        Self {
            last: None,
            ewma: Ewma::new(GAP_ALPHA),
        }
    }

    /// Record an arrival at `now`; returns the updated smoothed gap in
    /// nanoseconds, or `None` for the very first arrival (no gap yet).
    /// Gaps are clamped to [`GAP_CAP_NS`].
    pub fn observe(&mut self, now: Instant) -> Option<u64> {
        let gap = match self.last {
            Some(prev) => {
                let ns = now.saturating_duration_since(prev).as_nanos();
                Some((ns.min(GAP_CAP_NS as u128)) as u64)
            }
            None => None,
        };
        self.last = Some(now);
        gap.map(|g| self.ewma.observe(g as f64) as u64)
    }

    /// Current smoothed gap (ns), `None` until two arrivals were seen.
    pub fn gap_ewma_ns(&self) -> Option<u64> {
        self.ewma.value().map(|v| v as u64)
    }
}

impl Default for GapTracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Monotone id source for [`QueueAdaptive`] instances, letting
/// thread-local [`GapTracker`]s detect that they have been handed a
/// different queue and reset instead of dragging stale gaps across.
static NEXT_ADAPTIVE_ID: AtomicU64 = AtomicU64::new(1);

/// Shared adaptive state of one queue: the latest published decisions,
/// readable from any thread with relaxed loads.
///
/// Deliberately built on raw `std` atomics (never the model-check
/// shims): decisions are advisory gauges, and keeping them invisible
/// to the §9 enumerator leaves the modeled state spaces unchanged.
#[derive(Debug)]
pub struct QueueAdaptive {
    id: u64,
    /// Latest published smoothed inter-arrival gap (ns).
    gap_ewma_ns: AtomicU64,
    /// Latest spin budget derived from the gap (stored widened).
    spin_budget: AtomicU64,
    /// Live reclamation probability, stored as `f64` bits.
    live_p_bits: AtomicU64,
}

impl QueueAdaptive {
    /// Fresh state: full spin budget (optimistic — an unknown regime
    /// spins like the fixed path), live `p` seeded from the configured
    /// base probability.
    pub fn new(base_p: f64) -> Self {
        Self {
            id: NEXT_ADAPTIVE_ID.fetch_add(1, Ordering::Relaxed),
            gap_ewma_ns: AtomicU64::new(0),
            spin_budget: AtomicU64::new(MAX_SPIN_STEPS as u64),
            live_p_bits: AtomicU64::new(base_p.to_bits()),
        }
    }

    /// Process-unique id of this instance (thread-local tracker reset
    /// detection).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Publish a smoothed gap observation and the spin budget derived
    /// from it.
    pub fn record_gap(&self, gap_ewma_ns: u64) {
        self.gap_ewma_ns.store(gap_ewma_ns, Ordering::Relaxed);
        self.spin_budget
            .store(spin_budget_for(gap_ewma_ns) as u64, Ordering::Relaxed);
    }

    /// Current spin budget (steps before parking), in
    /// `0..=`[`MAX_SPIN_STEPS`].
    pub fn spin_budget(&self) -> u32 {
        self.spin_budget.load(Ordering::Relaxed) as u32
    }

    /// Latest published smoothed inter-arrival gap (ns); 0 until a
    /// consumer has published one.
    pub fn gap_ewma_ns(&self) -> u64 {
        self.gap_ewma_ns.load(Ordering::Relaxed)
    }

    /// Publish a new live reclamation probability.
    pub fn set_live_p(&self, p: f64) {
        self.live_p_bits.store(p.to_bits(), Ordering::Relaxed);
    }

    /// Current live reclamation probability.
    pub fn live_p(&self) -> f64 {
        f64::from_bits(self.live_p_bits.load(Ordering::Relaxed))
    }

    /// Coherent-enough snapshot of all published decisions (each field
    /// individually relaxed-loaded; they are independent gauges).
    pub fn snapshot(&self) -> AdaptiveSnapshot {
        AdaptiveSnapshot {
            gap_ewma_ns: self.gap_ewma_ns(),
            spin_budget: self.spin_budget(),
            live_p: self.live_p(),
        }
    }
}

/// Point-in-time view of a queue's published adaptive decisions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveSnapshot {
    /// Smoothed inter-arrival gap (ns); 0 until published.
    pub gap_ewma_ns: u64,
    /// Spin steps a waiter performs before parking.
    pub spin_budget: u32,
    /// Live reclamation Bernoulli probability.
    pub live_p: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_primes_on_first_sample() {
        let mut e = Ewma::new(0.25);
        assert_eq!(e.value(), None);
        assert_eq!(e.observe(100.0), 100.0);
        assert_eq!(e.value(), Some(100.0));
        // Second sample folds with alpha, not a fresh prime.
        assert_eq!(e.observe(200.0), 125.0);
    }

    #[test]
    fn spin_budget_endpoints_and_monotone() {
        assert_eq!(spin_budget_for(0), MAX_SPIN_STEPS);
        assert_eq!(spin_budget_for(FULL_SPIN_GAP_NS), MAX_SPIN_STEPS);
        assert_eq!(spin_budget_for(GAP_CAP_NS), 0);
        let mut prev = spin_budget_for(0);
        for gap in (0..10_000_000u64).step_by(997) {
            let b = spin_budget_for(gap);
            assert!(b <= prev, "budget must not grow with the gap");
            prev = b;
        }
    }

    #[test]
    fn reclaim_p_eager_when_empty_lazy_when_hot() {
        let base = 1.0 / 1024.0;
        assert!((reclaim_p_for(base, 0.0) - base * RECLAIM_MAX_SCALE).abs() < 1e-12);
        assert!((reclaim_p_for(base, 1.0) - base * RECLAIM_MIN_SCALE).abs() < 1e-12);
        // Never escapes [0, 1] even for silly base values.
        assert_eq!(reclaim_p_for(0.9, 0.0), 1.0);
        let mut prev = reclaim_p_for(base, 0.0);
        for i in 0..=100 {
            let p = reclaim_p_for(base, i as f64 / 100.0);
            assert!(p <= prev + 1e-12, "p must not grow with occupancy");
            prev = p;
        }
    }

    #[test]
    fn flush_wait_shrinks_with_fill() {
        let w = Duration::from_millis(2);
        assert_eq!(flush_wait_for(w, 0.0), w);
        assert_eq!(flush_wait_for(w, FLUSH_FULL_FILL), w);
        assert_eq!(flush_wait_for(w, 1.0), w.mul_f64(FLUSH_MIN_SCALE));
        let mut prev = flush_wait_for(w, 0.0);
        for i in 0..=100 {
            let d = flush_wait_for(w, i as f64 / 100.0);
            assert!(d <= prev, "deadline must not grow with fill");
            prev = d;
        }
    }

    #[test]
    fn gap_tracker_caps_and_smooths() {
        let mut t = GapTracker::new();
        let t0 = Instant::now();
        assert_eq!(t.observe(t0), None, "first arrival has no gap");
        let e1 = t.observe(t0 + Duration::from_micros(10)).unwrap();
        assert_eq!(e1, 10_000);
        // A multi-second outlier clamps to the cap instead of poisoning
        // the estimate for minutes.
        let e2 = t.observe(t0 + Duration::from_secs(30)).unwrap();
        assert!(e2 <= 10_000 + (GAP_CAP_NS as f64 * GAP_ALPHA) as u64 + 1);
    }

    #[test]
    fn queue_adaptive_publishes_decisions() {
        let qa = QueueAdaptive::new(1.0 / 512.0);
        assert_eq!(qa.spin_budget(), MAX_SPIN_STEPS, "optimistic start");
        qa.record_gap(GAP_CAP_NS);
        assert_eq!(qa.spin_budget(), 0);
        assert_eq!(qa.gap_ewma_ns(), GAP_CAP_NS);
        qa.set_live_p(0.5);
        let snap = qa.snapshot();
        assert_eq!(snap.spin_budget, 0);
        assert_eq!(snap.live_p, 0.5);
        let other = QueueAdaptive::new(0.1);
        assert_ne!(qa.id(), other.id(), "ids are process-unique");
    }
}
