//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`, HLO text —
//! see DESIGN.md §1 and /opt/xla-example/README.md for why text, not
//! serialized protos), compile once on the CPU PJRT client, execute
//! from the Rust hot path.

pub mod client;

pub use client::{ModelRuntime, TestVectors};
