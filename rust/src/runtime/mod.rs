//! Runtime services: the PJRT model runtime (load AOT artifacts —
//! `artifacts/*.hlo.txt`, HLO text; see DESIGN.md §1 and
//! /opt/xla-example/README.md for why text, not serialized protos —
//! compile once on the CPU PJRT client, execute from the Rust hot
//! path) and the adaptive control plane ([`adaptive`], DESIGN.md §15).

pub mod adaptive;
pub mod client;

pub use client::{ModelRuntime, TestVectors};
