//! Suite runner: round-robin sequencing of implementations (§4: "all
//! experiments were conducted ... with round-robin sequencing of
//! implementations to eliminate bias from CPU thermal throttling and
//! dynamic frequency scaling"), multiple rounds per configuration,
//! 3-sigma filtering of the per-round samples.

use super::latency::LatencySummary;
use super::sigma;
use super::synthetic::LoadProfile;
use super::workload::{latency_trial, throughput_trial, PairConfig, Scenario, TrialConfig};
use crate::queue::Impl;

/// Suite-level options.
#[derive(Debug, Clone)]
pub struct SuiteOptions {
    /// Items per trial (scaled per pair internally if desired).
    pub total_ops: u64,
    /// Measured rounds per (impl, pair) cell.
    pub rounds: usize,
    /// Unmeasured warmup rounds per cell.
    pub warmup_rounds: usize,
    /// Inter-op load profile.
    pub load: LoadProfile,
    /// Bounded-queue capacity hint.
    pub capacity_hint: usize,
    /// Operation batch size for throughput trials (1 = single-op API).
    pub batch_size: usize,
    /// Offered-load scenario for throughput trials (DESIGN.md §8);
    /// latency suites always run closed-loop.
    pub scenario: Scenario,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions {
            total_ops: 100_000,
            rounds: 3,
            warmup_rounds: 1,
            load: LoadProfile::None,
            capacity_hint: 1 << 16,
            batch_size: 1,
            scenario: Scenario::ClosedLoop,
            verbose: false,
        }
    }
}

impl SuiteOptions {
    fn trial_config(&self, pair: PairConfig) -> TrialConfig {
        // Scale total ops down at very high thread counts so a sweep
        // stays tractable on small testbeds (the paper's absolute op
        // counts are not specified; shapes are what matters).
        let threads = (pair.producers + pair.consumers) as u64;
        let scale = if threads >= 64 { 4 } else { 1 };
        TrialConfig {
            total_ops: (self.total_ops / scale).max(1000),
            load: self.load,
            capacity_hint: self.capacity_hint,
            max_samples_per_thread: 200_000,
            batch_size: self.batch_size,
            scenario: self.scenario,
        }
    }
}

/// One cell of the Figure-1 style throughput matrix.
#[derive(Debug, Clone)]
pub struct ThroughputCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Per-round samples (items/sec), pre-filter.
    pub samples: Vec<f64>,
    /// 3-sigma filtered mean.
    pub mean_ips: f64,
    /// Standard deviation of the filtered samples.
    pub std_ips: f64,
    /// Samples removed by the 3-sigma filter.
    pub discarded: usize,
    /// Mean items per CPU-second across rounds (3-sigma filtered); 0
    /// when CPU time was unavailable or below clock resolution.
    pub mean_ops_per_cpu: f64,
    /// Mean CPU utilization across rounds (CPU-seconds per wall-second
    /// per thread, ~1.0 = all cores busy); 0 when unmeasured.
    pub mean_cpu_util: f64,
}

/// Round-robin throughput suite over `impls × pairs`.
pub fn throughput_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
) -> Vec<ThroughputCell> {
    let cells = impls.len() * pairs.len();
    let mut samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    let mut cpu_samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    let mut util_samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    for round in 0..(opts.rounds + opts.warmup_rounds) {
        let measured = round >= opts.warmup_rounds;
        // Round-robin: every impl runs once per round before any impl
        // runs again (thermal fairness per the paper).
        for (pi, &pair) in pairs.iter().enumerate() {
            for (ii, &imp) in impls.iter().enumerate() {
                let cfg = opts.trial_config(pair);
                let t = throughput_trial(imp, pair, &cfg);
                if opts.verbose {
                    eprintln!(
                        "[throughput] round={round} {} {} -> {:.0} items/s{}",
                        pair.label(),
                        imp.name(),
                        t.items_per_sec,
                        if measured { "" } else { " (warmup)" },
                    );
                }
                if measured {
                    samples[pi * impls.len() + ii].push(t.items_per_sec);
                    if let Some(v) = t.ops_per_cpu_sec {
                        cpu_samples[pi * impls.len() + ii].push(v);
                    }
                    if let Some(u) = t.cpu_util {
                        util_samples[pi * impls.len() + ii].push(u);
                    }
                }
            }
        }
    }
    let mut out = Vec::new();
    for (pi, &pair) in pairs.iter().enumerate() {
        for (ii, &imp) in impls.iter().enumerate() {
            let idx = pi * impls.len() + ii;
            let raw = &samples[idx];
            let (kept, discarded) = sigma::three_sigma(raw);
            let (mean, std) = sigma::mean_std(&kept);
            let (cpu_kept, _) = sigma::three_sigma(&cpu_samples[idx]);
            let (mean_ops_per_cpu, _) = sigma::mean_std(&cpu_kept);
            let (util_kept, _) = sigma::three_sigma(&util_samples[idx]);
            let (mean_cpu_util, _) = sigma::mean_std(&util_kept);
            out.push(ThroughputCell {
                imp,
                pair,
                samples: raw.clone(),
                mean_ips: mean,
                std_ips: std,
                discarded,
                mean_ops_per_cpu,
                mean_cpu_util,
            });
        }
    }
    out
}

/// One cell of the Tables 1–3 style latency matrix.
#[derive(Debug, Clone)]
pub struct LatencyCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Enqueue-side latency summary (post-filter).
    pub enqueue: LatencySummary,
    /// Dequeue-side latency summary (post-filter).
    pub dequeue: LatencySummary,
    /// Enqueue samples removed by the 3-sigma filter.
    pub enq_discarded: usize,
    /// Dequeue samples removed by the 3-sigma filter.
    pub deq_discarded: usize,
}

/// Round-robin latency suite. Per-op samples from all rounds are
/// pooled, 3-sigma filtered (the paper's anomaly removal), then
/// summarized.
pub fn latency_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
) -> Vec<LatencyCell> {
    let mut enq: Vec<Vec<u64>> = vec![Vec::new(); impls.len() * pairs.len()];
    let mut deq: Vec<Vec<u64>> = vec![Vec::new(); impls.len() * pairs.len()];
    for round in 0..(opts.rounds + opts.warmup_rounds) {
        let measured = round >= opts.warmup_rounds;
        for (pi, &pair) in pairs.iter().enumerate() {
            for (ii, &imp) in impls.iter().enumerate() {
                let cfg = opts.trial_config(pair);
                let t = latency_trial(imp, pair, &cfg);
                if opts.verbose {
                    eprintln!(
                        "[latency] round={round} {} {} -> enq avg {:.1}ns deq avg {:.1}ns{}",
                        pair.label(),
                        imp.name(),
                        t.enqueue.mean(),
                        t.dequeue.mean(),
                        if measured { "" } else { " (warmup)" },
                    );
                }
                if measured {
                    enq[pi * impls.len() + ii].extend(t.enqueue_raw);
                    deq[pi * impls.len() + ii].extend(t.dequeue_raw);
                }
            }
        }
    }
    let mut out = Vec::new();
    for (pi, &pair) in pairs.iter().enumerate() {
        for (ii, &imp) in impls.iter().enumerate() {
            let (ek, ed) = sigma::three_sigma_u64(&enq[pi * impls.len() + ii]);
            let (dk, dd) = sigma::three_sigma_u64(&deq[pi * impls.len() + ii]);
            out.push(LatencyCell {
                imp,
                pair,
                enqueue: LatencySummary::from_samples(&ek),
                dequeue: LatencySummary::from_samples(&dk),
                enq_discarded: ed,
                deq_discarded: dd,
            });
        }
    }
    out
}

/// One cell of the Figure-2 retention matrix.
#[derive(Debug, Clone)]
pub struct RetentionCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Throughput without inter-op load (items/sec).
    pub baseline_ips: f64,
    /// Throughput under synthetic load (items/sec).
    pub loaded_ips: f64,
    /// `loaded / baseline` as a percentage (the paper's retention).
    pub retention_pct: f64,
}

/// Figure 2: run baseline and synthetic-load regimes, report retention.
pub fn retention_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
    intensity: u32,
) -> Vec<RetentionCell> {
    let base_opts = SuiteOptions {
        load: LoadProfile::None,
        ..opts.clone()
    };
    let load_opts = SuiteOptions {
        load: LoadProfile::Synthetic(intensity),
        ..opts.clone()
    };
    let base = throughput_suite(impls, pairs, &base_opts);
    let loaded = throughput_suite(impls, pairs, &load_opts);
    base.iter()
        .zip(loaded.iter())
        .map(|(b, l)| {
            debug_assert_eq!(b.imp, l.imp);
            RetentionCell {
                imp: b.imp,
                pair: b.pair,
                baseline_ips: b.mean_ips,
                loaded_ips: l.mean_ips,
                retention_pct: if b.mean_ips > 0.0 {
                    100.0 * l.mean_ips / b.mean_ips
                } else {
                    0.0
                },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> SuiteOptions {
        SuiteOptions {
            total_ops: 2000,
            rounds: 2,
            warmup_rounds: 0,
            ..SuiteOptions::default()
        }
    }

    #[test]
    fn throughput_suite_shape() {
        let impls = [Impl::Cmp, Impl::Mutex];
        let pairs = [PairConfig::symmetric(1), PairConfig::symmetric(2)];
        let cells = throughput_suite(&impls, &pairs, &tiny_opts());
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.samples.len(), 2);
            assert!(c.mean_ips > 0.0);
        }
    }

    #[test]
    fn latency_suite_shape() {
        let impls = [Impl::Cmp];
        let pairs = [PairConfig::symmetric(1)];
        let cells = latency_suite(&impls, &pairs, &tiny_opts());
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.enqueue.count > 0);
        assert!(c.dequeue.count > 0);
        assert!(c.enqueue.avg_ns > 0.0);
        assert!(c.enqueue.p99_ns >= c.enqueue.p50_ns);
    }

    #[test]
    fn retention_suite_reports_percentage() {
        let impls = [Impl::Cmp];
        let pairs = [PairConfig::symmetric(1)];
        let cells = retention_suite(&impls, &pairs, &tiny_opts(), 4);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.retention_pct > 0.0);
        assert!(
            c.retention_pct < 120.0,
            "loaded should not beat baseline by much: {}",
            c.retention_pct
        );
    }

    #[test]
    fn bursty_scenario_suite_runs() {
        let opts = SuiteOptions {
            total_ops: 1000,
            rounds: 1,
            warmup_rounds: 0,
            scenario: Scenario::Bursty {
                burst: 128,
                gap: std::time::Duration::from_millis(1),
            },
            ..SuiteOptions::default()
        };
        let cells = throughput_suite(&[Impl::Cmp], &[PairConfig::symmetric(1)], &opts);
        assert_eq!(cells.len(), 1);
        assert!(cells[0].mean_ips > 0.0);
        // CPU metrics are best-effort (procfs); utilization, when
        // measured, is a sane fraction.
        assert!(cells[0].mean_cpu_util >= 0.0);
    }

    #[test]
    fn warmup_rounds_are_not_counted() {
        let opts = SuiteOptions {
            total_ops: 1000,
            rounds: 1,
            warmup_rounds: 2,
            ..SuiteOptions::default()
        };
        let cells = throughput_suite(&[Impl::Cmp], &[PairConfig::symmetric(1)], &opts);
        assert_eq!(cells[0].samples.len(), 1);
    }
}
