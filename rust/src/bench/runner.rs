//! Suite runner: round-robin sequencing of implementations (§4: "all
//! experiments were conducted ... with round-robin sequencing of
//! implementations to eliminate bias from CPU thermal throttling and
//! dynamic frequency scaling"), multiple rounds per configuration,
//! 3-sigma filtering of the per-round samples — plus the generic
//! workload driver ([`run_workload`]) that executes declarative
//! [`WorkloadSpec`]s against any target transport (in-process queue,
//! coordinator pipeline, TCP ingress) and returns SLO report rows.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::latency::LatencySummary;
use super::report::WorkloadRow;
use super::sigma;
use super::spec::{Measure, Target, WorkloadSpec};
use super::synthetic::LoadProfile;
use super::workload::{
    latency_trial, rank_error_trial, run_throughput_on, sojourn_percentiles, PairConfig, Scenario,
    TrialConfig, ZipfRoutedFabric,
};
use crate::queue::sharded::{ShardMode, ShardedCmp, ShardedConfig};
use crate::queue::{ConcurrentQueue, Impl};

/// Suite-level options.
#[derive(Debug, Clone)]
pub struct SuiteOptions {
    /// Items per trial (scaled per pair internally if desired).
    pub total_ops: u64,
    /// Measured rounds per (impl, pair) cell.
    pub rounds: usize,
    /// Unmeasured warmup rounds per cell.
    pub warmup_rounds: usize,
    /// Inter-op load profile.
    pub load: LoadProfile,
    /// Bounded-queue capacity hint.
    pub capacity_hint: usize,
    /// Operation batch size for throughput trials (1 = single-op API).
    pub batch_size: usize,
    /// Offered-load scenario for throughput trials (DESIGN.md §8);
    /// latency suites always run closed-loop.
    pub scenario: Scenario,
    /// Record per-item sojourn latency in throughput trials
    /// ([`TrialConfig::record_sojourn`]); the samples pool across
    /// measured rounds into [`FactoryCell::sojourn_ns`].
    pub record_sojourn: bool,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions {
            total_ops: 100_000,
            rounds: 3,
            warmup_rounds: 1,
            load: LoadProfile::None,
            capacity_hint: 1 << 16,
            batch_size: 1,
            scenario: Scenario::ClosedLoop,
            record_sojourn: false,
            verbose: false,
        }
    }
}

impl SuiteOptions {
    fn trial_config(&self, pair: PairConfig) -> TrialConfig {
        // Scale total ops down at very high thread counts so a sweep
        // stays tractable on small testbeds (the paper's absolute op
        // counts are not specified; shapes are what matters).
        let threads = (pair.producers + pair.consumers) as u64;
        let scale = if threads >= 64 { 4 } else { 1 };
        TrialConfig {
            total_ops: (self.total_ops / scale).max(1000),
            load: self.load,
            capacity_hint: self.capacity_hint,
            max_samples_per_thread: 200_000,
            batch_size: self.batch_size,
            scenario: self.scenario,
            record_sojourn: self.record_sojourn,
        }
    }
}

/// A named queue constructor for [`factory_suite`]: the generalization
/// of [`Impl`] that also covers queues with runtime configuration (the
/// zipf-routed relaxed fabric), so one suite loop serves both.
pub struct NamedFactory {
    /// Report label for rows produced from this factory.
    pub name: String,
    /// Build a fresh queue instance for one trial.
    pub make: Box<dyn Fn() -> Arc<dyn ConcurrentQueue<u64>> + Send + Sync>,
}

impl NamedFactory {
    /// The factory equivalent of `imp.make(capacity_hint)`.
    pub fn for_impl(imp: Impl, capacity_hint: usize) -> NamedFactory {
        NamedFactory {
            name: imp.name().to_string(),
            make: Box::new(move || imp.make(capacity_hint)),
        }
    }
}

/// One cell of a [`factory_suite`] run: [`ThroughputCell`] plus the
/// pooled sojourn samples, keyed by factory name instead of [`Impl`].
#[derive(Debug, Clone)]
pub struct FactoryCell {
    /// Factory name this cell measured.
    pub name: String,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Per-round samples (items/sec), pre-filter.
    pub samples: Vec<f64>,
    /// 3-sigma filtered mean.
    pub mean_ips: f64,
    /// Standard deviation of the filtered samples.
    pub std_ips: f64,
    /// Samples removed by the 3-sigma filter.
    pub discarded: usize,
    /// Mean items per CPU-second across rounds (3-sigma filtered); 0
    /// when CPU time was unavailable or below clock resolution.
    pub mean_ops_per_cpu: f64,
    /// Mean CPU utilization across rounds (CPU-seconds per wall-second
    /// per thread, ~1.0 = all cores busy); 0 when unmeasured.
    pub mean_cpu_util: f64,
    /// Sojourn samples pooled across measured rounds; empty unless
    /// [`SuiteOptions::record_sojourn`] was set.
    pub sojourn_ns: Vec<u64>,
    /// Control-plane report from the last measured round (each round
    /// builds a fresh queue, so the last one reflects the steady
    /// state); `None` for implementations without a control plane.
    pub control: Option<crate::queue::ControlReport>,
}

/// Round-robin throughput suite over `factories × pairs`: every
/// factory runs once per round before any runs again (thermal fairness
/// per the paper), warmup rounds discarded, samples 3-sigma filtered.
/// Cells come back pair-major (`pairs[0] × factories…`, then
/// `pairs[1] × factories…`).
pub fn factory_suite(
    factories: &[NamedFactory],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
) -> Vec<FactoryCell> {
    let cells = factories.len() * pairs.len();
    let mut samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    let mut cpu_samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    let mut util_samples: Vec<Vec<f64>> = vec![Vec::new(); cells];
    let mut sojourns: Vec<Vec<u64>> = vec![Vec::new(); cells];
    let mut controls: Vec<Option<crate::queue::ControlReport>> = vec![None; cells];
    for round in 0..(opts.rounds + opts.warmup_rounds) {
        let measured = round >= opts.warmup_rounds;
        for (pi, &pair) in pairs.iter().enumerate() {
            for (fi, f) in factories.iter().enumerate() {
                let cfg = opts.trial_config(pair);
                let t = run_throughput_on((f.make)(), pair, &cfg);
                if opts.verbose {
                    eprintln!(
                        "[throughput] round={round} {} {} -> {:.0} items/s{}",
                        pair.label(),
                        f.name,
                        t.items_per_sec,
                        if measured { "" } else { " (warmup)" },
                    );
                }
                if measured {
                    let idx = pi * factories.len() + fi;
                    samples[idx].push(t.items_per_sec);
                    if let Some(v) = t.ops_per_cpu_sec {
                        cpu_samples[idx].push(v);
                    }
                    if let Some(u) = t.cpu_util {
                        util_samples[idx].push(u);
                    }
                    sojourns[idx].extend(t.sojourn_ns);
                    if t.control.is_some() {
                        controls[idx] = t.control;
                    }
                }
            }
        }
    }
    let mut out = Vec::new();
    for (pi, &pair) in pairs.iter().enumerate() {
        for (fi, f) in factories.iter().enumerate() {
            let idx = pi * factories.len() + fi;
            let raw = &samples[idx];
            let (kept, discarded) = sigma::three_sigma(raw);
            let (mean, std) = sigma::mean_std(&kept);
            let (cpu_kept, _) = sigma::three_sigma(&cpu_samples[idx]);
            let (mean_ops_per_cpu, _) = sigma::mean_std(&cpu_kept);
            let (util_kept, _) = sigma::three_sigma(&util_samples[idx]);
            let (mean_cpu_util, _) = sigma::mean_std(&util_kept);
            out.push(FactoryCell {
                name: f.name.clone(),
                pair,
                samples: raw.clone(),
                mean_ips: mean,
                std_ips: std,
                discarded,
                mean_ops_per_cpu,
                mean_cpu_util,
                sojourn_ns: std::mem::take(&mut sojourns[idx]),
                control: controls[idx],
            });
        }
    }
    out
}

/// One cell of the Figure-1 style throughput matrix.
#[derive(Debug, Clone)]
pub struct ThroughputCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Per-round samples (items/sec), pre-filter.
    pub samples: Vec<f64>,
    /// 3-sigma filtered mean.
    pub mean_ips: f64,
    /// Standard deviation of the filtered samples.
    pub std_ips: f64,
    /// Samples removed by the 3-sigma filter.
    pub discarded: usize,
    /// Mean items per CPU-second across rounds (3-sigma filtered); 0
    /// when CPU time was unavailable or below clock resolution.
    pub mean_ops_per_cpu: f64,
    /// Mean CPU utilization across rounds (CPU-seconds per wall-second
    /// per thread, ~1.0 = all cores busy); 0 when unmeasured.
    pub mean_cpu_util: f64,
}

/// Round-robin throughput suite over `impls × pairs` — a
/// [`factory_suite`] over [`Impl`] constructors, keeping the
/// `Impl`-typed cells the figure/table printers consume.
pub fn throughput_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
) -> Vec<ThroughputCell> {
    let factories: Vec<NamedFactory> = impls
        .iter()
        .map(|&imp| NamedFactory::for_impl(imp, opts.capacity_hint))
        .collect();
    factory_suite(&factories, pairs, opts)
        .into_iter()
        .enumerate()
        .map(|(idx, c)| ThroughputCell {
            // factory_suite output is pair-major with the factory index
            // cycling fastest, so the impl is recovered positionally.
            imp: impls[idx % impls.len()],
            pair: c.pair,
            samples: c.samples,
            mean_ips: c.mean_ips,
            std_ips: c.std_ips,
            discarded: c.discarded,
            mean_ops_per_cpu: c.mean_ops_per_cpu,
            mean_cpu_util: c.mean_cpu_util,
        })
        .collect()
}

/// One cell of the Tables 1–3 style latency matrix.
#[derive(Debug, Clone)]
pub struct LatencyCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Enqueue-side latency summary (post-filter).
    pub enqueue: LatencySummary,
    /// Dequeue-side latency summary (post-filter).
    pub dequeue: LatencySummary,
    /// Enqueue samples removed by the 3-sigma filter.
    pub enq_discarded: usize,
    /// Dequeue samples removed by the 3-sigma filter.
    pub deq_discarded: usize,
}

/// Round-robin latency suite. Per-op samples from all rounds are
/// pooled, 3-sigma filtered (the paper's anomaly removal), then
/// summarized.
pub fn latency_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
) -> Vec<LatencyCell> {
    let mut enq: Vec<Vec<u64>> = vec![Vec::new(); impls.len() * pairs.len()];
    let mut deq: Vec<Vec<u64>> = vec![Vec::new(); impls.len() * pairs.len()];
    for round in 0..(opts.rounds + opts.warmup_rounds) {
        let measured = round >= opts.warmup_rounds;
        for (pi, &pair) in pairs.iter().enumerate() {
            for (ii, &imp) in impls.iter().enumerate() {
                let cfg = opts.trial_config(pair);
                let t = latency_trial(imp, pair, &cfg);
                if opts.verbose {
                    eprintln!(
                        "[latency] round={round} {} {} -> enq avg {:.1}ns deq avg {:.1}ns{}",
                        pair.label(),
                        imp.name(),
                        t.enqueue.mean(),
                        t.dequeue.mean(),
                        if measured { "" } else { " (warmup)" },
                    );
                }
                if measured {
                    enq[pi * impls.len() + ii].extend(t.enqueue_raw);
                    deq[pi * impls.len() + ii].extend(t.dequeue_raw);
                }
            }
        }
    }
    let mut out = Vec::new();
    for (pi, &pair) in pairs.iter().enumerate() {
        for (ii, &imp) in impls.iter().enumerate() {
            let (ek, ed) = sigma::three_sigma_u64(&enq[pi * impls.len() + ii]);
            let (dk, dd) = sigma::three_sigma_u64(&deq[pi * impls.len() + ii]);
            out.push(LatencyCell {
                imp,
                pair,
                enqueue: LatencySummary::from_samples(&ek),
                dequeue: LatencySummary::from_samples(&dk),
                enq_discarded: ed,
                deq_discarded: dd,
            });
        }
    }
    out
}

/// One cell of the Figure-2 retention matrix.
#[derive(Debug, Clone)]
pub struct RetentionCell {
    /// Queue implementation this cell measured.
    pub imp: Impl,
    /// Producer/consumer configuration.
    pub pair: PairConfig,
    /// Throughput without inter-op load (items/sec).
    pub baseline_ips: f64,
    /// Throughput under synthetic load (items/sec).
    pub loaded_ips: f64,
    /// `loaded / baseline` as a percentage (the paper's retention).
    pub retention_pct: f64,
}

/// Figure 2: run baseline and synthetic-load regimes, report retention.
pub fn retention_suite(
    impls: &[Impl],
    pairs: &[PairConfig],
    opts: &SuiteOptions,
    intensity: u32,
) -> Vec<RetentionCell> {
    let base_opts = SuiteOptions {
        load: LoadProfile::None,
        ..opts.clone()
    };
    let load_opts = SuiteOptions {
        load: LoadProfile::Synthetic(intensity),
        ..opts.clone()
    };
    let base = throughput_suite(impls, pairs, &base_opts);
    let loaded = throughput_suite(impls, pairs, &load_opts);
    base.iter()
        .zip(loaded.iter())
        .map(|(b, l)| {
            debug_assert_eq!(b.imp, l.imp);
            RetentionCell {
                imp: b.imp,
                pair: b.pair,
                baseline_ips: b.mean_ips,
                loaded_ips: l.mean_ips,
                retention_pct: if b.mean_ips > 0.0 {
                    100.0 * l.mean_ips / b.mean_ips
                } else {
                    0.0
                },
            }
        })
        .collect()
}

/// Options for one [`run_workload`] execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkloadRunOptions {
    /// Use the spec's `smoke_ops`/`smoke_pairs` instead of the full
    /// `ops`/`pairs` — the CI trajectory knob.
    pub smoke: bool,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

/// Execute one declarative workload and return its SLO report rows —
/// the single generic driver behind `repro bench --workload` and
/// `benches/throughput.rs`. Dispatch is by spec target and measure:
///
/// * queue + throughput — [`factory_suite`] per batch size, over the
///   spec's impls (or the zipf-routed relaxed fabric when `keys > 0`);
/// * queue + rank_error — [`rank_error_trial`] per pair per
///   `sweep_max_rank_error` point (`0` = strict mode), window-sized
///   from a warmup rate probe as `repro bench sharded` does;
/// * coordinator — closed-loop client threads against an in-process
///   [`crate::coordinator::server::Server`] (echo engine);
/// * tcp — blocking loopback clients through the full TCP ingress
///   ([`crate::net::listener::NetServer`]).
pub fn run_workload(
    spec: &WorkloadSpec,
    opts: &WorkloadRunOptions,
) -> Result<Vec<WorkloadRow>, String> {
    let ops = if opts.smoke { spec.smoke_ops } else { spec.ops };
    let pairs = if opts.smoke {
        &spec.smoke_pairs
    } else {
        &spec.pairs
    };
    match (spec.target, spec.measure) {
        (Target::Queue, Measure::Throughput) => Ok(run_queue_throughput(spec, ops, pairs, opts)),
        (Target::Queue, Measure::RankError) => Ok(run_rank_sweep(spec, ops, pairs, opts)),
        (Target::Coordinator, _) => Ok(vec![run_coordinator(spec, ops)]),
        (Target::Tcp, _) => run_tcp(spec, ops).map(|row| vec![row]),
    }
}

/// Queue factories for a throughput workload: the zipf-routed relaxed
/// fabric when the spec asks for key skew, plain [`Impl`] constructors
/// otherwise.
fn queue_factories(spec: &WorkloadSpec) -> Vec<NamedFactory> {
    if spec.keys > 0 {
        let (shards, bound) = (spec.shards, spec.max_rank_error);
        let (keys, s) = (spec.keys, spec.zipf_s);
        vec![NamedFactory {
            name: "sharded-zipf".to_string(),
            make: Box::new(move || {
                let fabric = ShardedCmp::with_config(
                    ShardedConfig::default()
                        .with_shards(shards)
                        .with_mode(ShardMode::Relaxed {
                            max_rank_error: bound,
                        }),
                );
                Arc::new(ZipfRoutedFabric::new(fabric, keys, s))
            }),
        }]
    } else {
        spec.impls
            .iter()
            .map(|&imp| NamedFactory::for_impl(imp, spec.capacity_hint))
            .collect()
    }
}

fn run_queue_throughput(
    spec: &WorkloadSpec,
    ops: u64,
    pairs: &[PairConfig],
    opts: &WorkloadRunOptions,
) -> Vec<WorkloadRow> {
    let factories = queue_factories(spec);
    let mut rows = Vec::new();
    for &batch in &spec.batches {
        let sopts = SuiteOptions {
            total_ops: ops,
            rounds: spec.rounds,
            warmup_rounds: spec.warmup_rounds,
            capacity_hint: spec.capacity_hint,
            batch_size: batch,
            scenario: spec.arrival.scenario(),
            record_sojourn: spec.latency,
            verbose: opts.verbose,
            ..SuiteOptions::default()
        };
        for mut cell in factory_suite(&factories, pairs, &sopts) {
            let lat = sojourn_percentiles(&mut cell.sojourn_ns);
            rows.push(WorkloadRow {
                workload: spec.name.clone(),
                impl_name: cell.name,
                pair: cell.pair.label(),
                threads: cell.pair.producers + cell.pair.consumers,
                batch,
                scenario: spec.arrival.label().to_string(),
                mean_ips: cell.mean_ips,
                std_ips: cell.std_ips,
                ops_per_cpu_sec: cell.mean_ops_per_cpu,
                cpu_util: cell.mean_cpu_util,
                rank_error_p99: None,
                lat_p50_ns: lat.map(|l| l.0),
                lat_p99_ns: lat.map(|l| l.1),
                lat_p999_ns: lat.map(|l| l.2),
                park_ratio: cell.control.and_then(|c| c.park_ratio),
                reclaim_p: cell.control.and_then(|c| c.reclaim_p),
                samples: cell.samples,
            });
        }
    }
    rows
}

fn run_rank_sweep(
    spec: &WorkloadSpec,
    ops: u64,
    pairs: &[PairConfig],
    opts: &WorkloadRunOptions,
) -> Vec<WorkloadRow> {
    let mut rows = Vec::new();
    for &pair in pairs {
        for &bound in &spec.sweep_max_rank_error {
            let mode = if bound == 0 {
                ShardMode::Strict
            } else {
                ShardMode::Relaxed {
                    max_rank_error: bound,
                }
            };
            let base = ShardedConfig::default()
                .with_shards(spec.shards)
                .with_mode(mode);
            // Size the protection window from a short rate probe, like
            // `repro bench sharded` (an undersized window at benchmark
            // rates would measure reclamation stalls, not ordering).
            let warm: Arc<dyn ConcurrentQueue<u64>> =
                Arc::new(ShardedCmp::with_config(base.clone()));
            let rate = rank_error_trial(warm, pair, ops.min(20_000), false).items_per_sec;
            let queue: Arc<dyn ConcurrentQueue<u64>> = Arc::new(ShardedCmp::with_config(
                base.sized_for_rate(rate.max(1.0) as u64, 0.5),
            ));
            let t = rank_error_trial(queue, pair, ops, false);
            let scenario = if bound == 0 {
                "strict".to_string()
            } else {
                format!("relaxed-{bound}")
            };
            if opts.verbose {
                eprintln!(
                    "[rank] {} {} {scenario} -> {:.0} items/s p99={}",
                    spec.name,
                    pair.label(),
                    t.items_per_sec,
                    t.stats.p99
                );
            }
            rows.push(WorkloadRow {
                workload: spec.name.clone(),
                impl_name: "sharded".to_string(),
                pair: pair.label(),
                threads: pair.producers + pair.consumers,
                batch: 1,
                scenario,
                mean_ips: t.items_per_sec,
                std_ips: 0.0,
                ops_per_cpu_sec: 0.0,
                cpu_util: 0.0,
                rank_error_p99: Some(t.stats.p99),
                lat_p50_ns: None,
                lat_p99_ns: None,
                lat_p999_ns: None,
                park_ratio: None,
                reclaim_p: None,
                samples: vec![t.items_per_sec],
            });
        }
    }
    rows
}

/// Echo-engine factory matched to the spec's feature width (no model
/// artifacts in a bench run).
fn echo_engine(spec: &WorkloadSpec) -> crate::coordinator::worker::EngineFactory {
    use crate::coordinator::worker::{EchoEngine, InferenceEngine};
    let features = spec.features;
    Arc::new(move || {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features,
            outputs: 16,
            scale: 1.0,
        }) as Box<dyn InferenceEngine>)
    })
}

fn run_coordinator(spec: &WorkloadSpec, ops: u64) -> WorkloadRow {
    use crate::coordinator::server::{Server, ServerConfig};

    let cfg = ServerConfig {
        shards: spec.shards,
        workers: spec.workers,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::start(cfg, echo_engine(spec)));
    let per_client = (ops / spec.clients as u64).max(1);
    let features = spec.features;
    let record = spec.latency;
    let t0 = Instant::now();
    let clients: Vec<_> = (0..spec.clients)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                let mut rng = crate::util::XorShift64::new(c as u64 + 1);
                let mut served = 0u64;
                let mut rtts: Vec<u64> = Vec::new();
                for _ in 0..per_client {
                    let row: Vec<f32> =
                        (0..features).map(|_| rng.next_f64() as f32 - 0.5).collect();
                    let q0 = Instant::now();
                    if let Ok(slot) = server.submit(row) {
                        if slot.wait_timeout(Duration::from_secs(30)).is_some() {
                            served += 1;
                            if record {
                                rtts.push(q0.elapsed().as_nanos() as u64);
                            }
                        }
                    }
                }
                (served, rtts)
            })
        })
        .collect();
    let mut served = 0u64;
    let mut rtts: Vec<u64> = Vec::new();
    for c in clients {
        let (s, r) = c.join().expect("client panicked");
        served += s;
        rtts.extend(r);
    }
    let elapsed = t0.elapsed();
    let server = Arc::try_unwrap(server)
        .ok()
        .expect("all client handles joined");
    let _ = server.shutdown();
    let lat = sojourn_percentiles(&mut rtts);
    let ips = served as f64 / elapsed.as_secs_f64().max(1e-12);
    WorkloadRow {
        workload: spec.name.clone(),
        impl_name: "coordinator".to_string(),
        pair: format!("{}C{}W", spec.clients, spec.workers),
        threads: spec.clients + spec.workers,
        batch: 1,
        scenario: "closed".to_string(),
        mean_ips: ips,
        std_ips: 0.0,
        ops_per_cpu_sec: 0.0,
        cpu_util: 0.0,
        rank_error_p99: None,
        lat_p50_ns: lat.map(|l| l.0),
        lat_p99_ns: lat.map(|l| l.1),
        lat_p999_ns: lat.map(|l| l.2),
        park_ratio: None,
        reclaim_p: None,
        samples: vec![ips],
    }
}

fn run_tcp(spec: &WorkloadSpec, ops: u64) -> Result<WorkloadRow, String> {
    use std::io::Write;
    use std::net::TcpStream;

    use crate::coordinator::server::{Server, ServerConfig};
    use crate::net::codec::{self, Status};
    use crate::net::listener::NetServer;
    use crate::net::NetConfig;

    let cfg = ServerConfig {
        shards: spec.shards,
        workers: spec.workers,
        ..ServerConfig::default()
    };
    let net_cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        io_threads: spec.io_threads,
        ..NetConfig::default()
    };
    let server = Server::start(cfg, echo_engine(spec));
    let net = NetServer::start(net_cfg, server)
        .map_err(|e| format!("workload {:?}: cannot bind TCP front end: {e}", spec.name))?;
    let addr = net.addr();
    let per_client = (ops / spec.clients as u64).max(1);
    let features = spec.features;
    let record = spec.latency;
    let t0 = Instant::now();
    let clients: Vec<_> = (0..spec.clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect loopback");
                let mut rng = crate::util::XorShift64::new(c as u64 + 1);
                let mut buf = Vec::new();
                let mut ok = 0u64;
                let mut rtts: Vec<u64> = Vec::new();
                for i in 0..per_client {
                    let req = codec::Request {
                        id: i + 1,
                        tenant: c as u32,
                        features: (0..features).map(|_| rng.next_f64() as f32 - 0.5).collect(),
                    };
                    let mut wire = Vec::new();
                    codec::encode_request(&req, &mut wire);
                    let q0 = Instant::now();
                    if stream.write_all(&wire).is_err() {
                        break;
                    }
                    let Some(resp) = codec::read_response_blocking(&mut stream, &mut buf) else {
                        break;
                    };
                    if resp.id == req.id && resp.status == Status::Ok {
                        ok += 1;
                        if record {
                            rtts.push(q0.elapsed().as_nanos() as u64);
                        }
                    }
                }
                (ok, rtts)
            })
        })
        .collect();
    let mut ok = 0u64;
    let mut rtts: Vec<u64> = Vec::new();
    for c in clients {
        let (o, r) = c.join().expect("tcp client panicked");
        ok += o;
        rtts.extend(r);
    }
    let elapsed = t0.elapsed();
    let _ = net.shutdown();
    let lat = sojourn_percentiles(&mut rtts);
    let ips = ok as f64 / elapsed.as_secs_f64().max(1e-12);
    Ok(WorkloadRow {
        workload: spec.name.clone(),
        impl_name: "tcp-ingress".to_string(),
        pair: format!("{}C{}W", spec.clients, spec.workers),
        threads: spec.clients + spec.workers + spec.io_threads,
        batch: 1,
        scenario: "closed".to_string(),
        mean_ips: ips,
        std_ips: 0.0,
        ops_per_cpu_sec: 0.0,
        cpu_util: 0.0,
        rank_error_p99: None,
        lat_p50_ns: lat.map(|l| l.0),
        lat_p99_ns: lat.map(|l| l.1),
        lat_p999_ns: lat.map(|l| l.2),
        park_ratio: None,
        reclaim_p: None,
        samples: vec![ips],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> SuiteOptions {
        SuiteOptions {
            total_ops: 2000,
            rounds: 2,
            warmup_rounds: 0,
            ..SuiteOptions::default()
        }
    }

    #[test]
    fn throughput_suite_shape() {
        let impls = [Impl::Cmp, Impl::Mutex];
        let pairs = [PairConfig::symmetric(1), PairConfig::symmetric(2)];
        let cells = throughput_suite(&impls, &pairs, &tiny_opts());
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.samples.len(), 2);
            assert!(c.mean_ips > 0.0);
        }
        // Pair-major order with the impl cycling fastest.
        assert_eq!(cells[0].imp, Impl::Cmp);
        assert_eq!(cells[1].imp, Impl::Mutex);
        assert_eq!(cells[0].pair, pairs[0]);
        assert_eq!(cells[2].pair, pairs[1]);
    }

    #[test]
    fn factory_suite_pools_sojourn() {
        let opts = SuiteOptions {
            total_ops: 1000,
            rounds: 2,
            warmup_rounds: 1,
            record_sojourn: true,
            ..SuiteOptions::default()
        };
        let factories = [NamedFactory::for_impl(Impl::Cmp, 1 << 10)];
        let cells = factory_suite(&factories, &[PairConfig::symmetric(1)], &opts);
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].name, "cmp");
        // 2 measured rounds × 1000 items, warmup discarded.
        assert_eq!(cells[0].sojourn_ns.len(), 2000);
        // CMP reports its control plane into the cell; the effective
        // reclamation probability is always known.
        let control = cells[0].control.expect("cmp has a control report");
        assert!(control.reclaim_p.is_some());
    }

    #[test]
    fn latency_suite_shape() {
        let impls = [Impl::Cmp];
        let pairs = [PairConfig::symmetric(1)];
        let cells = latency_suite(&impls, &pairs, &tiny_opts());
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.enqueue.count > 0);
        assert!(c.dequeue.count > 0);
        assert!(c.enqueue.avg_ns > 0.0);
        assert!(c.enqueue.p99_ns >= c.enqueue.p50_ns);
    }

    #[test]
    fn retention_suite_reports_percentage() {
        let impls = [Impl::Cmp];
        let pairs = [PairConfig::symmetric(1)];
        let cells = retention_suite(&impls, &pairs, &tiny_opts(), 4);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.retention_pct > 0.0);
        assert!(
            c.retention_pct < 120.0,
            "loaded should not beat baseline by much: {}",
            c.retention_pct
        );
    }

    #[test]
    fn bursty_scenario_suite_runs() {
        let opts = SuiteOptions {
            total_ops: 1000,
            rounds: 1,
            warmup_rounds: 0,
            scenario: Scenario::Bursty {
                burst: 128,
                gap: std::time::Duration::from_millis(1),
            },
            ..SuiteOptions::default()
        };
        let cells = throughput_suite(&[Impl::Cmp], &[PairConfig::symmetric(1)], &opts);
        assert_eq!(cells.len(), 1);
        assert!(cells[0].mean_ips > 0.0);
        // CPU metrics are best-effort (procfs); utilization, when
        // measured, is a sane fraction.
        assert!(cells[0].mean_cpu_util >= 0.0);
    }

    #[test]
    fn warmup_rounds_are_not_counted() {
        let opts = SuiteOptions {
            total_ops: 1000,
            rounds: 1,
            warmup_rounds: 2,
            ..SuiteOptions::default()
        };
        let cells = throughput_suite(&[Impl::Cmp], &[PairConfig::symmetric(1)], &opts);
        assert_eq!(cells[0].samples.len(), 1);
    }

    #[test]
    fn run_workload_queue_rows_carry_latency() {
        let spec = WorkloadSpec::parse(
            r#"{"name":"t","impls":["cmp"],"pairs":[1],"ops":2000,"rounds":1,
                "warmup_rounds":0,"arrival":{"kind":"open","burst":128,"gap_ms":1}}"#,
        )
        .unwrap();
        let rows = run_workload(&spec, &WorkloadRunOptions::default()).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.workload, "t");
        assert_eq!(r.impl_name, "cmp");
        assert_eq!(r.pair, "1P1C");
        assert_eq!(r.scenario, "bursty");
        assert!(r.mean_ips > 0.0);
        assert!(r.lat_p50_ns.is_some(), "open-loop rows carry percentiles");
        assert!(r.lat_p50_ns <= r.lat_p99_ns && r.lat_p99_ns <= r.lat_p999_ns);
    }

    #[test]
    fn run_workload_smoke_uses_smoke_axes() {
        let spec = WorkloadSpec::parse(
            r#"{"name":"t","impls":["cmp","mutex"],"pairs":[1,2],"smoke_pairs":[1],
                "ops":50000,"smoke_ops":1000,"rounds":1,"warmup_rounds":0}"#,
        )
        .unwrap();
        let rows = run_workload(
            &spec,
            &WorkloadRunOptions {
                smoke: true,
                verbose: false,
            },
        )
        .unwrap();
        assert_eq!(rows.len(), 2, "smoke_pairs [1] × 2 impls");
        assert!(rows.iter().all(|r| r.pair == "1P1C"));
    }

    #[test]
    fn run_workload_rank_sweep_rows() {
        let spec = WorkloadSpec::parse(
            r#"{"name":"rs","measure":"rank_error","impls":["sharded"],"pairs":[1],
                "ops":3000,"sweep_max_rank_error":[0,1024]}"#,
        )
        .unwrap();
        let rows = run_workload(&spec, &WorkloadRunOptions::default()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].scenario, "strict");
        assert_eq!(rows[0].rank_error_p99, Some(0), "strict fabric in order");
        assert_eq!(rows[1].scenario, "relaxed-1024");
        assert!(rows[1].rank_error_p99.is_some());
    }

    #[test]
    fn run_workload_zipf_uses_routed_fabric() {
        let spec = WorkloadSpec::parse(
            r#"{"name":"z","impls":["sharded"],"keys":16,"zipf_s":1.0,"pairs":[1],
                "ops":2000,"rounds":1,"warmup_rounds":0}"#,
        )
        .unwrap();
        let rows = run_workload(&spec, &WorkloadRunOptions::default()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].impl_name, "sharded-zipf");
        assert!(rows[0].mean_ips > 0.0);
    }

    #[test]
    fn run_workload_coordinator_row() {
        let spec = WorkloadSpec::parse(
            r#"{"name":"c","target":"coordinator","ops":64,"clients":2,"workers":1,
                "latency":true}"#,
        )
        .unwrap();
        let rows = run_workload(&spec, &WorkloadRunOptions::default()).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.impl_name, "coordinator");
        assert_eq!(r.pair, "2C1W");
        assert!(r.mean_ips > 0.0);
        assert!(r.lat_p50_ns.is_some());
    }
}
