//! Fault-injection experiments (FAULT, §2.3.1 / §3.6): what happens to
//! *reclamation* when a participant stalls or crashes mid-operation?
//!
//! * CMP: a consumer crashed right after its claim CAS
//!   ([`crate::queue::cmp::CmpQueue::inject_stalled_claim`]) — the
//!   paper's claim is that reclamation proceeds and the abandoned node
//!   is recovered within W cycles.
//! * Hazard pointers: a thread that published a hazard and never
//!   cleared it pins its target forever; the queue keeps retiring nodes
//!   that can be freed, but the pinned one never is.
//! * EBR: a thread that pinned an epoch and stalled blocks the global
//!   epoch — retention grows without bound while the queue churns.

use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};
use std::sync::Arc;

use crate::queue::baselines::ms_ebr::MsEbrQueue;
use crate::queue::baselines::ms_hp::MsHpQueue;
use crate::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

/// Outcome of a fault experiment.
#[derive(Debug, Clone)]
pub struct FaultOutcome {
    /// Reclamation scheme under test (`cmp`, `ms-hp`, `ms-ebr`).
    pub scheme: &'static str,
    /// Items churned through the queue after the fault.
    pub churn_ops: u64,
    /// Unreclaimed nodes after the churn (pool in-use for CMP, pending
    /// retirees for HP/EBR).
    pub retained_after: u64,
    /// Whether retention stayed bounded (the paper's resilience
    /// criterion: retained ≤ bound).
    pub bounded: bool,
    /// The bound used for the verdict.
    pub bound: u64,
}

/// CMP under a crashed consumer: claim-then-abandon `faults` nodes,
/// then churn; retention must stay ≤ W + slack.
pub fn cmp_stalled_consumer(churn_ops: u64, faults: u64) -> FaultOutcome {
    let window = 512u64;
    let cfg = CmpConfig::default()
        .with_window(window)
        .with_min_batch(1)
        .with_reclaim_period(256)
        .with_trigger(ReclaimTrigger::Modulo);
    let q: CmpQueue<u64> = CmpQueue::with_config(cfg);

    // Seed and crash `faults` consumers mid-dequeue.
    for i in 0..faults {
        q.push(i).unwrap();
    }
    let mut injected = 0;
    for _ in 0..faults {
        if q.inject_stalled_claim() {
            injected += 1;
        }
    }
    assert_eq!(injected, faults, "all claims injected");

    // Churn: the queue keeps operating; reclamation keeps running.
    for i in 0..churn_ops {
        q.push(i).unwrap();
        q.pop();
    }
    q.reclaim();

    let retained = q.nodes_in_use();
    // Bound: window + injected-but-recent + reclaim batch slack + dummy.
    let bound = window + 256 + faults + 1;
    FaultOutcome {
        scheme: "cmp",
        churn_ops,
        retained_after: retained,
        bounded: retained <= bound,
        bound,
    }
}

/// Hazard pointers under a stalled reader: one thread publishes a
/// hazard on the current head and never clears it, while the main
/// thread churns. HP keeps freeing *unpinned* nodes (bounded leak of 1
/// here), so `bounded` is true but the pinned node is never freed —
/// returned via `retained_after ≥ 1`.
pub fn hp_stalled_reader(churn_ops: u64) -> FaultOutcome {
    let q: Arc<MsHpQueue<u64>> = Arc::new(MsHpQueue::new());
    q.push(1);
    q.push(2);

    // Stalled thread: protect head and never clear; park forever.
    let hold = Arc::new(AtomicBool::new(true));
    let h2 = hold.clone();
    let q2 = q.clone();
    let stalled = std::thread::spawn(move || {
        // Publish a hazard through the domain on an arbitrary live node
        // pointer source — we use a private AtomicPtr holding a node
        // we know is in the queue by dequeuing its *value* later.
        // Simplest faithful stall: protect the queue's internals via a
        // dequeue that never finishes is not expressible through the
        // public API, so we emulate with a domain-level pin of a node
        // we retire ourselves.
        let obj = Box::into_raw(Box::new(0xDEADu64));
        let slot = AtomicPtr::new(obj);
        let p = q2.domain().protect(0, &slot);
        assert!(!p.is_null());
        unsafe {
            q2.domain()
                .retire(obj, crate::queue::reclamation::hazard::drop_box::<u64>)
        };
        while h2.load(Ordering::Acquire) {
            std::thread::park_timeout(std::time::Duration::from_millis(1));
        }
        // Cleanup on release so the test harness doesn't leak.
        q2.domain().clear_all();
    });

    // Wait for the stalled thread's hazard to be pinned.
    while q.domain().pending() == 0 {
        std::thread::yield_now();
    }

    for i in 0..churn_ops {
        q.push(i);
        q.pop();
    }
    q.domain().scan();
    let retained = q.domain().pending() as u64;

    hold.store(false, Ordering::Release);
    stalled.thread().unpark();
    stalled.join().unwrap();
    q.domain().scan();

    FaultOutcome {
        scheme: "ms-hp",
        churn_ops,
        retained_after: retained,
        // HP's leak is proportional to pinned pointers (here 1) — it is
        // "bounded" per stalled slot but *permanent* until the thread
        // recovers. We report bounded=true with the caveat in docs.
        bounded: retained <= 64 + 1,
        bound: 65,
    }
}

/// EBR under a stalled pinned thread: retention grows with churn —
/// unbounded (the §2.2 failure mode).
pub fn ebr_stalled_reader(churn_ops: u64) -> FaultOutcome {
    let q: Arc<MsEbrQueue<u64>> = Arc::new(MsEbrQueue::new());
    let hold = Arc::new(AtomicBool::new(true));
    let h2 = hold.clone();
    let q2 = q.clone();
    let pinned = Arc::new(AtomicBool::new(false));
    let p2 = pinned.clone();
    let stalled = std::thread::spawn(move || {
        let _guard = q2.domain().pin(); // pinned and stalled mid-operation
        p2.store(true, Ordering::Release);
        while h2.load(Ordering::Acquire) {
            std::thread::park_timeout(std::time::Duration::from_millis(1));
        }
    });
    while !pinned.load(Ordering::Acquire) {
        std::thread::yield_now();
    }
    // Let the pinned epoch fall behind by advancing once.
    q.domain().try_advance();

    for i in 0..churn_ops {
        q.push(i);
        q.pop();
    }
    q.domain().collect();
    let retained = q.domain().pending() as u64;

    hold.store(false, Ordering::Release);
    stalled.thread().unpark();
    stalled.join().unwrap();

    FaultOutcome {
        scheme: "ms-ebr",
        churn_ops,
        retained_after: retained,
        // Criterion: did retention scale with churn (unbounded) rather
        // than staying near a constant?
        bounded: retained < churn_ops / 2,
        bound: churn_ops / 2,
    }
}

/// Render outcomes as an aligned table.
pub fn fault_table(outcomes: &[FaultOutcome]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "# FAULT — retention after a stalled/crashed participant ({}k churn ops)",
        outcomes.first().map(|o| o.churn_ops / 1000).unwrap_or(0)
    );
    let _ = writeln!(
        s,
        "{:<10}{:>16}{:>14}{:>10}",
        "scheme", "retained_nodes", "bound", "bounded"
    );
    for o in outcomes {
        let _ = writeln!(
            s,
            "{:<10}{:>16}{:>14}{:>10}",
            o.scheme,
            o.retained_after,
            o.bound,
            if o.bounded { "yes" } else { "NO" }
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmp_recovers_from_crashed_consumer() {
        let o = cmp_stalled_consumer(20_000, 8);
        assert!(
            o.bounded,
            "CMP retention must stay bounded: retained={} bound={}",
            o.retained_after, o.bound
        );
    }

    #[test]
    fn ebr_retention_grows_with_stall() {
        let o = ebr_stalled_reader(20_000);
        assert!(
            !o.bounded,
            "EBR under a pinned stall should retain ~all churned nodes, got {}",
            o.retained_after
        );
        assert!(o.retained_after > 10_000);
    }

    #[test]
    fn hp_pins_only_the_hazarded_node() {
        let o = hp_stalled_reader(20_000);
        assert!(
            o.bounded,
            "HP leak is per-pinned-pointer: retained={}",
            o.retained_after
        );
        assert!(o.retained_after >= 1, "the pinned object is never freed");
    }

    #[test]
    fn table_renders_all_schemes() {
        let rows = vec![
            cmp_stalled_consumer(5_000, 2),
            hp_stalled_reader(5_000),
            ebr_stalled_reader(5_000),
        ];
        let t = fault_table(&rows);
        assert!(t.contains("cmp"));
        assert!(t.contains("ms-hp"));
        assert!(t.contains("ms-ebr"));
    }
}
