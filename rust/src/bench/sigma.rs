//! 3-sigma filtering (§4): "samples beyond μ ± 3σ were discarded,
//! removing ~0.3% of anomalies", applied uniformly across all
//! implementations per Georges et al. (OOPSLA '07).

/// Mean and (population) standard deviation of `xs`.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Retain samples within `μ ± k·σ`. Returns `(kept, discarded_count)`.
pub fn sigma_filter(xs: &[f64], k: f64) -> (Vec<f64>, usize) {
    let (mean, std) = mean_std(xs);
    if std == 0.0 {
        return (xs.to_vec(), 0);
    }
    let lo = mean - k * std;
    let hi = mean + k * std;
    let kept: Vec<f64> = xs.iter().copied().filter(|&x| x >= lo && x <= hi).collect();
    let discarded = xs.len() - kept.len();
    (kept, discarded)
}

/// The paper's filter: `k = 3`.
pub fn three_sigma(xs: &[f64]) -> (Vec<f64>, usize) {
    sigma_filter(xs, 3.0)
}

/// Integer-sample variant for latency nanoseconds.
pub fn three_sigma_u64(xs: &[u64]) -> (Vec<u64>, usize) {
    let f: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    let (mean, std) = mean_std(&f);
    if std == 0.0 {
        return (xs.to_vec(), 0);
    }
    let lo = mean - 3.0 * std;
    let hi = mean + 3.0 * std;
    let kept: Vec<u64> = xs
        .iter()
        .copied()
        .filter(|&x| (x as f64) >= lo && (x as f64) <= hi)
        .collect();
    let discarded = xs.len() - kept.len();
    (kept, discarded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_input() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        let (kept, d) = three_sigma(&[]);
        assert!(kept.is_empty());
        assert_eq!(d, 0);
    }

    #[test]
    fn uniform_data_is_untouched() {
        let xs = vec![5.0; 100];
        let (kept, d) = three_sigma(&xs);
        assert_eq!(kept.len(), 100);
        assert_eq!(d, 0);
    }

    #[test]
    fn outlier_is_removed() {
        let mut xs: Vec<f64> = (0..1000).map(|i| 100.0 + (i % 10) as f64).collect();
        xs.push(1_000_000.0); // an OS-preemption style spike
        let (kept, d) = three_sigma(&xs);
        assert_eq!(d, 1, "exactly the spike is removed");
        assert!(kept.iter().all(|&x| x < 1000.0));
    }

    #[test]
    fn inliers_survive() {
        // Gaussian-ish data: ≥ 99% kept.
        let xs: Vec<f64> = (0..10_000)
            .map(|i| {
                let t = i as f64 / 10_000.0 * std::f64::consts::TAU;
                500.0 + 50.0 * t.sin() + 20.0 * (3.0 * t).cos()
            })
            .collect();
        let (kept, _) = three_sigma(&xs);
        assert!(kept.len() as f64 >= 0.99 * xs.len() as f64);
    }

    #[test]
    fn u64_variant_matches() {
        let xs: Vec<u64> = vec![100, 110, 105, 95, 102, 99, 1_000_000];
        let (kept, d) = three_sigma_u64(&xs);
        // With one extreme outlier dominating sigma, filter may need the
        // value to be beyond 3σ of the *contaminated* stats; just check
        // consistency here.
        assert_eq!(kept.len() + d, xs.len());
    }

    #[test]
    fn repeated_filtering_converges() {
        let mut xs: Vec<f64> = (0..1000).map(|i| 100.0 + (i % 7) as f64).collect();
        xs.push(10_000.0);
        xs.push(20_000.0);
        let (once, _) = three_sigma(&xs);
        let (twice, d2) = three_sigma(&once);
        assert_eq!(d2, 0, "second pass removes nothing");
        assert_eq!(once.len(), twice.len());
    }
}
