//! Criterion-style benchmark harness (the offline image has no
//! criterion; DESIGN.md §3) reproducing the paper's methodology (§4):
//! round-robin sequencing across implementations, 3-sigma filtering,
//! baseline vs synthetic-load regimes, avg + P99 latency.

pub mod faults;
pub mod latency;
pub mod report;
pub mod runner;
pub mod sigma;
pub mod spec;
pub mod synthetic;
pub mod workload;
