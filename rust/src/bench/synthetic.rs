//! Synthetic mixed workload (§4 "Synthetic Workload Resilience
//! Analysis"): threads perform additional computation between queue
//! operations, "inducing memory pressure, cache contention, and
//! scheduling interference". Retention = throughput under load /
//! baseline throughput (Figure 2).

use std::cell::RefCell;

/// Size of the per-thread scratch buffer the load kernel walks
/// (256 KiB ≫ L1, ≈ L2 — produces real cache pressure).
const SCRATCH_WORDS: usize = 32 * 1024;

thread_local! {
    static SCRATCH: RefCell<Vec<u64>> = RefCell::new(vec![0x9E37_79B9u64; SCRATCH_WORDS]);
}

/// One unit of synthetic inter-operation work: strided read-modify-
/// write sweep over a thread-local buffer plus integer mixing.
/// `intensity` = number of cache lines touched (≈ a handful of ns
/// each), so the load stays comparable to a queue operation — the
/// paper's Figure 2 regime keeps retention in the 69–92% band, which
/// means the inter-op computation is the same order as the op itself.
/// Returns a value dependent on the computation so it cannot be
/// optimized away.
pub fn synthetic_work(intensity: u32, salt: u64) -> u64 {
    SCRATCH.with(|s| {
        let mut buf = s.borrow_mut();
        let len = buf.len();
        let mut acc = salt | 1;
        // Stride of 9 cache lines (72 words) defeats the prefetcher
        // enough to generate misses without TLB thrash.
        let steps = intensity as usize;
        let mut idx = (salt as usize) % len;
        for _ in 0..steps {
            let v = buf[idx];
            acc = acc
                .rotate_left(7)
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(v);
            buf[idx] = acc;
            idx = (idx + 72) % len;
        }
        acc
    })
}

/// Load profile for the Figure 2 experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadProfile {
    /// No inter-operation work (baseline regime).
    None,
    /// Synthetic computation of the given intensity between every
    /// queue operation (synthetic-load regime).
    Synthetic(u32),
}

impl LoadProfile {
    /// Execute the profile once. A `black_box`-equivalent sink prevents
    /// dead-code elimination.
    #[inline]
    pub fn run(&self, salt: u64) -> u64 {
        match self {
            LoadProfile::None => 0,
            LoadProfile::Synthetic(intensity) => synthetic_work(*intensity, salt),
        }
    }

    /// Report label: `baseline` or `synthetic(xN)`.
    pub fn label(&self) -> String {
        match self {
            LoadProfile::None => "baseline".to_string(),
            LoadProfile::Synthetic(i) => format!("synthetic(x{i})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_depends_on_inputs() {
        let a = synthetic_work(1, 1);
        let b = synthetic_work(1, 2);
        assert_ne!(a, b, "different salts give different results");
    }

    #[test]
    fn work_mutates_scratch_state() {
        // Same salt twice still differs because the buffer evolved.
        let a = synthetic_work(1, 42);
        let b = synthetic_work(1, 42);
        assert_ne!(a, b);
    }

    #[test]
    fn intensity_scales_cost() {
        use std::time::Instant;
        // Warm.
        synthetic_work(8, 0);
        let t0 = Instant::now();
        for i in 0..2000 {
            synthetic_work(1, i);
        }
        let low = t0.elapsed();
        let t1 = Instant::now();
        for i in 0..2000 {
            synthetic_work(256, i);
        }
        let high = t1.elapsed();
        assert!(
            high > low,
            "16x intensity must cost more wall time ({low:?} vs {high:?})"
        );
    }

    #[test]
    fn profile_none_is_free() {
        assert_eq!(LoadProfile::None.run(9), 0);
    }

    #[test]
    fn profile_labels() {
        assert_eq!(LoadProfile::None.label(), "baseline");
        assert_eq!(LoadProfile::Synthetic(4).label(), "synthetic(x4)");
    }
}
