//! Report rendering: the same rows/series the paper reports, as
//! aligned ASCII tables (and simple bar charts for the figures), plus a
//! minimal JSON dump for machine consumption.

use std::fmt::Write as _;

use super::runner::{LatencyCell, RetentionCell, ThroughputCell};
use crate::queue::Impl;
use crate::util::time::fmt_rate;

/// Figure 1: throughput comparison across thread configurations.
pub fn fig1_table(cells: &[ThroughputCell]) -> String {
    let mut pairs: Vec<_> = Vec::new();
    let mut impls: Vec<Impl> = Vec::new();
    for c in cells {
        if !pairs.contains(&c.pair) {
            pairs.push(c.pair);
        }
        if !impls.contains(&c.imp) {
            impls.push(c.imp);
        }
    }
    let mut s = String::new();
    let _ = writeln!(s, "# Figure 1 — Throughput (items/sec) by configuration");
    let _ = write!(s, "{:<10}", "config");
    for i in &impls {
        let _ = write!(s, "{:>16}", i.name());
    }
    let _ = writeln!(s);
    for p in &pairs {
        let _ = write!(s, "{:<10}", p.label());
        for i in &impls {
            let cell = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
            let _ = write!(s, "{:>16}", fmt_rate(cell.mean_ips));
        }
        let _ = writeln!(s);
    }
    // Relative-to-CMP rows, matching the paper's "% higher" narrative.
    if impls.contains(&Impl::Cmp) {
        let _ = writeln!(s, "\n## CMP advantage (CMP / other, ×)");
        let _ = write!(s, "{:<10}", "config");
        for i in impls.iter().filter(|i| **i != Impl::Cmp) {
            let _ = write!(s, "{:>16}", i.name());
        }
        let _ = writeln!(s);
        for p in &pairs {
            let cmp = cells
                .iter()
                .find(|c| c.pair == *p && c.imp == Impl::Cmp)
                .unwrap();
            let _ = write!(s, "{:<10}", p.label());
            for i in impls.iter().filter(|i| **i != Impl::Cmp) {
                let other = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
                let ratio = if other.mean_ips > 0.0 {
                    cmp.mean_ips / other.mean_ips
                } else {
                    f64::INFINITY
                };
                let _ = write!(s, "{:>15.2}x", ratio);
            }
            let _ = writeln!(s);
        }
    }
    s
}

/// ASCII bar chart for a figure series (log-ish scaling by sqrt).
pub fn bar_chart(title: &str, rows: &[(String, f64)], width: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let max = rows.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    if max <= 0.0 {
        return s;
    }
    for (label, v) in rows {
        let frac = (v / max).sqrt(); // sqrt softens the dynamic range
        let bars = ((width as f64) * frac).round() as usize;
        let _ = writeln!(s, "{label:<22} {} {}", "#".repeat(bars), fmt_rate(*v));
    }
    s
}

/// Tables 1–3: latency table for one pair configuration.
pub fn latency_table(title: &str, cells: &[LatencyCell]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = writeln!(
        s,
        "{:<28}{:>10}{:>10}{:>10}{:>10}",
        "Impl", "Avg Enq", "P99 Enq", "Avg Deq", "P99 Deq"
    );
    for c in cells {
        let _ = writeln!(
            s,
            "{:<28}{:>10.1}{:>10}{:>10.1}{:>10}",
            c.imp.label(),
            c.enqueue.avg_ns,
            c.enqueue.p99_ns,
            c.dequeue.avg_ns,
            c.dequeue.p99_ns
        );
    }
    s
}

/// Figure 2: retention under synthetic load.
pub fn fig2_table(cells: &[RetentionCell]) -> String {
    let mut pairs: Vec<_> = Vec::new();
    let mut impls: Vec<Impl> = Vec::new();
    for c in cells {
        if !pairs.contains(&c.pair) {
            pairs.push(c.pair);
        }
        if !impls.contains(&c.imp) {
            impls.push(c.imp);
        }
    }
    let mut s = String::new();
    let _ = writeln!(s, "# Figure 2 — Retention under synthetic load (% of baseline)");
    let _ = write!(s, "{:<10}", "config");
    for i in &impls {
        let _ = write!(s, "{:>16}", i.name());
    }
    let _ = writeln!(s);
    for p in &pairs {
        let _ = write!(s, "{:<10}", p.label());
        for i in &impls {
            let cell = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
            let _ = write!(s, "{:>15.1}%", cell.retention_pct);
        }
        let _ = writeln!(s);
    }
    s
}

/// Minimal JSON encoder for result dumps (no serde offline).
pub fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Figure-1 cells as a JSON array (`bench_results/fig1_throughput.json`).
pub fn throughput_json(cells: &[ThroughputCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"mean_ips\":{:.3},\"std_ips\":{:.3},\"discarded\":{},\"samples\":{:?}}}",
            c.imp.name(),
            c.pair.label(),
            c.mean_ips,
            c.std_ips,
            c.discarded,
            c.samples
        );
    }
    s.push(']');
    s
}

/// One row of the `BENCH_throughput.json` SLO report: a measurement
/// produced by [`crate::bench::runner::run_workload`], keyed by the
/// workload name it came from. Optional fields are emitted as JSON
/// `null` when the workload did not measure them.
#[derive(Debug, Clone)]
pub struct WorkloadRow {
    /// Name of the workload spec this row belongs to.
    pub workload: String,
    /// Implementation / transport label (`cmp`, `sharded-zipf`,
    /// `coordinator`, `tcp-ingress`, …).
    pub impl_name: String,
    /// Thread-shape label (`4P4C` for queue rows, `8C2W` for
    /// coordinator/TCP rows).
    pub pair: String,
    /// Total threads participating in the trial.
    pub threads: usize,
    /// Operation batch size the row ran at (1 = single-op API).
    pub batch: usize,
    /// Arrival-process label (`closed` / `bursty` / `idle` / `async`)
    /// or sweep-point label (`strict` / `relaxed-<bound>`).
    pub scenario: String,
    /// 3-sigma filtered mean throughput (items/sec).
    pub mean_ips: f64,
    /// Standard deviation of the filtered samples (0 for
    /// single-sample rows).
    pub std_ips: f64,
    /// Items per CPU-second (0 when CPU time was unmeasurable).
    pub ops_per_cpu_sec: f64,
    /// CPU utilization (CPU-seconds per wall-second per thread; 0
    /// when unmeasured).
    pub cpu_util: f64,
    /// p99 dequeue rank error, for rank-error sweep rows only.
    pub rank_error_p99: Option<u64>,
    /// Median per-item sojourn (queue rows) or request RTT
    /// (coordinator/TCP rows) in nanoseconds; `None` when the spec did
    /// not request latency recording.
    pub lat_p50_ns: Option<u64>,
    /// 99th-percentile latency in nanoseconds.
    pub lat_p99_ns: Option<u64>,
    /// 99.9th-percentile latency in nanoseconds.
    pub lat_p999_ns: Option<u64>,
    /// Fraction of blocking-wait exits that parked, from the queue's
    /// control report at trial end (DESIGN.md §15); `None` for rows
    /// whose implementation has no control plane.
    pub park_ratio: Option<f64>,
    /// Reclamation Bernoulli probability in effect at trial end — the
    /// occupancy-tuned live value under `cmp-adaptive`, the configured
    /// constant under plain `cmp`; `None` elsewhere.
    pub reclaim_p: Option<f64>,
    /// Per-round throughput samples, pre-filter.
    pub samples: Vec<f64>,
}

fn json_opt_u64(v: Option<u64>) -> String {
    match v {
        Some(n) => n.to_string(),
        None => "null".to_string(),
    }
}

fn json_opt_f64(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.6}"),
        None => "null".to_string(),
    }
}

/// `workload × impl × threads × batch × scenario → ops/s, CPU
/// efficiency, latency percentiles`, written to `BENCH_throughput.json`
/// so the whole scenario library is tracked across PRs rather than
/// asserted. `ops_per_cpu_sec` and `cpu_util` are 0 when CPU time was
/// unmeasurable (no procfs / below clock resolution); `rank_error_p99`,
/// the `lat_*_ns` percentiles, and the control-plane observations
/// `park_ratio`/`reclaim_p` are numbers where the workload measured
/// them and `null` elsewhere. [`diff_bench_json`] gates only
/// on throughput and CPU efficiency, so dumps from before these fields
/// existed still diff cleanly against new ones.
pub fn batch_throughput_json(rows: &[WorkloadRow]) -> String {
    let mut s = String::from("[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"workload\":\"{}\",\"impl\":\"{}\",\"pair\":\"{}\",\"threads\":{},\"batch\":{},\"scenario\":\"{}\",\"mean_ips\":{:.3},\"std_ips\":{:.3},\"ops_per_cpu_sec\":{:.3},\"cpu_util\":{:.5},\"rank_error_p99\":{},\"lat_p50_ns\":{},\"lat_p99_ns\":{},\"lat_p999_ns\":{},\"park_ratio\":{},\"reclaim_p\":{},\"samples\":{:?}}}",
            json_escape(&r.workload),
            json_escape(&r.impl_name),
            json_escape(&r.pair),
            r.threads,
            r.batch,
            json_escape(&r.scenario),
            r.mean_ips,
            r.std_ips,
            r.ops_per_cpu_sec,
            r.cpu_util,
            json_opt_u64(r.rank_error_p99),
            json_opt_u64(r.lat_p50_ns),
            json_opt_u64(r.lat_p99_ns),
            json_opt_u64(r.lat_p999_ns),
            json_opt_f64(r.park_ratio),
            json_opt_f64(r.reclaim_p),
            r.samples
        );
    }
    s.push(']');
    s
}

fn fmt_us(ns: Option<u64>) -> String {
    match ns {
        Some(n) => format!("{:.1}", n as f64 / 1000.0),
        None => "-".to_string(),
    }
}

/// SLO report table: one aligned line per workload row with
/// throughput, CPU efficiency, latency percentiles (µs; `-` where the
/// workload did not record latency) and rank error.
pub fn slo_table(rows: &[WorkloadRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# SLO report — per-workload throughput and latency");
    let _ = writeln!(
        s,
        "{:<18}{:<14}{:<8}{:>6}{:<14}{:>12}{:>12}{:>9}{:>9}{:>9}{:>9}",
        "workload",
        "impl",
        "pair",
        "batch",
        " scenario",
        "ops/s",
        "ops/cpu-s",
        "p50us",
        "p99us",
        "p999us",
        "rank99"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<18}{:<14}{:<8}{:>6} {:<13}{:>12}{:>12}{:>9}{:>9}{:>9}{:>9}",
            r.workload,
            r.impl_name,
            r.pair,
            r.batch,
            r.scenario,
            fmt_rate(r.mean_ips),
            fmt_rate(r.ops_per_cpu_sec),
            fmt_us(r.lat_p50_ns),
            fmt_us(r.lat_p99_ns),
            fmt_us(r.lat_p999_ns),
            match r.rank_error_p99 {
                Some(v) => v.to_string(),
                None => "-".to_string(),
            }
        );
    }
    s
}

/// One compared cell of `repro bench diff`: the same
/// `workload × impl × pair × batch × scenario` key measured in two
/// `BENCH_throughput.json` dumps.
#[derive(Debug, Clone)]
pub struct BenchDiffRow {
    /// Row key: `workload impl pair batch scenario` (`-` for the
    /// workload in pre-library dumps that lack the field).
    pub key: String,
    /// Old mean items/sec.
    pub old_ips: f64,
    /// New mean items/sec.
    pub new_ips: f64,
    /// `(new − old) / old` in percent (items/sec).
    pub ips_delta_pct: f64,
    /// Old items per CPU-second (0 = unmeasured in that run).
    pub old_ops_per_cpu: f64,
    /// New items per CPU-second (0 = unmeasured in that run).
    pub new_ops_per_cpu: f64,
    /// `(new − old) / old` in percent (ops/CPU-s); 0 when either side
    /// was unmeasured.
    pub cpu_delta_pct: f64,
    /// Items/sec dropped by more than the threshold.
    pub ips_regressed: bool,
    /// Ops/CPU-s dropped by more than the threshold (never set when
    /// either side was unmeasured).
    pub cpu_regressed: bool,
}

/// Result of comparing two `BENCH_throughput.json` dumps
/// ([`diff_bench_json`]) — the PR-to-PR perf-trajectory gate behind
/// `repro bench diff`.
#[derive(Debug, Clone)]
pub struct BenchDiff {
    /// Rows present in both dumps, in old-dump order.
    pub rows: Vec<BenchDiffRow>,
    /// Row keys only the old dump has (coverage shrank).
    pub only_old: Vec<String>,
    /// Row keys only the new dump has (coverage grew).
    pub only_new: Vec<String>,
    /// Workload names only the old dump covers — a removed workload is
    /// a coverage change to warn about, never a perf regression.
    pub workloads_only_old: Vec<String>,
    /// Workload names only the new dump covers (library grew).
    pub workloads_only_new: Vec<String>,
    /// Regression threshold in percent that was applied.
    pub threshold_pct: f64,
}

impl BenchDiff {
    /// Number of rows flagged as regressed on either metric.
    pub fn regressions(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| r.ips_regressed || r.cpu_regressed)
            .count()
    }

    /// Aligned ASCII table of every compared row, regressions flagged.
    pub fn table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "# Bench diff — items/s and ops/CPU-s vs baseline (threshold {:.1}%)",
            self.threshold_pct
        );
        let _ = writeln!(
            s,
            "{:<34}{:>12}{:>12}{:>9}{:>12}{:>12}{:>9}  {}",
            "key", "old ips", "new ips", "Δ%", "old op/cpu", "new op/cpu", "Δ%", "flags"
        );
        for r in &self.rows {
            let mut flags = String::new();
            if r.ips_regressed {
                flags.push_str("REGRESS(ips) ");
            }
            if r.cpu_regressed {
                flags.push_str("REGRESS(cpu)");
            }
            let _ = writeln!(
                s,
                "{:<34}{:>12.0}{:>12.0}{:>+9.1}{:>12.0}{:>12.0}{:>+9.1}  {}",
                r.key,
                r.old_ips,
                r.new_ips,
                r.ips_delta_pct,
                r.old_ops_per_cpu,
                r.new_ops_per_cpu,
                r.cpu_delta_pct,
                flags.trim_end()
            );
        }
        for k in &self.only_old {
            let _ = writeln!(s, "{k:<34} only in old dump (coverage shrank)");
        }
        for k in &self.only_new {
            let _ = writeln!(s, "{k:<34} only in new dump (new coverage)");
        }
        for w in &self.workloads_only_old {
            let _ = writeln!(s, "warn: workload {w:?} removed (coverage change)");
        }
        for w in &self.workloads_only_new {
            let _ = writeln!(s, "warn: workload {w:?} added (coverage change)");
        }
        s
    }
}

/// Percent change from `old` to `new`; 0 when `old` is unmeasurable.
fn delta_pct(old: f64, new: f64) -> f64 {
    if old > 0.0 {
        100.0 * (new - old) / old
    } else {
        0.0
    }
}

/// A parsed diff-side row: comparison key, workload name, ips, cpu.
type ParsedRow = (String, String, f64, f64);

/// Compare two `BENCH_throughput.json` documents (the format
/// [`batch_throughput_json`] writes). Rows are matched on the
/// `workload × impl × pair × batch × scenario` key (the workload
/// defaults to `-` for pre-library dumps that lack the field); a drop
/// of more than `threshold_pct` percent in `mean_ips` or
/// `ops_per_cpu_sec` flags the row as regressed. A zero
/// `ops_per_cpu_sec` means that run could not measure CPU time — such
/// rows are never CPU-flagged. Rows of a workload present on only one
/// side are *coverage changes* — surfaced via
/// [`BenchDiff::workloads_only_old`]/[`BenchDiff::workloads_only_new`]
/// and excluded from the per-row `only_*` lists — so growing or
/// pruning the library never reads as a perf regression. Errors on
/// malformed JSON or missing fields.
pub fn diff_bench_json(old: &str, new: &str, threshold_pct: f64) -> Result<BenchDiff, String> {
    let parse = |doc: &str, label: &str| -> Result<Vec<ParsedRow>, String> {
        let json = crate::util::json::Json::parse(doc).map_err(|e| format!("{label}: {e}"))?;
        let arr = json
            .as_arr()
            .ok_or_else(|| format!("{label}: top level is not an array"))?;
        let mut rows = Vec::with_capacity(arr.len());
        for (i, row) in arr.iter().enumerate() {
            let field = |k: &str| -> Result<String, String> {
                row.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| format!("{label}: row {i} lacks string field {k:?}"))
            };
            let num = |k: &str| -> Result<f64, String> {
                row.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("{label}: row {i} lacks numeric field {k:?}"))
            };
            let workload = row
                .get("workload")
                .and_then(|v| v.as_str())
                .unwrap_or("-")
                .to_string();
            let key = format!(
                "{} {} {} batch={} {}",
                workload,
                field("impl")?,
                field("pair")?,
                num("batch")? as u64,
                field("scenario")?
            );
            rows.push((key, workload, num("mean_ips")?, num("ops_per_cpu_sec")?));
        }
        Ok(rows)
    };
    let old_rows = parse(old, "old")?;
    let new_rows = parse(new, "new")?;

    let workload_set = |rows: &[ParsedRow]| -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for (_, w, _, _) in rows {
            if !names.contains(w) {
                names.push(w.clone());
            }
        }
        names
    };
    let old_workloads = workload_set(&old_rows);
    let new_workloads = workload_set(&new_rows);
    let workloads_only_old: Vec<String> = old_workloads
        .iter()
        .filter(|w| !new_workloads.contains(w))
        .cloned()
        .collect();
    let workloads_only_new: Vec<String> = new_workloads
        .iter()
        .filter(|w| !old_workloads.contains(w))
        .cloned()
        .collect();

    let mut rows = Vec::new();
    let mut only_old = Vec::new();
    for (key, workload, old_ips, old_cpu) in &old_rows {
        let Some((_, _, new_ips, new_cpu)) = new_rows.iter().find(|(k, _, _, _)| k == key) else {
            // A whole missing workload is a coverage change, not a
            // per-row hole worth listing.
            if !workloads_only_old.contains(workload) {
                only_old.push(key.clone());
            }
            continue;
        };
        let ips_delta_pct = delta_pct(*old_ips, *new_ips);
        let cpu_measured = *old_cpu > 0.0 && *new_cpu > 0.0;
        let cpu_delta_pct = if cpu_measured {
            delta_pct(*old_cpu, *new_cpu)
        } else {
            0.0
        };
        rows.push(BenchDiffRow {
            key: key.clone(),
            old_ips: *old_ips,
            new_ips: *new_ips,
            ips_delta_pct,
            old_ops_per_cpu: *old_cpu,
            new_ops_per_cpu: *new_cpu,
            cpu_delta_pct,
            ips_regressed: ips_delta_pct < -threshold_pct,
            cpu_regressed: cpu_measured && cpu_delta_pct < -threshold_pct,
        });
    }
    let only_new = new_rows
        .iter()
        .filter(|(k, w, _, _)| {
            !workloads_only_new.contains(w) && !old_rows.iter().any(|(ok, _, _, _)| ok == k)
        })
        .map(|(k, _, _, _)| k.clone())
        .collect();
    Ok(BenchDiff {
        rows,
        only_old,
        only_new,
        workloads_only_old,
        workloads_only_new,
        threshold_pct,
    })
}

/// Latency cells as a JSON array (`bench_results/tables_latency.json`).
pub fn latency_json(cells: &[LatencyCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"enq_avg\":{:.2},\"enq_p99\":{},\"deq_avg\":{:.2},\"deq_p99\":{}}}",
            c.imp.name(),
            c.pair.label(),
            c.enqueue.avg_ns,
            c.enqueue.p99_ns,
            c.dequeue.avg_ns,
            c.dequeue.p99_ns
        );
    }
    s.push(']');
    s
}

/// Retention cells as a JSON array (`bench_results/fig2_retention.json`).
pub fn retention_json(cells: &[RetentionCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"baseline_ips\":{:.1},\"loaded_ips\":{:.1},\"retention_pct\":{:.2}}}",
            c.imp.name(),
            c.pair.label(),
            c.baseline_ips,
            c.loaded_ips,
            c.retention_pct
        );
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::latency::LatencySummary;
    use crate::bench::workload::PairConfig;

    fn tcell(imp: Impl, n: usize, ips: f64) -> ThroughputCell {
        ThroughputCell {
            imp,
            pair: PairConfig::symmetric(n),
            samples: vec![ips],
            mean_ips: ips,
            std_ips: 0.0,
            discarded: 0,
            mean_ops_per_cpu: ips * 2.0,
            mean_cpu_util: 0.25,
        }
    }

    #[test]
    fn fig1_table_contains_ratios() {
        let cells = vec![
            tcell(Impl::Cmp, 1, 6.49e6),
            tcell(Impl::Segmented, 1, 3.77e6),
            tcell(Impl::MsHp, 1, 2.25e6),
        ];
        let t = fig1_table(&cells);
        assert!(t.contains("1P1C"));
        assert!(t.contains("6.49M/s"));
        assert!(t.contains("CMP advantage"));
        assert!(t.contains("1.72x"), "CMP/MC ratio from the paper: {t}");
    }

    #[test]
    fn latency_table_has_paper_columns() {
        let cells = vec![LatencyCell {
            imp: Impl::Cmp,
            pair: PairConfig::symmetric(1),
            enqueue: LatencySummary {
                count: 10,
                avg_ns: 63.9,
                p50_ns: 60,
                p99_ns: 111,
                min_ns: 40,
                max_ns: 150,
            },
            dequeue: LatencySummary {
                count: 10,
                avg_ns: 70.6,
                p50_ns: 70,
                p99_ns: 74,
                min_ns: 50,
                max_ns: 90,
            },
            enq_discarded: 0,
            deq_discarded: 0,
        }];
        let t = latency_table("Table 1 — no contention", &cells);
        for col in ["Avg Enq", "P99 Enq", "Avg Deq", "P99 Deq", "63.9", "111"] {
            assert!(t.contains(col), "missing {col} in\n{t}");
        }
    }

    #[test]
    fn fig2_table_percentages() {
        let cells = vec![RetentionCell {
            imp: Impl::Cmp,
            pair: PairConfig::symmetric(8),
            baseline_ips: 100.0,
            loaded_ips: 92.0,
            retention_pct: 92.0,
        }];
        let t = fig2_table(&cells);
        assert!(t.contains("92.0%"));
        assert!(t.contains("8P8C"));
    }

    #[test]
    fn bar_chart_renders() {
        let rows = vec![
            ("cmp".to_string(), 100.0),
            ("boost".to_string(), 25.0),
        ];
        let c = bar_chart("demo", &rows, 40);
        assert!(c.contains("cmp"));
        assert!(c.contains('#'));
    }

    #[test]
    fn json_escape_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    fn wrow(workload: &str, imp: &str, ips: f64) -> WorkloadRow {
        WorkloadRow {
            workload: workload.to_string(),
            impl_name: imp.to_string(),
            pair: "8P8C".to_string(),
            threads: 16,
            batch: 64,
            scenario: "closed".to_string(),
            mean_ips: ips,
            std_ips: 0.0,
            ops_per_cpu_sec: ips * 2.0,
            cpu_util: 0.25,
            rank_error_p99: None,
            lat_p50_ns: None,
            lat_p99_ns: None,
            lat_p999_ns: None,
            park_ratio: None,
            reclaim_p: None,
            samples: vec![ips],
        }
    }

    #[test]
    fn batch_throughput_json_shape() {
        let mut sharded = wrow("rank_sweep", "sharded", 2.0e6);
        sharded.batch = 1;
        sharded.scenario = "relaxed-1024".to_string();
        sharded.rank_error_p99 = Some(17);
        let mut lat = wrow("bursty", "cmp", 3.0e6);
        lat.lat_p50_ns = Some(1_200);
        lat.lat_p99_ns = Some(9_000);
        lat.lat_p999_ns = Some(55_000);
        lat.park_ratio = Some(0.125);
        lat.reclaim_p = Some(0.03125);
        let rows = vec![wrow("closed_loop", "cmp", 5.0e6), sharded, lat];
        let j = batch_throughput_json(&rows);
        let parsed = crate::util::json::Json::parse(&j).expect("valid JSON");
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("workload").unwrap().as_str(), Some("closed_loop"));
        assert_eq!(arr[0].get("impl").unwrap().as_str(), Some("cmp"));
        assert_eq!(arr[0].get("batch").unwrap().as_usize(), Some(64));
        assert_eq!(arr[0].get("threads").unwrap().as_usize(), Some(16));
        assert_eq!(arr[0].get("scenario").unwrap().as_str(), Some("closed"));
        assert_eq!(arr[1].get("pair").unwrap().as_str(), Some("8P8C"));
        assert_eq!(arr[1].get("scenario").unwrap().as_str(), Some("relaxed-1024"));
        assert!(arr[0].get("mean_ips").unwrap().as_f64().unwrap() > 0.0);
        assert!(arr[0].get("ops_per_cpu_sec").unwrap().as_f64().unwrap() > 0.0);
        let util = arr[0].get("cpu_util").unwrap().as_f64().unwrap();
        assert!((util - 0.25).abs() < 1e-9);
        // Unmeasured fields carry an explicit null, measured a number.
        assert_eq!(
            arr[0].get("rank_error_p99"),
            Some(&crate::util::json::Json::Null)
        );
        assert_eq!(arr[1].get("rank_error_p99").unwrap().as_usize(), Some(17));
        assert_eq!(arr[0].get("lat_p50_ns"), Some(&crate::util::json::Json::Null));
        assert_eq!(arr[2].get("lat_p50_ns").unwrap().as_usize(), Some(1_200));
        assert_eq!(arr[2].get("lat_p999_ns").unwrap().as_usize(), Some(55_000));
        // Control-plane observations: null where absent, numbers where
        // the queue reported them.
        assert_eq!(arr[0].get("park_ratio"), Some(&crate::util::json::Json::Null));
        assert_eq!(arr[0].get("reclaim_p"), Some(&crate::util::json::Json::Null));
        let pr = arr[2].get("park_ratio").unwrap().as_f64().unwrap();
        assert!((pr - 0.125).abs() < 1e-9, "park_ratio round-trips: {pr}");
        let rp = arr[2].get("reclaim_p").unwrap().as_f64().unwrap();
        assert!((rp - 0.03125).abs() < 1e-9, "reclaim_p round-trips: {rp}");
    }

    #[test]
    fn slo_table_renders_latency_and_dashes() {
        let mut lat = wrow("bursty", "cmp", 3.0e6);
        lat.lat_p50_ns = Some(1_200);
        lat.lat_p99_ns = Some(9_000);
        lat.lat_p999_ns = Some(55_000);
        let t = slo_table(&[wrow("closed_loop", "mutex", 5.0e6), lat]);
        assert!(t.contains("closed_loop"), "{t}");
        assert!(t.contains("bursty"), "{t}");
        assert!(t.contains("1.2"), "p50 in µs: {t}");
        assert!(t.contains("55.0"), "p99.9 in µs: {t}");
        assert!(t.contains('-'), "unmeasured latency as dash: {t}");
    }

    fn diff_row(workload: &str, imp: &str, ips: f64, cpu: f64) -> String {
        format!(
            "{{\"workload\":\"{workload}\",\"impl\":\"{imp}\",\"pair\":\"4P4C\",\
             \"threads\":8,\"batch\":1,\
             \"scenario\":\"closed\",\"mean_ips\":{ips:.1},\"std_ips\":0.0,\
             \"ops_per_cpu_sec\":{cpu:.1},\"cpu_util\":0.5,\"samples\":[{ips:.1}]}}"
        )
    }

    #[test]
    fn bench_diff_flags_regressions_only() {
        let old = format!(
            "[{},{},{}]",
            diff_row("w", "cmp", 1000.0, 2000.0),
            diff_row("w", "mutex", 500.0, 800.0),
            diff_row("w", "vyukov", 700.0, 900.0)
        );
        // cmp: ips −20% (regressed), cpu +10%. mutex: ips +20%, cpu
        // −50% (regressed). vyukov: within threshold both ways.
        let new = format!(
            "[{},{},{}]",
            diff_row("w", "cmp", 800.0, 2200.0),
            diff_row("w", "mutex", 600.0, 400.0),
            diff_row("w", "vyukov", 665.0, 900.0)
        );
        let d = diff_bench_json(&old, &new, 10.0).expect("valid dumps");
        assert_eq!(d.rows.len(), 3);
        assert_eq!(d.regressions(), 2);
        let cmp = &d.rows[0];
        assert!(cmp.ips_regressed && !cmp.cpu_regressed, "{cmp:?}");
        assert!((cmp.ips_delta_pct + 20.0).abs() < 1e-9);
        let mx = &d.rows[1];
        assert!(!mx.ips_regressed && mx.cpu_regressed, "{mx:?}");
        let vy = &d.rows[2];
        assert!(!vy.ips_regressed && !vy.cpu_regressed, "−5% is in budget");
        let t = d.table();
        assert!(t.contains("REGRESS(ips)"), "{t}");
        assert!(t.contains("REGRESS(cpu)"), "{t}");
        assert!(t.contains("w cmp 4P4C batch=1 closed"), "{t}");
    }

    #[test]
    fn bench_diff_handles_coverage_changes_and_unmeasured_cpu() {
        let old = format!(
            "[{},{}]",
            diff_row("w", "cmp", 1000.0, 0.0),
            diff_row("w", "mutex", 1.0, 1.0)
        );
        let new = format!(
            "[{},{}]",
            diff_row("w", "cmp", 100.0, 3000.0),
            diff_row("w", "vyukov", 2.0, 2.0)
        );
        let d = diff_bench_json(&old, &new, 10.0).expect("valid dumps");
        assert_eq!(d.rows.len(), 1, "only cmp matches");
        assert!(d.rows[0].ips_regressed);
        assert!(!d.rows[0].cpu_regressed, "unmeasured old CPU must not flag");
        assert_eq!(d.only_old, vec!["w mutex 4P4C batch=1 closed".to_string()]);
        assert_eq!(d.only_new, vec!["w vyukov 4P4C batch=1 closed".to_string()]);
        assert!(d.workloads_only_old.is_empty());
        assert!(d.workloads_only_new.is_empty());
        let t = d.table();
        assert!(t.contains("only in old dump"), "{t}");
        assert!(t.contains("only in new dump"), "{t}");
    }

    #[test]
    fn bench_diff_treats_workload_churn_as_coverage_not_regression() {
        let old = format!(
            "[{},{}]",
            diff_row("keep", "cmp", 1000.0, 2000.0),
            diff_row("gone", "cmp", 1000.0, 2000.0)
        );
        let new = format!(
            "[{},{}]",
            diff_row("keep", "cmp", 1000.0, 2000.0),
            diff_row("fresh", "cmp", 5.0, 5.0)
        );
        let d = diff_bench_json(&old, &new, 10.0).expect("valid dumps");
        assert_eq!(d.regressions(), 0, "workload churn must not gate");
        assert_eq!(d.workloads_only_old, vec!["gone".to_string()]);
        assert_eq!(d.workloads_only_new, vec!["fresh".to_string()]);
        assert!(
            d.only_old.is_empty() && d.only_new.is_empty(),
            "whole-workload churn is not per-row coverage: {:?} {:?}",
            d.only_old,
            d.only_new
        );
        let t = d.table();
        assert!(t.contains("warn: workload \"gone\" removed"), "{t}");
        assert!(t.contains("warn: workload \"fresh\" added"), "{t}");
    }

    #[test]
    fn bench_diff_accepts_legacy_rows_without_workload() {
        // Pre-library dumps lack the workload field; they key as "-".
        let legacy = "[{\"impl\":\"cmp\",\"pair\":\"4P4C\",\"threads\":8,\
             \"batch\":1,\"scenario\":\"closed\",\"mean_ips\":1000.0,\
             \"std_ips\":0.0,\"ops_per_cpu_sec\":0.0,\"cpu_util\":0.0,\
             \"samples\":[1000.0]}]";
        let modern = format!("[{}]", diff_row("-", "cmp", 900.0, 0.0));
        let d = diff_bench_json(legacy, &modern, 15.0).expect("legacy must parse");
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.rows[0].key, "- cmp 4P4C batch=1 closed");
        assert!(!d.rows[0].ips_regressed, "−10% is within 15%");
    }

    #[test]
    fn bench_diff_rejects_malformed_input() {
        assert!(diff_bench_json("not json", "[]", 10.0).is_err());
        assert!(diff_bench_json("[]", "{\"a\":1}", 10.0).is_err());
        assert!(diff_bench_json("[{\"impl\":\"cmp\"}]", "[]", 10.0).is_err());
        // Round-trips the real writer output.
        let mut row = wrow("lib", "cmp", 1234.0);
        row.batch = 8;
        row.scenario = "async".to_string();
        row.pair = "2P2C".to_string();
        let j = batch_throughput_json(&[row]);
        let d = diff_bench_json(&j, &j, 5.0).expect("writer output must diff");
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.regressions(), 0, "identical dumps never regress");
        assert_eq!(d.rows[0].key, "lib cmp 2P2C batch=8 async");
    }

    #[test]
    fn json_dumps_parse_shallowly() {
        let cells = vec![tcell(Impl::Cmp, 1, 1000.0)];
        let j = throughput_json(&cells);
        assert!(j.starts_with('['));
        assert!(j.ends_with(']'));
        assert!(j.contains("\"impl\":\"cmp\""));
    }
}
