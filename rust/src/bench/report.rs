//! Report rendering: the same rows/series the paper reports, as
//! aligned ASCII tables (and simple bar charts for the figures), plus a
//! minimal JSON dump for machine consumption.

use std::fmt::Write as _;

use super::runner::{LatencyCell, RetentionCell, ThroughputCell};
use crate::queue::Impl;
use crate::util::time::fmt_rate;

/// Figure 1: throughput comparison across thread configurations.
pub fn fig1_table(cells: &[ThroughputCell]) -> String {
    let mut pairs: Vec<_> = Vec::new();
    let mut impls: Vec<Impl> = Vec::new();
    for c in cells {
        if !pairs.contains(&c.pair) {
            pairs.push(c.pair);
        }
        if !impls.contains(&c.imp) {
            impls.push(c.imp);
        }
    }
    let mut s = String::new();
    let _ = writeln!(s, "# Figure 1 — Throughput (items/sec) by configuration");
    let _ = write!(s, "{:<10}", "config");
    for i in &impls {
        let _ = write!(s, "{:>16}", i.name());
    }
    let _ = writeln!(s);
    for p in &pairs {
        let _ = write!(s, "{:<10}", p.label());
        for i in &impls {
            let cell = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
            let _ = write!(s, "{:>16}", fmt_rate(cell.mean_ips));
        }
        let _ = writeln!(s);
    }
    // Relative-to-CMP rows, matching the paper's "% higher" narrative.
    if impls.contains(&Impl::Cmp) {
        let _ = writeln!(s, "\n## CMP advantage (CMP / other, ×)");
        let _ = write!(s, "{:<10}", "config");
        for i in impls.iter().filter(|i| **i != Impl::Cmp) {
            let _ = write!(s, "{:>16}", i.name());
        }
        let _ = writeln!(s);
        for p in &pairs {
            let cmp = cells
                .iter()
                .find(|c| c.pair == *p && c.imp == Impl::Cmp)
                .unwrap();
            let _ = write!(s, "{:<10}", p.label());
            for i in impls.iter().filter(|i| **i != Impl::Cmp) {
                let other = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
                let ratio = if other.mean_ips > 0.0 {
                    cmp.mean_ips / other.mean_ips
                } else {
                    f64::INFINITY
                };
                let _ = write!(s, "{:>15.2}x", ratio);
            }
            let _ = writeln!(s);
        }
    }
    s
}

/// ASCII bar chart for a figure series (log-ish scaling by sqrt).
pub fn bar_chart(title: &str, rows: &[(String, f64)], width: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let max = rows.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    if max <= 0.0 {
        return s;
    }
    for (label, v) in rows {
        let frac = (v / max).sqrt(); // sqrt softens the dynamic range
        let bars = ((width as f64) * frac).round() as usize;
        let _ = writeln!(s, "{label:<22} {} {}", "#".repeat(bars), fmt_rate(*v));
    }
    s
}

/// Tables 1–3: latency table for one pair configuration.
pub fn latency_table(title: &str, cells: &[LatencyCell]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = writeln!(
        s,
        "{:<28}{:>10}{:>10}{:>10}{:>10}",
        "Impl", "Avg Enq", "P99 Enq", "Avg Deq", "P99 Deq"
    );
    for c in cells {
        let _ = writeln!(
            s,
            "{:<28}{:>10.1}{:>10}{:>10.1}{:>10}",
            c.imp.label(),
            c.enqueue.avg_ns,
            c.enqueue.p99_ns,
            c.dequeue.avg_ns,
            c.dequeue.p99_ns
        );
    }
    s
}

/// Figure 2: retention under synthetic load.
pub fn fig2_table(cells: &[RetentionCell]) -> String {
    let mut pairs: Vec<_> = Vec::new();
    let mut impls: Vec<Impl> = Vec::new();
    for c in cells {
        if !pairs.contains(&c.pair) {
            pairs.push(c.pair);
        }
        if !impls.contains(&c.imp) {
            impls.push(c.imp);
        }
    }
    let mut s = String::new();
    let _ = writeln!(s, "# Figure 2 — Retention under synthetic load (% of baseline)");
    let _ = write!(s, "{:<10}", "config");
    for i in &impls {
        let _ = write!(s, "{:>16}", i.name());
    }
    let _ = writeln!(s);
    for p in &pairs {
        let _ = write!(s, "{:<10}", p.label());
        for i in &impls {
            let cell = cells.iter().find(|c| c.pair == *p && c.imp == *i).unwrap();
            let _ = write!(s, "{:>15.1}%", cell.retention_pct);
        }
        let _ = writeln!(s);
    }
    s
}

/// Minimal JSON encoder for result dumps (no serde offline).
pub fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Figure-1 cells as a JSON array (`bench_results/fig1_throughput.json`).
pub fn throughput_json(cells: &[ThroughputCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"mean_ips\":{:.3},\"std_ips\":{:.3},\"discarded\":{},\"samples\":{:?}}}",
            c.imp.name(),
            c.pair.label(),
            c.mean_ips,
            c.std_ips,
            c.discarded,
            c.samples
        );
    }
    s.push(']');
    s
}

/// One row of the `BENCH_throughput.json` perf-trajectory dump:
/// a [`ThroughputCell`] tagged with the operation batch size and the
/// offered-load scenario it ran under.
#[derive(Debug, Clone)]
pub struct BatchThroughputRow {
    /// The measured cell.
    pub cell: ThroughputCell,
    /// Operation batch size the cell ran at.
    pub batch: usize,
    /// Offered-load scenario label (`closed` / `bursty` / `idle`),
    /// from [`crate::bench::workload::Scenario::label`].
    pub scenario: &'static str,
    /// p99 dequeue rank error measured for this cell
    /// ([`crate::bench::workload::rank_error_trial`]), or `None` for
    /// rows where rank error was not measured (plain throughput
    /// trials). Emitted as JSON `null` when absent so old and new
    /// dumps stay mutually diffable.
    pub rank_error_p99: Option<u64>,
}

/// `impl × threads × batch-size × scenario → ops/s + CPU efficiency`,
/// written to `BENCH_throughput.json` so the amortization win *and* the
/// spin-vs-park trade-off are tracked across PRs rather than asserted.
/// `ops_per_cpu_sec` and `cpu_util` are 0 when CPU time was
/// unmeasurable (no procfs / below clock resolution).
/// `rank_error_p99` is a number for rank-error rows (the sharded
/// fabric's ordering-vs-throughput trade) and `null` elsewhere;
/// [`diff_bench_json`] ignores the field, so dumps from before it
/// existed still diff cleanly against new ones.
pub fn batch_throughput_json(rows: &[BatchThroughputRow]) -> String {
    let mut s = String::from("[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"threads\":{},\"batch\":{},\"scenario\":\"{}\",\"mean_ips\":{:.3},\"std_ips\":{:.3},\"ops_per_cpu_sec\":{:.3},\"cpu_util\":{:.5},\"rank_error_p99\":{},\"samples\":{:?}}}",
            r.cell.imp.name(),
            r.cell.pair.label(),
            r.cell.pair.producers + r.cell.pair.consumers,
            r.batch,
            r.scenario,
            r.cell.mean_ips,
            r.cell.std_ips,
            r.cell.mean_ops_per_cpu,
            r.cell.mean_cpu_util,
            match r.rank_error_p99 {
                Some(p) => p.to_string(),
                None => "null".to_string(),
            },
            r.cell.samples
        );
    }
    s.push(']');
    s
}

/// One compared cell of `repro bench diff`: the same
/// `impl × pair × batch × scenario` key measured in two
/// `BENCH_throughput.json` dumps.
#[derive(Debug, Clone)]
pub struct BenchDiffRow {
    /// Row key: `impl pair batch scenario`.
    pub key: String,
    /// Old mean items/sec.
    pub old_ips: f64,
    /// New mean items/sec.
    pub new_ips: f64,
    /// `(new − old) / old` in percent (items/sec).
    pub ips_delta_pct: f64,
    /// Old items per CPU-second (0 = unmeasured in that run).
    pub old_ops_per_cpu: f64,
    /// New items per CPU-second (0 = unmeasured in that run).
    pub new_ops_per_cpu: f64,
    /// `(new − old) / old` in percent (ops/CPU-s); 0 when either side
    /// was unmeasured.
    pub cpu_delta_pct: f64,
    /// Items/sec dropped by more than the threshold.
    pub ips_regressed: bool,
    /// Ops/CPU-s dropped by more than the threshold (never set when
    /// either side was unmeasured).
    pub cpu_regressed: bool,
}

/// Result of comparing two `BENCH_throughput.json` dumps
/// ([`diff_bench_json`]) — the PR-to-PR perf-trajectory gate behind
/// `repro bench diff`.
#[derive(Debug, Clone)]
pub struct BenchDiff {
    /// Rows present in both dumps, in old-dump order.
    pub rows: Vec<BenchDiffRow>,
    /// Row keys only the old dump has (coverage shrank).
    pub only_old: Vec<String>,
    /// Row keys only the new dump has (coverage grew).
    pub only_new: Vec<String>,
    /// Regression threshold in percent that was applied.
    pub threshold_pct: f64,
}

impl BenchDiff {
    /// Number of rows flagged as regressed on either metric.
    pub fn regressions(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| r.ips_regressed || r.cpu_regressed)
            .count()
    }

    /// Aligned ASCII table of every compared row, regressions flagged.
    pub fn table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "# Bench diff — items/s and ops/CPU-s vs baseline (threshold {:.1}%)",
            self.threshold_pct
        );
        let _ = writeln!(
            s,
            "{:<34}{:>12}{:>12}{:>9}{:>12}{:>12}{:>9}  {}",
            "key", "old ips", "new ips", "Δ%", "old op/cpu", "new op/cpu", "Δ%", "flags"
        );
        for r in &self.rows {
            let mut flags = String::new();
            if r.ips_regressed {
                flags.push_str("REGRESS(ips) ");
            }
            if r.cpu_regressed {
                flags.push_str("REGRESS(cpu)");
            }
            let _ = writeln!(
                s,
                "{:<34}{:>12.0}{:>12.0}{:>+9.1}{:>12.0}{:>12.0}{:>+9.1}  {}",
                r.key,
                r.old_ips,
                r.new_ips,
                r.ips_delta_pct,
                r.old_ops_per_cpu,
                r.new_ops_per_cpu,
                r.cpu_delta_pct,
                flags.trim_end()
            );
        }
        for k in &self.only_old {
            let _ = writeln!(s, "{k:<34} only in old dump (coverage shrank)");
        }
        for k in &self.only_new {
            let _ = writeln!(s, "{k:<34} only in new dump (new coverage)");
        }
        s
    }
}

/// Percent change from `old` to `new`; 0 when `old` is unmeasurable.
fn delta_pct(old: f64, new: f64) -> f64 {
    if old > 0.0 {
        100.0 * (new - old) / old
    } else {
        0.0
    }
}

/// Compare two `BENCH_throughput.json` documents (the format
/// [`batch_throughput_json`] writes). Rows are matched on the
/// `impl × pair × batch × scenario` key; a drop of more than
/// `threshold_pct` percent in `mean_ips` or `ops_per_cpu_sec` flags
/// the row as regressed. A zero `ops_per_cpu_sec` means that run
/// could not measure CPU time — such rows are never CPU-flagged.
/// Errors on malformed JSON or missing fields.
pub fn diff_bench_json(old: &str, new: &str, threshold_pct: f64) -> Result<BenchDiff, String> {
    let parse = |doc: &str, label: &str| -> Result<Vec<(String, f64, f64)>, String> {
        let json = crate::util::json::Json::parse(doc).map_err(|e| format!("{label}: {e}"))?;
        let arr = json
            .as_arr()
            .ok_or_else(|| format!("{label}: top level is not an array"))?;
        let mut rows = Vec::with_capacity(arr.len());
        for (i, row) in arr.iter().enumerate() {
            let field = |k: &str| -> Result<String, String> {
                row.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| format!("{label}: row {i} lacks string field {k:?}"))
            };
            let num = |k: &str| -> Result<f64, String> {
                row.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("{label}: row {i} lacks numeric field {k:?}"))
            };
            let key = format!(
                "{} {} batch={} {}",
                field("impl")?,
                field("pair")?,
                num("batch")? as u64,
                field("scenario")?
            );
            rows.push((key, num("mean_ips")?, num("ops_per_cpu_sec")?));
        }
        Ok(rows)
    };
    let old_rows = parse(old, "old")?;
    let new_rows = parse(new, "new")?;

    let mut rows = Vec::new();
    let mut only_old = Vec::new();
    for (key, old_ips, old_cpu) in &old_rows {
        let Some((_, new_ips, new_cpu)) = new_rows.iter().find(|(k, _, _)| k == key) else {
            only_old.push(key.clone());
            continue;
        };
        let ips_delta_pct = delta_pct(*old_ips, *new_ips);
        let cpu_measured = *old_cpu > 0.0 && *new_cpu > 0.0;
        let cpu_delta_pct = if cpu_measured {
            delta_pct(*old_cpu, *new_cpu)
        } else {
            0.0
        };
        rows.push(BenchDiffRow {
            key: key.clone(),
            old_ips: *old_ips,
            new_ips: *new_ips,
            ips_delta_pct,
            old_ops_per_cpu: *old_cpu,
            new_ops_per_cpu: *new_cpu,
            cpu_delta_pct,
            ips_regressed: ips_delta_pct < -threshold_pct,
            cpu_regressed: cpu_measured && cpu_delta_pct < -threshold_pct,
        });
    }
    let only_new = new_rows
        .iter()
        .filter(|(k, _, _)| !old_rows.iter().any(|(ok, _, _)| ok == k))
        .map(|(k, _, _)| k.clone())
        .collect();
    Ok(BenchDiff {
        rows,
        only_old,
        only_new,
        threshold_pct,
    })
}

/// Latency cells as a JSON array (`bench_results/tables_latency.json`).
pub fn latency_json(cells: &[LatencyCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"enq_avg\":{:.2},\"enq_p99\":{},\"deq_avg\":{:.2},\"deq_p99\":{}}}",
            c.imp.name(),
            c.pair.label(),
            c.enqueue.avg_ns,
            c.enqueue.p99_ns,
            c.dequeue.avg_ns,
            c.dequeue.p99_ns
        );
    }
    s.push(']');
    s
}

/// Retention cells as a JSON array (`bench_results/fig2_retention.json`).
pub fn retention_json(cells: &[RetentionCell]) -> String {
    let mut s = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"impl\":\"{}\",\"pair\":\"{}\",\"baseline_ips\":{:.1},\"loaded_ips\":{:.1},\"retention_pct\":{:.2}}}",
            c.imp.name(),
            c.pair.label(),
            c.baseline_ips,
            c.loaded_ips,
            c.retention_pct
        );
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::latency::LatencySummary;
    use crate::bench::workload::PairConfig;

    fn tcell(imp: Impl, n: usize, ips: f64) -> ThroughputCell {
        ThroughputCell {
            imp,
            pair: PairConfig::symmetric(n),
            samples: vec![ips],
            mean_ips: ips,
            std_ips: 0.0,
            discarded: 0,
            mean_ops_per_cpu: ips * 2.0,
            mean_cpu_util: 0.25,
        }
    }

    #[test]
    fn fig1_table_contains_ratios() {
        let cells = vec![
            tcell(Impl::Cmp, 1, 6.49e6),
            tcell(Impl::Segmented, 1, 3.77e6),
            tcell(Impl::MsHp, 1, 2.25e6),
        ];
        let t = fig1_table(&cells);
        assert!(t.contains("1P1C"));
        assert!(t.contains("6.49M/s"));
        assert!(t.contains("CMP advantage"));
        assert!(t.contains("1.72x"), "CMP/MC ratio from the paper: {t}");
    }

    #[test]
    fn latency_table_has_paper_columns() {
        let cells = vec![LatencyCell {
            imp: Impl::Cmp,
            pair: PairConfig::symmetric(1),
            enqueue: LatencySummary {
                count: 10,
                avg_ns: 63.9,
                p50_ns: 60,
                p99_ns: 111,
                min_ns: 40,
                max_ns: 150,
            },
            dequeue: LatencySummary {
                count: 10,
                avg_ns: 70.6,
                p50_ns: 70,
                p99_ns: 74,
                min_ns: 50,
                max_ns: 90,
            },
            enq_discarded: 0,
            deq_discarded: 0,
        }];
        let t = latency_table("Table 1 — no contention", &cells);
        for col in ["Avg Enq", "P99 Enq", "Avg Deq", "P99 Deq", "63.9", "111"] {
            assert!(t.contains(col), "missing {col} in\n{t}");
        }
    }

    #[test]
    fn fig2_table_percentages() {
        let cells = vec![RetentionCell {
            imp: Impl::Cmp,
            pair: PairConfig::symmetric(8),
            baseline_ips: 100.0,
            loaded_ips: 92.0,
            retention_pct: 92.0,
        }];
        let t = fig2_table(&cells);
        assert!(t.contains("92.0%"));
        assert!(t.contains("8P8C"));
    }

    #[test]
    fn bar_chart_renders() {
        let rows = vec![
            ("cmp".to_string(), 100.0),
            ("boost".to_string(), 25.0),
        ];
        let c = bar_chart("demo", &rows, 40);
        assert!(c.contains("cmp"));
        assert!(c.contains('#'));
    }

    #[test]
    fn json_escape_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn batch_throughput_json_shape() {
        let rows = vec![
            BatchThroughputRow {
                cell: tcell(Impl::Cmp, 8, 5.0e6),
                batch: 64,
                scenario: "closed",
                rank_error_p99: None,
            },
            BatchThroughputRow {
                cell: tcell(Impl::Sharded, 8, 2.0e6),
                batch: 1,
                scenario: "rank-relaxed",
                rank_error_p99: Some(17),
            },
        ];
        let j = batch_throughput_json(&rows);
        let parsed = crate::util::json::Json::parse(&j).expect("valid JSON");
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("impl").unwrap().as_str(), Some("cmp"));
        assert_eq!(arr[0].get("batch").unwrap().as_usize(), Some(64));
        assert_eq!(arr[0].get("threads").unwrap().as_usize(), Some(16));
        assert_eq!(arr[0].get("scenario").unwrap().as_str(), Some("closed"));
        assert_eq!(arr[1].get("pair").unwrap().as_str(), Some("8P8C"));
        assert_eq!(arr[1].get("impl").unwrap().as_str(), Some("sharded"));
        assert_eq!(arr[1].get("scenario").unwrap().as_str(), Some("rank-relaxed"));
        assert!(arr[0].get("mean_ips").unwrap().as_f64().unwrap() > 0.0);
        assert!(arr[0].get("ops_per_cpu_sec").unwrap().as_f64().unwrap() > 0.0);
        let util = arr[0].get("cpu_util").unwrap().as_f64().unwrap();
        assert!((util - 0.25).abs() < 1e-9);
        // Unmeasured rows carry an explicit null, measured ones a number.
        assert_eq!(
            arr[0].get("rank_error_p99"),
            Some(&crate::util::json::Json::Null)
        );
        assert_eq!(arr[1].get("rank_error_p99").unwrap().as_usize(), Some(17));
    }

    fn diff_row(imp: &str, ips: f64, cpu: f64) -> String {
        format!(
            "{{\"impl\":\"{imp}\",\"pair\":\"4P4C\",\"threads\":8,\"batch\":1,\
             \"scenario\":\"closed\",\"mean_ips\":{ips:.1},\"std_ips\":0.0,\
             \"ops_per_cpu_sec\":{cpu:.1},\"cpu_util\":0.5,\"samples\":[{ips:.1}]}}"
        )
    }

    #[test]
    fn bench_diff_flags_regressions_only() {
        let old = format!(
            "[{},{},{}]",
            diff_row("cmp", 1000.0, 2000.0),
            diff_row("mutex", 500.0, 800.0),
            diff_row("vyukov", 700.0, 900.0)
        );
        // cmp: ips −20% (regressed), cpu +10%. mutex: ips +20%, cpu
        // −50% (regressed). vyukov: within threshold both ways.
        let new = format!(
            "[{},{},{}]",
            diff_row("cmp", 800.0, 2200.0),
            diff_row("mutex", 600.0, 400.0),
            diff_row("vyukov", 665.0, 900.0)
        );
        let d = diff_bench_json(&old, &new, 10.0).expect("valid dumps");
        assert_eq!(d.rows.len(), 3);
        assert_eq!(d.regressions(), 2);
        let cmp = &d.rows[0];
        assert!(cmp.ips_regressed && !cmp.cpu_regressed, "{cmp:?}");
        assert!((cmp.ips_delta_pct + 20.0).abs() < 1e-9);
        let mx = &d.rows[1];
        assert!(!mx.ips_regressed && mx.cpu_regressed, "{mx:?}");
        let vy = &d.rows[2];
        assert!(!vy.ips_regressed && !vy.cpu_regressed, "−5% is in budget");
        let t = d.table();
        assert!(t.contains("REGRESS(ips)"), "{t}");
        assert!(t.contains("REGRESS(cpu)"), "{t}");
        assert!(t.contains("cmp 4P4C batch=1 closed"), "{t}");
    }

    #[test]
    fn bench_diff_handles_coverage_changes_and_unmeasured_cpu() {
        let old = format!(
            "[{},{}]",
            diff_row("cmp", 1000.0, 0.0),
            diff_row("mutex", 1.0, 1.0)
        );
        let new = format!(
            "[{},{}]",
            diff_row("cmp", 100.0, 3000.0),
            diff_row("vyukov", 2.0, 2.0)
        );
        let d = diff_bench_json(&old, &new, 10.0).expect("valid dumps");
        assert_eq!(d.rows.len(), 1, "only cmp matches");
        assert!(d.rows[0].ips_regressed);
        assert!(!d.rows[0].cpu_regressed, "unmeasured old CPU must not flag");
        assert_eq!(d.only_old, vec!["mutex 4P4C batch=1 closed".to_string()]);
        assert_eq!(d.only_new, vec!["vyukov 4P4C batch=1 closed".to_string()]);
        let t = d.table();
        assert!(t.contains("only in old dump"), "{t}");
        assert!(t.contains("only in new dump"), "{t}");
    }

    #[test]
    fn bench_diff_rejects_malformed_input() {
        assert!(diff_bench_json("not json", "[]", 10.0).is_err());
        assert!(diff_bench_json("[]", "{\"a\":1}", 10.0).is_err());
        assert!(diff_bench_json("[{\"impl\":\"cmp\"}]", "[]", 10.0).is_err());
        // Round-trips the real writer output.
        let rows = vec![BatchThroughputRow {
            cell: tcell(Impl::Cmp, 2, 1234.0),
            batch: 8,
            scenario: "async",
            rank_error_p99: None,
        }];
        let j = batch_throughput_json(&rows);
        let d = diff_bench_json(&j, &j, 5.0).expect("writer output must diff");
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.regressions(), 0, "identical dumps never regress");
        assert_eq!(d.rows[0].key, "cmp 2P2C batch=8 async");
    }

    #[test]
    fn json_dumps_parse_shallowly() {
        let cells = vec![tcell(Impl::Cmp, 1, 1000.0)];
        let j = throughput_json(&cells);
        assert!(j.starts_with('['));
        assert!(j.ends_with(']'));
        assert!(j.contains("\"impl\":\"cmp\""));
    }
}
