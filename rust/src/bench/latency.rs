//! Latency recording: log-bucket histogram (HDR-style) for nanosecond
//! samples plus exact raw-sample collection for the paper's 3-sigma
//! filtering methodology (§4).

/// Buckets: 64 major (power of two) × 16 minor = 1024 buckets covering
/// 1ns .. ~590years with ≤ 6.25% relative error — plenty for queue ops.
const MINORS: usize = 16;
const BUCKETS: usize = 64 * MINORS;

/// Log-bucket latency histogram.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
    min: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v < MINORS as u64 {
            return v as usize;
        }
        let major = 63 - v.leading_zeros() as usize; // ≥ 4
        let minor = ((v >> (major - 4)) & (MINORS as u64 - 1)) as usize;
        ((major - 3) * MINORS + minor).min(BUCKETS - 1)
    }

    /// Representative (lower-bound) value of a bucket.
    fn bucket_low(idx: usize) -> u64 {
        if idx < MINORS {
            return idx as u64;
        }
        let major = idx / MINORS + 3;
        let minor = (idx % MINORS) as u64;
        (1u64 << major) | (minor << (major - 4))
    }

    /// Record one sample (nanoseconds).
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    /// Fold `other`'s samples into this histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of all recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile `q ∈ [0,1]` (bucket lower bound — a slight
    /// underestimate, consistent across implementations).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_low(i);
            }
        }
        self.max
    }

    /// Median ([`Histogram::quantile`] at 0.50).
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 99th percentile ([`Histogram::quantile`] at 0.99).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

/// Summary statistics the paper's tables report (avg + P99, ns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    /// Samples summarized.
    pub count: u64,
    /// Mean latency in nanoseconds.
    pub avg_ns: f64,
    /// Median latency in nanoseconds.
    pub p50_ns: u64,
    /// 99th-percentile latency in nanoseconds.
    pub p99_ns: u64,
    /// Minimum latency in nanoseconds.
    pub min_ns: u64,
    /// Maximum latency in nanoseconds.
    pub max_ns: u64,
}

impl LatencySummary {
    /// Summarize a histogram.
    pub fn from_histogram(h: &Histogram) -> Self {
        LatencySummary {
            count: h.count(),
            avg_ns: h.mean(),
            p50_ns: h.p50(),
            p99_ns: h.p99(),
            min_ns: h.min(),
            max_ns: h.max(),
        }
    }

    /// Summary from raw samples (used after 3-sigma filtering).
    pub fn from_samples(samples: &[u64]) -> Self {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        let mut h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        Self::from_histogram(&h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
        // rank ⌈0.5·16⌉ = 8 ⇒ the 8th smallest value, which is 7.
        assert_eq!(h.p50(), 7);
    }

    #[test]
    fn bucket_error_is_bounded() {
        // Relative error of bucket_low ≤ 1/16 for any value ≥ 16.
        for v in [17u64, 100, 1000, 54321, 1 << 20, (1 << 40) + 12345] {
            let b = Histogram::bucket_of(v);
            let low = Histogram::bucket_low(b);
            assert!(low <= v, "low {low} > v {v}");
            assert!(
                (v - low) as f64 / v as f64 <= 1.0 / 16.0 + 1e-9,
                "error too large for {v}: low={low}"
            );
        }
    }

    #[test]
    fn quantiles_are_monotonic() {
        let mut h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        assert!(h.p50() <= h.p99());
        assert!(h.p99() <= h.max());
        // p50 of uniform 1..10000 ≈ 5000 (within bucket error).
        let p50 = h.p50() as f64;
        assert!((4400.0..=5200.0).contains(&p50), "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((9200.0..=10000.0).contains(&p99), "p99={p99}");
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for i in 0..1000u64 {
            a.record(i * 3);
            c.record(i * 3);
        }
        for i in 0..500u64 {
            b.record(i * 7);
            c.record(i * 7);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.mean(), c.mean());
        assert_eq!(a.p99(), c.p99());
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        h.record(100);
        h.record(200);
        h.record(600);
        assert_eq!(h.mean(), 300.0);
    }

    #[test]
    fn summary_from_samples() {
        let samples: Vec<u64> = (1..=100).collect();
        let s = LatencySummary::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert!((s.avg_ns - 50.5).abs() < 1e-9);
        assert_eq!(s.min_ns, 1);
        assert_eq!(s.max_ns, 100);
        assert!(s.p99_ns >= 95);
    }

    #[test]
    fn summary_from_empty_samples() {
        assert_eq!(LatencySummary::from_samples(&[]), LatencySummary::default());
    }
}
