//! Producer/consumer workload generator: the NPNC trial engine behind
//! every figure and table (§4). One *trial* runs N producers and N
//! consumers against a fresh queue instance, measuring either wall-
//! clock throughput or per-operation latency, with an optional
//! synthetic load between operations (Figure 2 regime).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use super::latency::Histogram;
use super::synthetic::LoadProfile;
use crate::queue::{ConcurrentQueue, Impl};

/// Producer/consumer pair configuration. The paper sweeps symmetric
/// pairs 1P1C … 64P64C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairConfig {
    pub producers: usize,
    pub consumers: usize,
}

impl PairConfig {
    pub fn symmetric(n: usize) -> Self {
        PairConfig {
            producers: n,
            consumers: n,
        }
    }

    /// The paper's Figure 1 sweep.
    pub fn paper_sweep() -> Vec<PairConfig> {
        [1, 2, 4, 8, 16, 32, 64]
            .into_iter()
            .map(PairConfig::symmetric)
            .collect()
    }

    pub fn label(&self) -> String {
        format!("{}P{}C", self.producers, self.consumers)
    }
}

/// One trial's knobs.
#[derive(Debug, Clone)]
pub struct TrialConfig {
    /// Total items enqueued across all producers in the trial.
    pub total_ops: u64,
    /// Inter-operation load (baseline vs synthetic regimes).
    pub load: LoadProfile,
    /// Capacity hint for bounded comparators (Vyukov ring).
    pub capacity_hint: usize,
    /// Cap on recorded latency samples per thread (memory bound).
    pub max_samples_per_thread: usize,
    /// Operation batch size (the amortization axis, DESIGN.md §7):
    /// producers enqueue chunks of this many items via
    /// `try_enqueue_batch` and consumers claim up to this many per
    /// `try_dequeue_batch`. `1` (the default) uses the single-op API,
    /// exactly as before. Latency trials always run single-op.
    pub batch_size: usize,
}

impl Default for TrialConfig {
    fn default() -> Self {
        TrialConfig {
            total_ops: 100_000,
            load: LoadProfile::None,
            capacity_hint: 1 << 16,
            max_samples_per_thread: 200_000,
            batch_size: 1,
        }
    }
}

/// Result of a throughput trial.
#[derive(Debug, Clone, Copy)]
pub struct ThroughputTrial {
    /// Items actually consumed. Can be slightly below the enqueued
    /// count for CMP when a consumer is preempted past the protection
    /// window and the reclaimer recovers its claimed payload — the
    /// paper's bounded-window semantics (§3.6). Reported, never hidden.
    pub items: u64,
    pub elapsed: Duration,
    pub items_per_sec: f64,
    /// Items enqueued but recovered by reclamation instead of consumed.
    pub lost: u64,
}

/// Consecutive empty polls (with producers finished) that terminate a
/// consumer. After producers are done, `None` from a strict queue means
/// empty-at-linearization; the streak absorbs transient claim races.
const EMPTY_STREAK_EXIT: u32 = 256;

/// Result of a latency trial: merged per-op histograms.
pub struct LatencyTrial {
    pub enqueue: Histogram,
    pub dequeue: Histogram,
    /// Raw samples (for 3-sigma filtering), truncated per thread.
    pub enqueue_raw: Vec<u64>,
    pub dequeue_raw: Vec<u64>,
}

/// Run one throughput trial of `imp` at `pair`.
pub fn throughput_trial(imp: Impl, pair: PairConfig, cfg: &TrialConfig) -> ThroughputTrial {
    let queue: Arc<dyn ConcurrentQueue<u64>> = imp.make(cfg.capacity_hint);
    run_throughput_on(queue, pair, cfg)
}

/// Run one throughput trial against a caller-supplied queue (used by
/// the ablation benches to test specific CMP configurations).
pub fn run_throughput_on(
    queue: Arc<dyn ConcurrentQueue<u64>>,
    pair: PairConfig,
    cfg: &TrialConfig,
) -> ThroughputTrial {
    let per_producer = (cfg.total_ops / pair.producers as u64).max(1);
    let total = per_producer * pair.producers as u64;
    let consumed = Arc::new(AtomicU64::new(0));
    let producers_done = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(pair.producers + pair.consumers + 1));
    let load = cfg.load;
    // Workers stamp the trial's start/end themselves: on an
    // oversubscribed single core the whole trial can finish before the
    // *main* thread (also a barrier participant) gets scheduled to read
    // a clock, which would report near-zero elapsed time.
    let anchor = crate::util::time::Anchor::now();
    let start_ns = Arc::new(AtomicU64::new(0));
    let end_ns = Arc::new(AtomicU64::new(0));
    fn stamp_start(anchor: crate::util::time::Anchor, s: &AtomicU64) {
        let now = anchor.ns().max(1);
        let _ = s.compare_exchange(0, now, Ordering::AcqRel, Ordering::Acquire);
    }

    let batch = cfg.batch_size.max(1);

    let mut handles = Vec::with_capacity(pair.producers + pair.consumers);
    for p in 0..pair.producers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let producers_done = producers_done.clone();
        let (start_ns, end_ns) = (start_ns.clone(), end_ns.clone());
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            stamp_start(anchor, &start_ns);
            let base = p as u64 * per_producer;
            if batch <= 1 {
                for i in 0..per_producer {
                    load.run(i ^ (p as u64) << 32);
                    queue.enqueue(base + i);
                }
            } else {
                let mut i = 0u64;
                while i < per_producer {
                    let k = (batch as u64).min(per_producer - i);
                    for j in 0..k {
                        load.run((i + j) ^ (p as u64) << 32);
                    }
                    queue.enqueue_batch((base + i..base + i + k).collect());
                    i += k;
                }
            }
            producers_done.fetch_add(1, Ordering::AcqRel);
            end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
        }));
    }
    let n_producers = pair.producers as u64;
    for c in 0..pair.consumers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let consumed = consumed.clone();
        let producers_done = producers_done.clone();
        let (start_ns, end_ns) = (start_ns.clone(), end_ns.clone());
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            stamp_start(anchor, &start_ns);
            let mut salt = c as u64;
            let mut empty_streak = 0u32;
            let mut buf: Vec<u64> = Vec::with_capacity(batch);
            loop {
                let got = if batch <= 1 {
                    load.run(salt);
                    salt = salt.wrapping_add(0x9E37_79B9);
                    match queue.try_dequeue() {
                        Some(_) => 1,
                        None => 0,
                    }
                } else {
                    let n = queue.try_dequeue_batch(batch, &mut buf);
                    buf.clear();
                    // Run the inter-op load once per received item so
                    // synthetic-load regimes stay comparable per item.
                    for _ in 0..n.max(1) {
                        load.run(salt);
                        salt = salt.wrapping_add(0x9E37_79B9);
                    }
                    n
                };
                if got > 0 {
                    consumed.fetch_add(got as u64, Ordering::AcqRel);
                    empty_streak = 0;
                } else {
                    if consumed.load(Ordering::Acquire) >= total {
                        break;
                    }
                    // Termination must not depend on `consumed`
                    // alone: CMP may *recover* a payload whose
                    // claimer was preempted past the window (§3.6),
                    // so `consumed` can stall below `total`.
                    if producers_done.load(Ordering::Acquire) == n_producers {
                        empty_streak += 1;
                        if empty_streak >= EMPTY_STREAK_EXIT {
                            break;
                        }
                    }
                    std::thread::yield_now();
                }
            }
            end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
        }));
    }

    barrier.wait();
    for h in handles {
        h.join().expect("worker panicked");
    }
    let t0 = start_ns.load(Ordering::Acquire);
    let t1 = end_ns.load(Ordering::Acquire).max(t0 + 1);
    let elapsed = Duration::from_nanos(t1 - t0);
    let got = consumed.load(Ordering::Acquire);
    ThroughputTrial {
        items: got,
        elapsed,
        items_per_sec: got as f64 / elapsed.as_secs_f64().max(1e-12),
        lost: total - got,
    }
}

/// Run one latency trial of `imp` at `pair`: every enqueue and every
/// successful dequeue is individually timed.
pub fn latency_trial(imp: Impl, pair: PairConfig, cfg: &TrialConfig) -> LatencyTrial {
    let queue: Arc<dyn ConcurrentQueue<u64>> = imp.make(cfg.capacity_hint);
    run_latency_on(queue, pair, cfg)
}

/// Latency trial against a caller-supplied queue.
pub fn run_latency_on(
    queue: Arc<dyn ConcurrentQueue<u64>>,
    pair: PairConfig,
    cfg: &TrialConfig,
) -> LatencyTrial {
    let per_producer = (cfg.total_ops / pair.producers as u64).max(1);
    let total = per_producer * pair.producers as u64;
    let consumed = Arc::new(AtomicU64::new(0));
    let producers_done = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(pair.producers + pair.consumers + 1));
    let load = cfg.load;
    let cap = cfg.max_samples_per_thread;

    let mut prod_handles = Vec::with_capacity(pair.producers);
    for p in 0..pair.producers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let producers_done = producers_done.clone();
        prod_handles.push(std::thread::spawn(move || {
            let mut hist = Histogram::new();
            let mut raw = Vec::with_capacity(per_producer.min(cap as u64) as usize);
            barrier.wait();
            for i in 0..per_producer {
                load.run(i);
                let t0 = Instant::now();
                queue.enqueue(p as u64 * per_producer + i);
                let ns = t0.elapsed().as_nanos() as u64;
                hist.record(ns);
                if raw.len() < cap {
                    raw.push(ns);
                }
            }
            producers_done.fetch_add(1, Ordering::AcqRel);
            (hist, raw)
        }));
    }
    let n_producers = pair.producers as u64;
    let mut cons_handles = Vec::with_capacity(pair.consumers);
    for _ in 0..pair.consumers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let consumed = consumed.clone();
        let producers_done = producers_done.clone();
        cons_handles.push(std::thread::spawn(move || {
            let mut hist = Histogram::new();
            let mut raw = Vec::new();
            barrier.wait();
            let mut salt = 0u64;
            let mut empty_streak = 0u32;
            loop {
                load.run(salt);
                salt = salt.wrapping_add(1);
                let t0 = Instant::now();
                let r = queue.try_dequeue();
                let ns = t0.elapsed().as_nanos() as u64;
                match r {
                    Some(_) => {
                        hist.record(ns);
                        if raw.len() < cap {
                            raw.push(ns);
                        }
                        consumed.fetch_add(1, Ordering::AcqRel);
                        empty_streak = 0;
                    }
                    None => {
                        if consumed.load(Ordering::Acquire) >= total {
                            break;
                        }
                        // See run_throughput_on: window-recovered
                        // payloads mean `consumed` can stall below
                        // `total` — terminate on producer completion +
                        // a sustained empty streak.
                        if producers_done.load(Ordering::Acquire) == n_producers {
                            empty_streak += 1;
                            if empty_streak >= EMPTY_STREAK_EXIT {
                                break;
                            }
                        }
                        std::thread::yield_now();
                    }
                }
            }
            (hist, raw)
        }));
    }

    barrier.wait();
    let mut enqueue = Histogram::new();
    let mut enqueue_raw = Vec::new();
    for h in prod_handles {
        let (hist, raw) = h.join().expect("producer panicked");
        enqueue.merge(&hist);
        enqueue_raw.extend(raw);
    }
    let mut dequeue = Histogram::new();
    let mut dequeue_raw = Vec::new();
    for h in cons_handles {
        let (hist, raw) = h.join().expect("consumer panicked");
        dequeue.merge(&hist);
        dequeue_raw.extend(raw);
    }
    LatencyTrial {
        enqueue,
        dequeue,
        enqueue_raw,
        dequeue_raw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TrialConfig {
        TrialConfig {
            total_ops: 4000,
            ..TrialConfig::default()
        }
    }

    #[test]
    fn pair_labels() {
        assert_eq!(PairConfig::symmetric(4).label(), "4P4C");
        let sweep = PairConfig::paper_sweep();
        assert_eq!(sweep.len(), 7);
        assert_eq!(sweep[0].label(), "1P1C");
        assert_eq!(sweep[6].label(), "64P64C");
    }

    #[test]
    fn throughput_trial_conserves_items() {
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &small_cfg());
        assert_eq!(t.items, 4000);
        assert!(t.items_per_sec > 0.0);
        assert!(t.elapsed > Duration::ZERO);
    }

    #[test]
    fn batched_throughput_trial_conserves_items() {
        for batch in [8usize, 64] {
            let cfg = TrialConfig {
                total_ops: 4000,
                batch_size: batch,
                ..TrialConfig::default()
            };
            let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 4000, "batch={batch}");
            assert_eq!(t.lost, 0, "batch={batch}");
        }
    }

    #[test]
    fn batched_trial_works_for_default_impls_too() {
        // Baselines ride the trait's default batch methods.
        let cfg = TrialConfig {
            total_ops: 4000,
            batch_size: 8,
            ..TrialConfig::default()
        };
        for imp in [Impl::Mutex, Impl::Segmented, Impl::Vyukov] {
            let t = throughput_trial(imp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 4000, "{}", imp.name());
        }
    }

    #[test]
    fn throughput_trial_all_impls_1p1c() {
        for imp in Impl::ALL {
            let t = throughput_trial(imp, PairConfig::symmetric(1), &small_cfg());
            assert_eq!(t.items, 4000, "{}", imp.name());
        }
    }

    #[test]
    fn latency_trial_counts_match() {
        let t = latency_trial(Impl::Cmp, PairConfig::symmetric(2), &small_cfg());
        assert_eq!(t.enqueue.count(), 4000);
        assert_eq!(t.dequeue.count(), 4000);
        assert_eq!(t.enqueue_raw.len(), 4000);
        assert_eq!(t.dequeue_raw.len(), 4000);
        assert!(t.enqueue.mean() > 0.0);
    }

    #[test]
    fn synthetic_load_slows_throughput() {
        let base = throughput_trial(Impl::Cmp, PairConfig::symmetric(1), &small_cfg());
        let loaded_cfg = TrialConfig {
            total_ops: 4000,
            load: LoadProfile::Synthetic(64),
            ..TrialConfig::default()
        };
        let loaded = throughput_trial(Impl::Cmp, PairConfig::symmetric(1), &loaded_cfg);
        assert!(
            loaded.items_per_sec < base.items_per_sec,
            "load must reduce throughput ({} vs {})",
            loaded.items_per_sec,
            base.items_per_sec
        );
    }

    #[test]
    fn uneven_ops_round_down_consistently() {
        let cfg = TrialConfig {
            total_ops: 1001,
            ..TrialConfig::default()
        };
        let t = throughput_trial(Impl::Mutex, PairConfig::symmetric(3), &cfg);
        assert_eq!(t.items, 999, "333 per producer × 3");
    }
}
