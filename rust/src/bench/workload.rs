//! Producer/consumer workload generator: the NPNC trial engine behind
//! every figure and table (§4). One *trial* runs N producers and N
//! consumers against a fresh queue instance, measuring either wall-
//! clock throughput or per-operation latency, with an optional
//! synthetic load between operations (Figure 2 regime) and an
//! offered-load [`Scenario`] axis (closed-loop / bursty / idle /
//! async-task consumers) that also reports CPU efficiency (ops per
//! CPU-second, DESIGN.md §8 and §10).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use super::latency::Histogram;
use super::synthetic::LoadProfile;
use crate::queue::sharded::{ShardMode, ShardedCmp};
use crate::queue::{BoxFuture, ConcurrentQueue, Impl};
use crate::util::cpu::process_cpu_seconds;

/// Producer/consumer pair configuration. The paper sweeps symmetric
/// pairs 1P1C … 64P64C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairConfig {
    /// Producer thread count.
    pub producers: usize,
    /// Consumer thread count.
    pub consumers: usize,
}

impl PairConfig {
    /// `n` producers and `n` consumers.
    pub fn symmetric(n: usize) -> Self {
        PairConfig {
            producers: n,
            consumers: n,
        }
    }

    /// The paper's Figure 1 sweep.
    pub fn paper_sweep() -> Vec<PairConfig> {
        [1, 2, 4, 8, 16, 32, 64]
            .into_iter()
            .map(PairConfig::symmetric)
            .collect()
    }

    /// Display label, e.g. `4P4C`.
    pub fn label(&self) -> String {
        format!("{}P{}C", self.producers, self.consumers)
    }
}

/// Offered-load scenario for a throughput trial (DESIGN.md §8): how
/// producers pace their enqueues and how consumers wait when empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// The paper's regime: producers enqueue as fast as they can and
    /// consumers spin-poll. Measures peak throughput.
    ClosedLoop,
    /// Open-loop arrival bursts with idle gaps (bursty/diurnal serving
    /// load): each producer emits a burst, then idles. Consumers use
    /// the blocking (parking) dequeue paths, so the trial measures CPU
    /// efficiency as well as throughput.
    Bursty {
        /// Items emitted per burst, per producer.
        burst: u64,
        /// Idle time between bursts.
        gap: Duration,
    },
    /// Zero offered load: producers stay silent for `hold` while
    /// consumers park. Measures the idle CPU floor of the empty-queue
    /// wait path (~100% of a core per consumer when spinning, <5% when
    /// parking).
    Idle {
        /// How long consumers are left facing an empty queue.
        hold: Duration,
    },
    /// Async serving shape (DESIGN.md §10): producers push closed-loop
    /// from threads, but each consumer thread hosts a round-robin
    /// [`crate::util::Executor`] multiplexing `tasks_per_consumer`
    /// async consumer tasks pulling through
    /// [`crate::queue::ConcurrentQueue::pop_deadline_async`]. For CMP
    /// the tasks resolve on push-side waker wakeups; baselines ride
    /// the polling default — so the row measures exactly the overhead
    /// (or win) of the async bridge versus dedicated consumer threads.
    /// `batch_size` is ignored (tasks claim single items).
    Async {
        /// Consumer tasks multiplexed per consumer thread.
        tasks_per_consumer: usize,
    },
}

impl Scenario {
    /// Short report label: `closed`, `bursty`, `idle`, or `async`.
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::ClosedLoop => "closed",
            Scenario::Bursty { .. } => "bursty",
            Scenario::Idle { .. } => "idle",
            Scenario::Async { .. } => "async",
        }
    }
}

/// One trial's knobs.
#[derive(Debug, Clone)]
pub struct TrialConfig {
    /// Total items enqueued across all producers in the trial.
    pub total_ops: u64,
    /// Inter-operation load (baseline vs synthetic regimes).
    pub load: LoadProfile,
    /// Capacity hint for bounded comparators (Vyukov ring).
    pub capacity_hint: usize,
    /// Cap on recorded latency samples per thread (memory bound).
    pub max_samples_per_thread: usize,
    /// Operation batch size (the amortization axis, DESIGN.md §7):
    /// producers enqueue chunks of this many items via
    /// `try_enqueue_batch` and consumers claim up to this many per
    /// `try_dequeue_batch`. `1` (the default) uses the single-op API,
    /// exactly as before. Latency trials always run single-op.
    pub batch_size: usize,
    /// Offered-load scenario (DESIGN.md §8). Latency trials always run
    /// closed-loop.
    pub scenario: Scenario,
    /// Record per-item sojourn time (enqueue → dequeue, DESIGN.md §14):
    /// producers stamp the payload with the trial clock and consumers
    /// log `now − stamp` into [`ThroughputTrial::sojourn_ns`], capped
    /// at `max_samples_per_thread` per consumer. Off by default —
    /// recording costs a clock read per item, which distorts peak
    /// closed-loop rows.
    pub record_sojourn: bool,
}

impl Default for TrialConfig {
    fn default() -> Self {
        TrialConfig {
            total_ops: 100_000,
            load: LoadProfile::None,
            capacity_hint: 1 << 16,
            max_samples_per_thread: 200_000,
            batch_size: 1,
            scenario: Scenario::ClosedLoop,
            record_sojourn: false,
        }
    }
}

/// Result of a throughput trial.
#[derive(Debug, Clone)]
pub struct ThroughputTrial {
    /// Items actually consumed. Can be slightly below the enqueued
    /// count for CMP when a consumer is preempted past the protection
    /// window and the reclaimer recovers its claimed payload — the
    /// paper's bounded-window semantics (§3.6). Reported, never hidden.
    pub items: u64,
    /// Wall-clock span from the first worker's start to the last exit.
    pub elapsed: Duration,
    /// `items / elapsed` in items per second.
    pub items_per_sec: f64,
    /// Items enqueued but recovered by reclamation instead of consumed.
    pub lost: u64,
    /// Process CPU time consumed during the trial (user + system);
    /// `None` when the platform exposes no `/proc/self/stat`.
    pub cpu_seconds: Option<f64>,
    /// Items per CPU-second — the spin-vs-park efficiency metric
    /// (DESIGN.md §8). `None` when CPU time was unavailable or below
    /// clock resolution.
    pub ops_per_cpu_sec: Option<f64>,
    /// CPU-seconds per wall-second per thread, in `[0, ~1]`: ~1.0 means
    /// every thread burned its core the whole trial; an idle parked
    /// fleet sits near 0.
    pub cpu_util: Option<f64>,
    /// Per-item sojourn samples (enqueue → dequeue, nanoseconds),
    /// pooled across consumers. Empty unless
    /// [`TrialConfig::record_sojourn`] was set; feed to
    /// [`sojourn_percentiles`] for the SLO report.
    pub sojourn_ns: Vec<u64>,
    /// The queue's control-plane report sampled at trial end
    /// (`park_ratio`, live `reclaim_p`, learned spin budget). `None`
    /// for implementations without one (everything but CMP).
    pub control: Option<crate::queue::ControlReport>,
}

/// Consecutive empty polls (with producers finished) that terminate a
/// consumer. After producers are done, `None` from a strict queue means
/// empty-at-linearization; the streak absorbs transient claim races.
const EMPTY_STREAK_EXIT: u32 = 256;

/// Park slice for consumers in the parking scenarios: each blocking
/// claim waits at most this long. Pushes end the park immediately, so
/// the slice only bounds how quickly exit conditions are re-checked.
const PARK_SLICE: Duration = Duration::from_millis(50);

/// Consecutive fully-expired empty park slices (with producers done)
/// that terminate a parking consumer.
const EMPTY_SLICE_EXIT: u32 = 2;

/// Result of a latency trial: merged per-op histograms.
pub struct LatencyTrial {
    /// Per-enqueue latencies, merged across producers.
    pub enqueue: Histogram,
    /// Per-successful-dequeue latencies, merged across consumers.
    pub dequeue: Histogram,
    /// Raw samples (for 3-sigma filtering), truncated per thread.
    pub enqueue_raw: Vec<u64>,
    /// Raw dequeue samples (for 3-sigma filtering), truncated per thread.
    pub dequeue_raw: Vec<u64>,
}

/// Run one throughput trial of `imp` at `pair`.
pub fn throughput_trial(imp: Impl, pair: PairConfig, cfg: &TrialConfig) -> ThroughputTrial {
    let queue: Arc<dyn ConcurrentQueue<u64>> = imp.make(cfg.capacity_hint);
    run_throughput_on(queue, pair, cfg)
}

/// Run one throughput trial against a caller-supplied queue (used by
/// the ablation benches to test specific CMP configurations).
pub fn run_throughput_on(
    queue: Arc<dyn ConcurrentQueue<u64>>,
    pair: PairConfig,
    cfg: &TrialConfig,
) -> ThroughputTrial {
    let per_producer = match cfg.scenario {
        // Idle offers no load at all; producers only hold the phase open.
        Scenario::Idle { .. } => 0,
        _ => (cfg.total_ops / pair.producers as u64).max(1),
    };
    let total = per_producer * pair.producers as u64;
    let consumed = Arc::new(AtomicU64::new(0));
    let producers_done = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(pair.producers + pair.consumers + 1));
    let load = cfg.load;
    let scenario = cfg.scenario;
    // Workers stamp the trial's start/end themselves: on an
    // oversubscribed single core the whole trial can finish before the
    // *main* thread (also a barrier participant) gets scheduled to read
    // a clock, which would report near-zero elapsed time.
    let anchor = crate::util::time::Anchor::now();
    let start_ns = Arc::new(AtomicU64::new(0));
    let end_ns = Arc::new(AtomicU64::new(0));
    fn stamp_start(anchor: crate::util::time::Anchor, s: &AtomicU64) {
        let now = anchor.ns().max(1);
        let _ = s.compare_exchange(0, now, Ordering::AcqRel, Ordering::Acquire);
    }

    let batch = cfg.batch_size.max(1);
    // Sojourn recording (DESIGN.md §14): when enabled, the payload *is*
    // the enqueue timestamp (the trial's own anchor clock), so each
    // consumed item yields one enqueue→dequeue sample with no side
    // table. Payload values are otherwise unobserved by the trial.
    let record = cfg.record_sojourn;
    let cap = cfg.max_samples_per_thread;
    let sojourn: Arc<std::sync::Mutex<Vec<u64>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    let cpu_before = process_cpu_seconds();

    let mut handles = Vec::with_capacity(pair.producers + pair.consumers);
    for p in 0..pair.producers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let producers_done = producers_done.clone();
        let (start_ns, end_ns) = (start_ns.clone(), end_ns.clone());
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            stamp_start(anchor, &start_ns);
            let base = p as u64 * per_producer;
            match scenario {
                Scenario::Idle { hold } => std::thread::sleep(hold),
                // Async consumers face the same full-speed offered
                // load as the closed loop.
                Scenario::ClosedLoop | Scenario::Async { .. } => {
                    if batch <= 1 {
                        for i in 0..per_producer {
                            load.run(i ^ (p as u64) << 32);
                            queue.enqueue(if record { anchor.ns() } else { base + i });
                        }
                    } else {
                        let mut i = 0u64;
                        while i < per_producer {
                            let k = (batch as u64).min(per_producer - i);
                            for j in 0..k {
                                load.run((i + j) ^ (p as u64) << 32);
                            }
                            let items: Vec<u64> = if record {
                                vec![anchor.ns(); k as usize]
                            } else {
                                (base + i..base + i + k).collect()
                            };
                            queue.enqueue_batch(items);
                            i += k;
                        }
                    }
                }
                Scenario::Bursty { burst, gap } => {
                    let burst = burst.max(1);
                    let mut i = 0u64;
                    while i < per_producer {
                        let burst_end = (i + burst).min(per_producer);
                        while i < burst_end {
                            let k = (batch as u64).min(burst_end - i);
                            for j in 0..k {
                                load.run((i + j) ^ (p as u64) << 32);
                            }
                            if k == 1 {
                                queue.enqueue(if record { anchor.ns() } else { base + i });
                            } else {
                                let items: Vec<u64> = if record {
                                    vec![anchor.ns(); k as usize]
                                } else {
                                    (base + i..base + i + k).collect()
                                };
                                queue.enqueue_batch(items);
                            }
                            i += k;
                        }
                        if i < per_producer {
                            std::thread::sleep(gap);
                        }
                    }
                }
            }
            producers_done.fetch_add(1, Ordering::AcqRel);
            end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
        }));
    }
    let n_producers = pair.producers as u64;
    for c in 0..pair.consumers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let consumed = consumed.clone();
        let producers_done = producers_done.clone();
        let sojourn = sojourn.clone();
        let (start_ns, end_ns) = (start_ns.clone(), end_ns.clone());
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            stamp_start(anchor, &start_ns);
            let mut salt = c as u64;
            let mut buf: Vec<u64> = Vec::with_capacity(batch);
            let mut lat: Vec<u64> = Vec::new();
            let closed_loop = scenario == Scenario::ClosedLoop;
            if closed_loop {
                let mut empty_streak = 0u32;
                loop {
                    let got = if batch <= 1 {
                        load.run(salt);
                        salt = salt.wrapping_add(0x9E37_79B9);
                        match queue.try_dequeue() {
                            Some(v) => {
                                if record && lat.len() < cap {
                                    lat.push(anchor.ns().saturating_sub(v));
                                }
                                1
                            }
                            None => 0,
                        }
                    } else {
                        let n = queue.try_dequeue_batch(batch, &mut buf);
                        if record {
                            let now = anchor.ns();
                            for &v in &buf {
                                if lat.len() >= cap {
                                    break;
                                }
                                lat.push(now.saturating_sub(v));
                            }
                        }
                        buf.clear();
                        // Run the inter-op load once per received item so
                        // synthetic-load regimes stay comparable per item.
                        for _ in 0..n.max(1) {
                            load.run(salt);
                            salt = salt.wrapping_add(0x9E37_79B9);
                        }
                        n
                    };
                    if got > 0 {
                        consumed.fetch_add(got as u64, Ordering::AcqRel);
                        empty_streak = 0;
                    } else {
                        if consumed.load(Ordering::Acquire) >= total {
                            break;
                        }
                        // Termination must not depend on `consumed`
                        // alone: CMP may *recover* a payload whose
                        // claimer was preempted past the window (§3.6),
                        // so `consumed` can stall below `total`.
                        if producers_done.load(Ordering::Acquire) == n_producers {
                            empty_streak += 1;
                            if empty_streak >= EMPTY_STREAK_EXIT {
                                break;
                            }
                        }
                        std::thread::yield_now();
                    }
                }
                end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
            } else if let Scenario::Async { tasks_per_consumer } = scenario {
                // Async consumer: one executor per consumer thread,
                // `tasks_per_consumer` tasks pulling via the async
                // dequeue in park slices (the slice bounds how quickly
                // the drain condition is re-checked, exactly like the
                // parking branch below).
                let mut ex = crate::util::Executor::new();
                let thread_claimed = Arc::new(AtomicU64::new(0));
                for t in 0..tasks_per_consumer.max(1) {
                    let queue = queue.clone();
                    let consumed = consumed.clone();
                    let producers_done = producers_done.clone();
                    let end_ns = end_ns.clone();
                    let thread_claimed = thread_claimed.clone();
                    let sojourn = sojourn.clone();
                    let mut salt = salt.wrapping_add(t as u64);
                    ex.spawn(async move {
                        let mut empty_slices = 0u32;
                        let mut tlat: Vec<u64> = Vec::new();
                        loop {
                            let slice_end = Instant::now() + PARK_SLICE;
                            match queue.pop_deadline_async(slice_end).await {
                                Some(v) => {
                                    load.run(salt);
                                    salt = salt.wrapping_add(0x9E37_79B9);
                                    if record && tlat.len() < cap {
                                        tlat.push(anchor.ns().saturating_sub(v));
                                    }
                                    consumed.fetch_add(1, Ordering::AcqRel);
                                    end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
                                    thread_claimed.fetch_add(1, Ordering::Relaxed);
                                    empty_slices = 0;
                                }
                                None => {
                                    if producers_done.load(Ordering::Acquire) == n_producers {
                                        empty_slices += 1;
                                        if empty_slices >= EMPTY_SLICE_EXIT {
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                        if !tlat.is_empty() {
                            sojourn.lock().expect("sojourn lock poisoned").extend(tlat);
                        }
                    });
                }
                ex.run();
                if thread_claimed.load(Ordering::Relaxed) == 0 {
                    end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
                }
            } else {
                // Parking consumer (bursty/idle scenarios): blocking
                // claims in park slices — asleep through the gaps,
                // woken by every push. The end stamp lands on the last
                // successful claim, NOT thread exit: the drain-detection
                // tail (EMPTY_SLICE_EXIT × PARK_SLICE after producers
                // finish) would otherwise inflate elapsed and deflate
                // the scenario's reported throughput.
                let mut empty_slices = 0u32;
                let mut claimed_any = false;
                loop {
                    let slice_end = Instant::now() + PARK_SLICE;
                    let n = queue.pop_deadline_batch(batch, &mut buf, slice_end);
                    if record {
                        let now = anchor.ns();
                        for &v in &buf {
                            if lat.len() >= cap {
                                break;
                            }
                            lat.push(now.saturating_sub(v));
                        }
                    }
                    buf.clear();
                    if n > 0 {
                        for _ in 0..n {
                            load.run(salt);
                            salt = salt.wrapping_add(0x9E37_79B9);
                        }
                        consumed.fetch_add(n as u64, Ordering::AcqRel);
                        end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
                        claimed_any = true;
                        empty_slices = 0;
                    } else if producers_done.load(Ordering::Acquire) == n_producers {
                        // A full slice expired with producers finished:
                        // treat as drained after a short streak (absorbs
                        // CMP claim races exactly like the closed loop).
                        empty_slices += 1;
                        if empty_slices >= EMPTY_SLICE_EXIT {
                            break;
                        }
                    }
                }
                // A consumer that never claimed (the idle scenario)
                // stamps at exit so elapsed covers the parked window it
                // was measured over.
                if !claimed_any {
                    end_ns.fetch_max(anchor.ns(), Ordering::AcqRel);
                }
            }
            if !lat.is_empty() {
                sojourn.lock().expect("sojourn lock poisoned").extend(lat);
            }
        }));
    }

    barrier.wait();
    for h in handles {
        h.join().expect("worker panicked");
    }
    let t0 = start_ns.load(Ordering::Acquire);
    let t1 = end_ns.load(Ordering::Acquire).max(t0 + 1);
    let elapsed = Duration::from_nanos(t1 - t0);
    let got = consumed.load(Ordering::Acquire);
    let cpu_seconds = match (cpu_before, process_cpu_seconds()) {
        (Some(a), Some(b)) => Some((b - a).max(0.0)),
        _ => None,
    };
    let threads = (pair.producers + pair.consumers) as f64;
    let sojourn_ns = std::mem::take(&mut *sojourn.lock().expect("sojourn lock poisoned"));
    ThroughputTrial {
        items: got,
        elapsed,
        items_per_sec: got as f64 / elapsed.as_secs_f64().max(1e-12),
        lost: total - got,
        cpu_seconds,
        ops_per_cpu_sec: cpu_seconds.and_then(|c| {
            if c > 0.0 {
                Some(got as f64 / c)
            } else {
                None
            }
        }),
        cpu_util: cpu_seconds.map(|c| c / (elapsed.as_secs_f64().max(1e-12) * threads)),
        sojourn_ns,
        control: queue.control_report(),
    }
}

/// Percentiles of a sojourn-sample pool: `(p50, p99, p99.9)` in
/// nanoseconds, or `None` for an empty pool. Sorts `samples` in place.
pub fn sojourn_percentiles(samples: &mut [u64]) -> Option<(u64, u64, u64)> {
    if samples.is_empty() {
        return None;
    }
    samples.sort_unstable();
    let at = |num: usize, den: usize| samples[(samples.len() - 1) * num / den];
    Some((at(50, 100), at(99, 100), at(999, 1000)))
}

/// Zipf(s) sampler over ranks `0..n` — the contention-skew knob: a
/// high exponent concentrates the probability mass on the first few
/// ranks (hot producers / hot shards), exponent 0 degenerates to
/// uniform. Inverse-CDF over a precomputed cumulative table, driven by
/// the crate's own [`crate::util::XorShift64`] so skewed workloads are
/// seed-replayable (no external rand crate in the offline image).
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Normalized cumulative distribution; `cdf[k]` = P(rank ≤ k).
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `s ≥ 0`
    /// (weight of rank `k` ∝ `(k+1)^-s`).
    ///
    /// # Panics
    /// If `n == 0`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over zero ranks");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc.max(f64::MIN_POSITIVE);
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn ranks(&self) -> usize {
        self.cdf.len()
    }

    /// Cumulative probability of ranks `0..=k` (clamped to the last
    /// rank, so `cdf(ranks() - 1) == 1.0`). Exposed for deterministic
    /// skew assertions: `s = 0` gives `cdf(k) = (k+1)/n`, and a larger
    /// exponent strictly raises every proper prefix's mass.
    pub fn cdf(&self, k: usize) -> f64 {
        self.cdf[k.min(self.cdf.len() - 1)]
    }

    /// Draw one rank in `0..ranks()`.
    pub fn sample(&self, rng: &mut crate::util::XorShift64) -> usize {
        let r = rng.next_f64();
        // First rank whose cumulative mass covers r.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&r).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// A [`ShardedCmp`] fabric whose *producers* route by zipf-sampled key
/// instead of the fabric's round-robin ticket — the contention-skew
/// knob for the sharded rows (workload fields `keys` / `zipf_s`): a
/// high exponent concentrates pushes on the low shards (hot-key
/// traffic), exponent 0 reproduces uniform spread. Dequeues delegate
/// to the fabric unchanged (affinity + steal-on-empty), so the row
/// measures exactly how skew degrades the fabric's scale-out.
///
/// Requires a `Relaxed` fabric: strict mode funnels every push through
/// shard 0's global ticket, which a direct-into-shard router would
/// bypass (breaking the strict-FIFO claim), so skew has no meaning
/// there.
pub struct ZipfRoutedFabric {
    fabric: ShardedCmp<u64>,
    zipf: Zipf,
}

impl ZipfRoutedFabric {
    /// Wrap `fabric` with zipf(`s`) routing over `keys` keys (keys map
    /// onto shards modulo the shard count).
    ///
    /// # Panics
    /// If the fabric is in strict mode or `keys == 0`.
    pub fn new(fabric: ShardedCmp<u64>, keys: usize, s: f64) -> Self {
        assert!(
            matches!(fabric.mode(), ShardMode::Relaxed { .. }),
            "zipf routing requires a relaxed fabric (strict routes via shard 0's ticket)"
        );
        assert!(keys > 0, "zipf routing over zero keys");
        ZipfRoutedFabric {
            fabric,
            zipf: Zipf::new(keys, s),
        }
    }

    /// Draw a key from the per-thread RNG and map it to a shard. Each
    /// thread seeds its own [`crate::util::XorShift64`] from a shared
    /// counter (odd-forced, so no thread lands on the all-zero state).
    fn route(&self) -> usize {
        use std::cell::RefCell;
        static ROUTE_SEED: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);
        thread_local! {
            static RNG: RefCell<crate::util::XorShift64> =
                RefCell::new(crate::util::XorShift64::new(
                    ROUTE_SEED.fetch_add(0x9E37_79B9, Ordering::Relaxed) | 1,
                ));
        }
        let key = RNG.with(|r| self.zipf.sample(&mut r.borrow_mut()));
        key % self.fabric.shard_count()
    }
}

impl ConcurrentQueue<u64> for ZipfRoutedFabric {
    fn try_enqueue(&self, item: u64) -> Result<(), u64> {
        self.fabric.shard(self.route()).push(item)?;
        // Direct-into-shard publishers must kick parked cross-shard
        // stealers themselves (the fabric's own push does this).
        self.fabric.notify_stealers();
        Ok(())
    }

    fn try_enqueue_batch(&self, items: Vec<u64>) -> Result<(), Vec<u64>> {
        // The whole batch lands on one shard: a batch models one
        // producer's run of same-key traffic.
        self.fabric.shard(self.route()).push_batch(items)?;
        self.fabric.notify_stealers();
        Ok(())
    }

    fn try_dequeue(&self) -> Option<u64> {
        self.fabric.try_dequeue()
    }

    fn try_dequeue_batch(&self, max: usize, out: &mut Vec<u64>) -> usize {
        self.fabric.try_dequeue_batch(max, out)
    }

    fn pop_blocking(&self) -> u64 {
        self.fabric.pop_blocking()
    }

    fn pop_deadline(&self, deadline: Instant) -> Option<u64> {
        self.fabric.pop_deadline(deadline)
    }

    fn pop_blocking_batch(&self, max: usize, out: &mut Vec<u64>) -> usize {
        self.fabric.pop_blocking_batch(max, out)
    }

    fn pop_deadline_batch(&self, max: usize, out: &mut Vec<u64>, deadline: Instant) -> usize {
        self.fabric.pop_deadline_batch(max, out, deadline)
    }

    fn pop_async(&self) -> BoxFuture<'_, u64> {
        self.fabric.pop_async()
    }

    fn pop_deadline_async(&self, deadline: Instant) -> BoxFuture<'_, Option<u64>> {
        self.fabric.pop_deadline_async(deadline)
    }

    fn pop_async_batch(&self, max: usize) -> BoxFuture<'_, Vec<u64>> {
        self.fabric.pop_async_batch(max)
    }

    fn wake_all(&self) {
        self.fabric.wake_all();
    }

    fn name(&self) -> &'static str {
        "sharded-zipf"
    }

    fn is_strict_fifo(&self) -> bool {
        false
    }

    fn is_lock_free(&self) -> bool {
        true
    }
}

/// Rank-error distribution of one dequeue history (BlockFIFO /
/// MultiFIFO methodology, arXiv:2507.22764): how far each element's
/// dequeue position strays from its global enqueue ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankErrorStats {
    /// Median |position − ticket|.
    pub p50: u64,
    /// 99th percentile |position − ticket|.
    pub p99: u64,
    /// Worst-case |position − ticket|.
    pub max: u64,
}

impl RankErrorStats {
    /// The all-zero distribution (what a strict FIFO must produce).
    pub fn zero() -> Self {
        RankErrorStats {
            p50: 0,
            p99: 0,
            max: 0,
        }
    }
}

/// Compute rank-error stats from per-consumer dequeue sequences of
/// *dense* tickets (every ticket in `0..total` appears exactly once
/// across all sequences).
///
/// Concurrent consumers give no total dequeue order, so one must be
/// reconstructed: this uses the **charitable linearization** — at each
/// step, take the smallest ticket among the consumers' next-undequeued
/// heads. Any such order is consistent with the per-consumer
/// observations; the charitable one lower-bounds the rank error, is
/// deterministic (stable across runs for given sequences), and makes
/// a strict FIFO score exactly zero: strict per-consumer sequences are
/// each increasing, so the greedy merge re-sorts them perfectly. The
/// strict-vs-relaxed *comparison* is what the bench charts, and both
/// sides use the same reconstruction.
pub fn rank_error_stats(seqs: &[Vec<u64>]) -> RankErrorStats {
    let total: usize = seqs.iter().map(|s| s.len()).sum();
    if total == 0 {
        return RankErrorStats::zero();
    }
    let mut heads = vec![0usize; seqs.len()];
    let mut errs: Vec<u64> = Vec::with_capacity(total);
    for pos in 0..total {
        let mut best: Option<(usize, u64)> = None;
        for (c, s) in seqs.iter().enumerate() {
            if let Some(&t) = s.get(heads[c]) {
                let better = match best {
                    None => true,
                    Some((_, bt)) => t < bt,
                };
                if better {
                    best = Some((c, t));
                }
            }
        }
        let (c, t) = best.expect("total counted non-empty heads");
        heads[c] += 1;
        errs.push((pos as i64 - t as i64).unsigned_abs());
    }
    errs.sort_unstable();
    let pct = |p: usize| errs[(errs.len() - 1) * p / 100];
    RankErrorStats {
        p50: pct(50),
        p99: pct(99),
        max: errs[errs.len() - 1],
    }
}

/// Result of a rank-error trial (the sharded fabric's quality axis).
#[derive(Debug, Clone, Copy)]
pub struct RankErrorTrial {
    /// Items actually dequeued (conservation check: == total enqueued).
    pub items: u64,
    /// Wall-clock throughput of the trial.
    pub items_per_sec: f64,
    /// Rank-error distribution of the dequeue history.
    pub stats: RankErrorStats,
}

/// Run a rank-error trial: `pair.producers` threads enqueue
/// `total_ops` globally-ticketed elements (one shared ticket counter —
/// the ticket *is* the payload), `pair.consumers` threads dequeue into
/// per-consumer logs, and the merged history is scored with
/// [`rank_error_stats`].
///
/// `serialize_stamps` controls the stamping discipline. A producer can
/// stall between drawing its ticket and enqueueing it, so with racy
/// stamping even a strict queue shows ~producer-count rank-error
/// noise that is the *harness's*, not the queue's. The correctness
/// oracle (`tests/sharded_fabric.rs`) passes `true` — ticket draw and
/// enqueue under one lock, so a strict queue must score exactly zero —
/// while the throughput bench passes `false` to keep the producer side
/// contention-honest for the rank-error-vs-ops/s chart.
pub fn rank_error_trial(
    queue: Arc<dyn ConcurrentQueue<u64>>,
    pair: PairConfig,
    total_ops: u64,
    serialize_stamps: bool,
) -> RankErrorTrial {
    let ticket = Arc::new(AtomicU64::new(0));
    let stamp_lock = Arc::new(std::sync::Mutex::new(()));
    let producers_done = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(pair.producers + pair.consumers + 1));
    let n_producers = pair.producers as u64;

    let mut prod_handles = Vec::with_capacity(pair.producers);
    for _ in 0..pair.producers {
        let queue = queue.clone();
        let ticket = ticket.clone();
        let producers_done = producers_done.clone();
        let barrier = barrier.clone();
        let stamp_lock = stamp_lock.clone();
        prod_handles.push(std::thread::spawn(move || {
            barrier.wait();
            loop {
                let guard = if serialize_stamps {
                    Some(stamp_lock.lock().expect("stamp lock poisoned"))
                } else {
                    None
                };
                let t = ticket.fetch_add(1, Ordering::AcqRel);
                if t >= total_ops {
                    break;
                }
                queue.enqueue(t);
                drop(guard);
            }
            producers_done.fetch_add(1, Ordering::AcqRel);
        }));
    }
    let mut cons_handles = Vec::with_capacity(pair.consumers);
    for _ in 0..pair.consumers {
        let queue = queue.clone();
        let producers_done = producers_done.clone();
        let barrier = barrier.clone();
        cons_handles.push(std::thread::spawn(move || {
            let mut log: Vec<u64> = Vec::new();
            barrier.wait();
            let mut empty_slices = 0u32;
            loop {
                match queue.pop_deadline(Instant::now() + Duration::from_millis(10)) {
                    Some(t) => {
                        log.push(t);
                        empty_slices = 0;
                    }
                    None => {
                        if producers_done.load(Ordering::Acquire) == n_producers {
                            empty_slices += 1;
                            if empty_slices >= EMPTY_SLICE_EXIT {
                                break;
                            }
                        }
                    }
                }
            }
            log
        }));
    }

    barrier.wait();
    let t0 = Instant::now();
    for h in prod_handles {
        h.join().expect("producer panicked");
    }
    let seqs: Vec<Vec<u64>> = cons_handles
        .into_iter()
        .map(|h| h.join().expect("consumer panicked"))
        .collect();
    let elapsed = t0.elapsed();
    let items: u64 = seqs.iter().map(|s| s.len() as u64).sum();
    RankErrorTrial {
        items,
        items_per_sec: items as f64 / elapsed.as_secs_f64().max(1e-12),
        stats: rank_error_stats(&seqs),
    }
}

/// Run one latency trial of `imp` at `pair`: every enqueue and every
/// successful dequeue is individually timed.
pub fn latency_trial(imp: Impl, pair: PairConfig, cfg: &TrialConfig) -> LatencyTrial {
    let queue: Arc<dyn ConcurrentQueue<u64>> = imp.make(cfg.capacity_hint);
    run_latency_on(queue, pair, cfg)
}

/// Latency trial against a caller-supplied queue.
pub fn run_latency_on(
    queue: Arc<dyn ConcurrentQueue<u64>>,
    pair: PairConfig,
    cfg: &TrialConfig,
) -> LatencyTrial {
    let per_producer = (cfg.total_ops / pair.producers as u64).max(1);
    let total = per_producer * pair.producers as u64;
    let consumed = Arc::new(AtomicU64::new(0));
    let producers_done = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(pair.producers + pair.consumers + 1));
    let load = cfg.load;
    let cap = cfg.max_samples_per_thread;

    let mut prod_handles = Vec::with_capacity(pair.producers);
    for p in 0..pair.producers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let producers_done = producers_done.clone();
        prod_handles.push(std::thread::spawn(move || {
            let mut hist = Histogram::new();
            let mut raw = Vec::with_capacity(per_producer.min(cap as u64) as usize);
            barrier.wait();
            for i in 0..per_producer {
                load.run(i);
                let t0 = Instant::now();
                queue.enqueue(p as u64 * per_producer + i);
                let ns = t0.elapsed().as_nanos() as u64;
                hist.record(ns);
                if raw.len() < cap {
                    raw.push(ns);
                }
            }
            producers_done.fetch_add(1, Ordering::AcqRel);
            (hist, raw)
        }));
    }
    let n_producers = pair.producers as u64;
    let mut cons_handles = Vec::with_capacity(pair.consumers);
    for _ in 0..pair.consumers {
        let queue = queue.clone();
        let barrier = barrier.clone();
        let consumed = consumed.clone();
        let producers_done = producers_done.clone();
        cons_handles.push(std::thread::spawn(move || {
            let mut hist = Histogram::new();
            let mut raw = Vec::new();
            barrier.wait();
            let mut salt = 0u64;
            let mut empty_streak = 0u32;
            loop {
                load.run(salt);
                salt = salt.wrapping_add(1);
                let t0 = Instant::now();
                let r = queue.try_dequeue();
                let ns = t0.elapsed().as_nanos() as u64;
                match r {
                    Some(_) => {
                        hist.record(ns);
                        if raw.len() < cap {
                            raw.push(ns);
                        }
                        consumed.fetch_add(1, Ordering::AcqRel);
                        empty_streak = 0;
                    }
                    None => {
                        if consumed.load(Ordering::Acquire) >= total {
                            break;
                        }
                        // See run_throughput_on: window-recovered
                        // payloads mean `consumed` can stall below
                        // `total` — terminate on producer completion +
                        // a sustained empty streak.
                        if producers_done.load(Ordering::Acquire) == n_producers {
                            empty_streak += 1;
                            if empty_streak >= EMPTY_STREAK_EXIT {
                                break;
                            }
                        }
                        std::thread::yield_now();
                    }
                }
            }
            (hist, raw)
        }));
    }

    barrier.wait();
    let mut enqueue = Histogram::new();
    let mut enqueue_raw = Vec::new();
    for h in prod_handles {
        let (hist, raw) = h.join().expect("producer panicked");
        enqueue.merge(&hist);
        enqueue_raw.extend(raw);
    }
    let mut dequeue = Histogram::new();
    let mut dequeue_raw = Vec::new();
    for h in cons_handles {
        let (hist, raw) = h.join().expect("consumer panicked");
        dequeue.merge(&hist);
        dequeue_raw.extend(raw);
    }
    LatencyTrial {
        enqueue,
        dequeue,
        enqueue_raw,
        dequeue_raw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TrialConfig {
        TrialConfig {
            total_ops: 4000,
            ..TrialConfig::default()
        }
    }

    #[test]
    fn pair_labels() {
        assert_eq!(PairConfig::symmetric(4).label(), "4P4C");
        let sweep = PairConfig::paper_sweep();
        assert_eq!(sweep.len(), 7);
        assert_eq!(sweep[0].label(), "1P1C");
        assert_eq!(sweep[6].label(), "64P64C");
    }

    #[test]
    fn throughput_trial_conserves_items() {
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &small_cfg());
        assert_eq!(t.items, 4000);
        assert!(t.items_per_sec > 0.0);
        assert!(t.elapsed > Duration::ZERO);
    }

    #[test]
    fn batched_throughput_trial_conserves_items() {
        for batch in [8usize, 64] {
            let cfg = TrialConfig {
                total_ops: 4000,
                batch_size: batch,
                ..TrialConfig::default()
            };
            let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 4000, "batch={batch}");
            assert_eq!(t.lost, 0, "batch={batch}");
        }
    }

    #[test]
    fn batched_trial_works_for_default_impls_too() {
        // Baselines ride the trait's default batch methods.
        let cfg = TrialConfig {
            total_ops: 4000,
            batch_size: 8,
            ..TrialConfig::default()
        };
        for imp in [Impl::Mutex, Impl::Segmented, Impl::Vyukov] {
            let t = throughput_trial(imp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 4000, "{}", imp.name());
        }
    }

    #[test]
    fn throughput_trial_all_impls_1p1c() {
        for imp in Impl::ALL {
            let t = throughput_trial(imp, PairConfig::symmetric(1), &small_cfg());
            assert_eq!(t.items, 4000, "{}", imp.name());
        }
    }

    #[test]
    fn latency_trial_counts_match() {
        let t = latency_trial(Impl::Cmp, PairConfig::symmetric(2), &small_cfg());
        assert_eq!(t.enqueue.count(), 4000);
        assert_eq!(t.dequeue.count(), 4000);
        assert_eq!(t.enqueue_raw.len(), 4000);
        assert_eq!(t.dequeue_raw.len(), 4000);
        assert!(t.enqueue.mean() > 0.0);
    }

    #[test]
    fn bursty_trial_conserves_items() {
        let cfg = TrialConfig {
            total_ops: 2000,
            scenario: Scenario::Bursty {
                burst: 256,
                gap: Duration::from_millis(1),
            },
            ..TrialConfig::default()
        };
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
        assert_eq!(t.items, 2000);
        assert_eq!(t.lost, 0);
    }

    #[test]
    fn bursty_trial_works_for_default_impls_too() {
        // Baselines ride the trait's default (polling) deadline pops.
        let cfg = TrialConfig {
            total_ops: 2000,
            batch_size: 8,
            scenario: Scenario::Bursty {
                burst: 128,
                gap: Duration::from_millis(1),
            },
            ..TrialConfig::default()
        };
        for imp in [Impl::Mutex, Impl::Segmented] {
            let t = throughput_trial(imp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 2000, "{}", imp.name());
        }
    }

    #[test]
    fn async_trial_conserves_items() {
        let cfg = TrialConfig {
            total_ops: 2000,
            scenario: Scenario::Async {
                tasks_per_consumer: 4,
            },
            ..TrialConfig::default()
        };
        // CMP rides real waker wakeups; Mutex rides the polling
        // default — both must conserve items.
        for imp in [Impl::Cmp, Impl::Mutex] {
            let t = throughput_trial(imp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 2000, "{}", imp.name());
            assert_eq!(t.lost, 0, "{}", imp.name());
            assert!(t.items_per_sec > 0.0, "{}", imp.name());
        }
    }

    #[test]
    fn idle_trial_parks_consumers() {
        let cfg = TrialConfig {
            scenario: Scenario::Idle {
                hold: Duration::from_millis(150),
            },
            ..TrialConfig::default()
        };
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
        assert_eq!(t.items, 0, "zero offered load");
        assert_eq!(t.lost, 0);
        assert!(t.elapsed >= Duration::from_millis(150));
        // CPU accounting is process-wide, and `cargo test` runs other
        // tests concurrently in this process — so no tight bound here
        // (the <5%-per-core idle-floor claim is measured by the
        // throughput bench's idle scenario, which runs alone). Just
        // check the metric is present and sane on Linux.
        if let Some(util) = t.cpu_util {
            assert!(util >= 0.0, "cpu_util must be non-negative: {util}");
        }
    }

    #[test]
    fn scenario_labels() {
        assert_eq!(Scenario::ClosedLoop.label(), "closed");
        assert_eq!(
            Scenario::Bursty {
                burst: 1,
                gap: Duration::ZERO
            }
            .label(),
            "bursty"
        );
        assert_eq!(
            Scenario::Idle {
                hold: Duration::ZERO
            }
            .label(),
            "idle"
        );
        assert_eq!(
            Scenario::Async {
                tasks_per_consumer: 4
            }
            .label(),
            "async"
        );
    }

    #[test]
    fn synthetic_load_slows_throughput() {
        let base = throughput_trial(Impl::Cmp, PairConfig::symmetric(1), &small_cfg());
        let loaded_cfg = TrialConfig {
            total_ops: 4000,
            load: LoadProfile::Synthetic(64),
            ..TrialConfig::default()
        };
        let loaded = throughput_trial(Impl::Cmp, PairConfig::symmetric(1), &loaded_cfg);
        assert!(
            loaded.items_per_sec < base.items_per_sec,
            "load must reduce throughput ({} vs {})",
            loaded.items_per_sec,
            base.items_per_sec
        );
    }

    #[test]
    fn zipf_sampler_is_skewed_and_in_range() {
        let mut rng = crate::util::XorShift64::new(42);
        let z = Zipf::new(8, 1.5);
        assert_eq!(z.ranks(), 8);
        let mut counts = [0u64; 8];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 dominates under a 1.5 exponent; every rank is legal.
        assert!(counts[0] > counts[7] * 4, "not skewed: {counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "rank starved: {counts:?}");
    }

    #[test]
    fn zipf_exponent_zero_is_roughly_uniform() {
        let mut rng = crate::util::XorShift64::new(7);
        let z = Zipf::new(4, 0.0);
        let mut counts = [0u64; 4];
        for _ in 0..40_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "not uniform: {counts:?}");
        }
    }

    #[test]
    fn rank_error_of_strict_histories_is_zero() {
        // Increasing per-consumer sequences (what a strict FIFO
        // produces) must merge to exactly the ticket order.
        let seqs = vec![vec![0, 3, 4, 7], vec![1, 2, 5, 6]];
        assert_eq!(rank_error_stats(&seqs), RankErrorStats::zero());
        assert_eq!(rank_error_stats(&[]), RankErrorStats::zero());
    }

    #[test]
    fn rank_error_detects_reordering() {
        // Single consumer that saw ticket 4 first: position 0 holds
        // ticket 4 (err 4) and every later ticket slips by one.
        let seqs = vec![vec![4, 0, 1, 2, 3]];
        let stats = rank_error_stats(&seqs);
        assert_eq!(stats.max, 4);
        assert!(stats.p99 >= 1);
    }

    #[test]
    fn rank_error_trial_strict_sharded_is_zero() {
        let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Sharded.make(1 << 16);
        let t = rank_error_trial(q, PairConfig::symmetric(2), 4000, true);
        assert_eq!(t.items, 4000, "conservation");
        assert_eq!(t.stats, RankErrorStats::zero());
        assert!(t.items_per_sec > 0.0);
    }

    #[test]
    fn uneven_ops_round_down_consistently() {
        let cfg = TrialConfig {
            total_ops: 1001,
            ..TrialConfig::default()
        };
        let t = throughput_trial(Impl::Mutex, PairConfig::symmetric(3), &cfg);
        assert_eq!(t.items, 999, "333 per producer × 3");
    }

    #[test]
    fn sojourn_recording_yields_one_sample_per_item() {
        let cfg = TrialConfig {
            total_ops: 2000,
            record_sojourn: true,
            scenario: Scenario::Bursty {
                burst: 256,
                gap: Duration::from_millis(1),
            },
            ..TrialConfig::default()
        };
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
        assert_eq!(t.items, 2000);
        assert_eq!(t.sojourn_ns.len(), 2000, "one sample per consumed item");
        let mut s = t.sojourn_ns.clone();
        let (p50, p99, p999) = sojourn_percentiles(&mut s).expect("non-empty pool");
        assert!(p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
    }

    #[test]
    fn sojourn_recording_covers_closed_and_async_paths() {
        for (scenario, batch) in [
            (Scenario::ClosedLoop, 1usize),
            (Scenario::ClosedLoop, 8),
            (
                Scenario::Async {
                    tasks_per_consumer: 2,
                },
                1,
            ),
        ] {
            let cfg = TrialConfig {
                total_ops: 1000,
                record_sojourn: true,
                batch_size: batch,
                scenario,
                ..TrialConfig::default()
            };
            let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 1000, "{scenario:?} batch={batch}");
            assert_eq!(t.sojourn_ns.len(), 1000, "{scenario:?} batch={batch}");
        }
    }

    #[test]
    fn sojourn_off_records_nothing() {
        let t = throughput_trial(Impl::Cmp, PairConfig::symmetric(1), &small_cfg());
        assert!(t.sojourn_ns.is_empty());
        assert_eq!(sojourn_percentiles(&mut []), None);
    }

    #[test]
    fn sojourn_percentiles_sort_and_index() {
        let mut v: Vec<u64> = (1..=1000).rev().collect();
        let (p50, p99, p999) = sojourn_percentiles(&mut v).unwrap();
        assert_eq!(p50, 500);
        assert_eq!(p99, 990);
        assert_eq!(p999, 999);
    }

    #[test]
    fn zipf_cdf_accessor_uniform_and_skewed() {
        let u = Zipf::new(10, 0.0);
        assert!((u.cdf(4) - 0.5).abs() < 1e-9);
        assert!((u.cdf(9) - 1.0).abs() < 1e-9);
        let z = Zipf::new(10, 1.5);
        assert!(z.cdf(0) > u.cdf(0), "skew concentrates mass on rank 0");
    }

    #[test]
    fn zipf_routed_fabric_conserves_items() {
        use crate::queue::sharded::ShardedConfig;
        for batch in [1usize, 8] {
            let fabric = ShardedCmp::with_config(
                ShardedConfig::default()
                    .with_shards(4)
                    .with_mode(ShardMode::Relaxed {
                        max_rank_error: 4096,
                    }),
            );
            let q: Arc<dyn ConcurrentQueue<u64>> =
                Arc::new(ZipfRoutedFabric::new(fabric, 64, 1.2));
            let cfg = TrialConfig {
                total_ops: 4000,
                batch_size: batch,
                ..TrialConfig::default()
            };
            let t = run_throughput_on(q, PairConfig::symmetric(2), &cfg);
            assert_eq!(t.items, 4000, "batch={batch}");
            assert_eq!(t.lost, 0, "batch={batch}");
        }
    }

    #[test]
    #[should_panic(expected = "relaxed fabric")]
    fn zipf_routed_fabric_rejects_strict_mode() {
        use crate::queue::sharded::ShardedConfig;
        let fabric = ShardedCmp::with_config(ShardedConfig::default().with_shards(2));
        let _ = ZipfRoutedFabric::new(fabric, 8, 1.0);
    }
}
