//! Declarative workload specs: the scenario-file library behind
//! `repro bench --workload <file>` / `--workload-dir <dir>` and the
//! `benches/throughput.rs` entry point (DESIGN.md §14).
//!
//! A workload is one JSON object (`workloads/*.json` at the repo root)
//! describing *what to measure* — target transport, implementations,
//! producer/consumer pairs, arrival process, batch mix, contention
//! skew — so the bench matrix lives in committed data instead of
//! compiled-in axes. Parsing is strict: unknown keys are rejected **by
//! name**, so a typo'd knob fails loudly instead of silently running
//! the default. The parser is the in-tree [`crate::util::json`] — no
//! serde in the offline image.
//!
//! Every field has a default (see the field docs), so the smallest
//! legal spec is `{"name":"my-workload"}` — a closed-loop sweep of the
//! paper's comparator set. [`WorkloadSpec::to_json`] emits every field
//! explicitly, and `parse(spec.to_json()) == spec` round-trips exactly
//! (asserted for every committed spec by `tests/workload_spec.rs`).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use super::report::json_escape;
use super::workload::{PairConfig, Scenario};
use crate::queue::Impl;
use crate::util::json::Json;

/// Transport a workload drives (the `target` spec field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// In-process queue trials (the default).
    Queue,
    /// The coordinator serving pipeline (router → batcher → workers)
    /// driven by in-process closed-loop clients.
    Coordinator,
    /// The TCP ingress (DESIGN.md §12) in front of the coordinator,
    /// driven by blocking loopback clients speaking the wire format.
    Tcp,
}

impl Target {
    /// Spec-file name of the target.
    pub fn name(&self) -> &'static str {
        match self {
            Target::Queue => "queue",
            Target::Coordinator => "coordinator",
            Target::Tcp => "tcp",
        }
    }

    fn parse(s: &str) -> Result<Target, String> {
        match s {
            "queue" => Ok(Target::Queue),
            "coordinator" => Ok(Target::Coordinator),
            "tcp" => Ok(Target::Tcp),
            other => Err(format!("unknown target {other:?} (queue|coordinator|tcp)")),
        }
    }
}

/// What a queue-target workload measures (the `measure` spec field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Measure {
    /// Wall-clock throughput (+ CPU efficiency and, for open-loop
    /// arrivals, sojourn-latency percentiles). The default.
    Throughput,
    /// The sharded fabric's ordering-quality axis: rank error vs
    /// throughput across a `sweep_max_rank_error` sweep (DESIGN.md
    /// §13). Requires `impls == ["sharded"]`.
    RankError,
}

impl Measure {
    /// Spec-file name of the measure.
    pub fn name(&self) -> &'static str {
        match self {
            Measure::Throughput => "throughput",
            Measure::RankError => "rank_error",
        }
    }

    fn parse(s: &str) -> Result<Measure, String> {
        match s {
            "throughput" => Ok(Measure::Throughput),
            "rank_error" => Ok(Measure::RankError),
            other => Err(format!("unknown measure {other:?} (throughput|rank_error)")),
        }
    }
}

/// Arrival process of a queue workload (the `arrival` spec object,
/// `{"kind": ..., ...}`). Maps onto the trial engine's
/// [`Scenario`] axis; see DESIGN.md §14 for why latency percentiles
/// are reported from the open-loop kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Closed loop (the default): producers enqueue as fast as they
    /// can, consumers spin-poll. Peak throughput, no honest latency.
    Closed,
    /// Open loop: bursts with idle gaps; consumers park between
    /// bursts. The latency-measuring arrival.
    Open {
        /// Items emitted per burst, per producer.
        burst: u64,
        /// Idle milliseconds between bursts.
        gap_ms: u64,
    },
    /// Zero offered load: consumers park for `hold_ms` against an
    /// empty queue (the idle CPU floor).
    Idle {
        /// Milliseconds consumers face the empty queue.
        hold_ms: u64,
    },
    /// Closed-loop producers, async-task consumers riding the §10
    /// waker bridge.
    Async {
        /// Consumer tasks multiplexed per consumer thread.
        tasks_per_consumer: usize,
    },
}

impl Arrival {
    /// The trial-engine scenario this arrival process maps to.
    pub fn scenario(&self) -> Scenario {
        match *self {
            Arrival::Closed => Scenario::ClosedLoop,
            Arrival::Open { burst, gap_ms } => Scenario::Bursty {
                burst,
                gap: Duration::from_millis(gap_ms),
            },
            Arrival::Idle { hold_ms } => Scenario::Idle {
                hold: Duration::from_millis(hold_ms),
            },
            Arrival::Async { tasks_per_consumer } => Scenario::Async { tasks_per_consumer },
        }
    }

    /// Report label (`closed` / `bursty` / `idle` / `async`).
    pub fn label(&self) -> &'static str {
        self.scenario().label()
    }

    /// Whether this arrival is open-loop enough for honest sojourn
    /// latency (DESIGN.md §14) — the default for the `latency` field.
    pub fn measures_latency(&self) -> bool {
        matches!(self, Arrival::Open { .. } | Arrival::Async { .. })
    }

    fn from_json(v: &Json) -> Result<Arrival, String> {
        let Json::Obj(map) = v else {
            return Err("\"arrival\" must be an object".into());
        };
        let kind = map
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or("\"arrival\" needs a string \"kind\"")?;
        let allowed: &[&str] = match kind {
            "closed" => &["kind"],
            "open" => &["kind", "burst", "gap_ms"],
            "idle" => &["kind", "hold_ms"],
            "async" => &["kind", "tasks_per_consumer"],
            other => {
                return Err(format!(
                    "unknown arrival kind {other:?} (closed|open|idle|async)"
                ))
            }
        };
        for k in map.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown key {k:?} in \"arrival\" (kind {kind})"));
            }
        }
        match kind {
            "closed" => Ok(Arrival::Closed),
            "open" => Ok(Arrival::Open {
                burst: obj_u64(map, "burst")?.unwrap_or(512).max(1),
                gap_ms: obj_u64(map, "gap_ms")?.unwrap_or(2),
            }),
            "idle" => Ok(Arrival::Idle {
                hold_ms: obj_u64(map, "hold_ms")?.unwrap_or(400).max(1),
            }),
            _ => Ok(Arrival::Async {
                tasks_per_consumer: obj_u64(map, "tasks_per_consumer")?.unwrap_or(4).max(1)
                    as usize,
            }),
        }
    }

    fn write_json(&self, out: &mut String) {
        use std::fmt::Write as _;
        match *self {
            Arrival::Closed => out.push_str("{\"kind\":\"closed\"}"),
            Arrival::Open { burst, gap_ms } => {
                let _ = write!(out, "{{\"kind\":\"open\",\"burst\":{burst},\"gap_ms\":{gap_ms}}}");
            }
            Arrival::Idle { hold_ms } => {
                let _ = write!(out, "{{\"kind\":\"idle\",\"hold_ms\":{hold_ms}}}");
            }
            Arrival::Async { tasks_per_consumer } => {
                let _ = write!(
                    out,
                    "{{\"kind\":\"async\",\"tasks_per_consumer\":{tasks_per_consumer}}}"
                );
            }
        }
    }
}

/// One declarative workload: everything a bench run needs, parsed from
/// a `workloads/*.json` file. See the module docs for the grammar and
/// README "Workloads" for the schema table.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Workload name — the report/diff row key prefix. Required.
    pub name: String,
    /// Transport under test. Default `queue`.
    pub target: Target,
    /// What to measure (queue target only). Default `throughput`.
    pub measure: Measure,
    /// Queue implementations to sweep. Default: the bench comparator
    /// set `[cmp, segmented, ms-hp, mutex]`.
    pub impls: Vec<Impl>,
    /// Producer/consumer pairs: a JSON entry is either `N` (symmetric
    /// NPNC) or `[P, C]`. Default `[1, 4]`.
    pub pairs: Vec<PairConfig>,
    /// Pairs used when running with `--smoke`. Default: same as
    /// `pairs` — set a subset so CI smoke keys stay a subset of a
    /// full run's.
    pub smoke_pairs: Vec<PairConfig>,
    /// Items per trial (requests per run for coordinator/tcp).
    /// Default 60 000.
    pub ops: u64,
    /// `ops` override when running with `--smoke` (the CI knob).
    /// Default `max(ops / 10, 1000)`.
    pub smoke_ops: u64,
    /// Measured rounds per cell. Default 3.
    pub rounds: usize,
    /// Unmeasured warmup rounds per cell. Default 1.
    pub warmup_rounds: usize,
    /// Operation batch-size mix (the amortization axis). Default `[1]`.
    pub batches: Vec<usize>,
    /// Arrival process. Default closed-loop.
    pub arrival: Arrival,
    /// Key-space size for zipf-skewed shard routing; 0 (default)
    /// disables skew. Non-zero requires `impls == ["sharded"]` — key
    /// skew only changes contention when keys route to shards.
    pub keys: usize,
    /// Zipf exponent over `keys` (0 = uniform). Default 0.
    pub zipf_s: f64,
    /// Record per-item sojourn latency and report p50/p99/p99.9.
    /// Default: `true` for open/async arrivals, `false` otherwise
    /// (closed-loop percentiles suffer coordinated omission —
    /// DESIGN.md §14 — and recording distorts peak-throughput rows).
    pub latency: bool,
    /// Shard count for sharded-fabric workloads (and coordinator
    /// request-fabric shards). Default 4.
    pub shards: usize,
    /// Rank-error bound for zipf-routed relaxed fabrics
    /// (`keys > 0`). Default 4096.
    pub max_rank_error: u64,
    /// `max_rank_error` sweep for `measure = "rank_error"`: one row
    /// per value, `0` meaning strict mode. Default `[0, 4096]`.
    pub sweep_max_rank_error: Vec<u64>,
    /// Client threads (coordinator/tcp targets). Default 8.
    pub clients: usize,
    /// Worker threads (coordinator/tcp targets). Default 2.
    pub workers: usize,
    /// I/O threads (tcp target). Default 2.
    pub io_threads: usize,
    /// Request feature width (coordinator/tcp targets). Default 64.
    pub features: usize,
    /// Capacity hint for bounded comparators. Default 65 536.
    pub capacity_hint: usize,
}

/// Every key [`WorkloadSpec::from_json`] accepts at the top level;
/// anything else is rejected by name.
const KNOWN_KEYS: &[&str] = &[
    "name",
    "target",
    "measure",
    "impls",
    "pairs",
    "smoke_pairs",
    "ops",
    "smoke_ops",
    "rounds",
    "warmup_rounds",
    "batches",
    "arrival",
    "keys",
    "zipf_s",
    "latency",
    "shards",
    "max_rank_error",
    "sweep_max_rank_error",
    "clients",
    "workers",
    "io_threads",
    "features",
    "capacity_hint",
];

fn obj_u64(map: &BTreeMap<String, Json>, k: &str) -> Result<Option<u64>, String> {
    match map.get(k) {
        None => Ok(None),
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| format!("{k:?} must be a number"))?;
            if n < 0.0 {
                return Err(format!("{k:?} must be non-negative"));
            }
            Ok(Some(n as u64))
        }
    }
}

fn obj_f64(map: &BTreeMap<String, Json>, k: &str) -> Result<Option<f64>, String> {
    match map.get(k) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("{k:?} must be a number")),
    }
}

fn obj_bool(map: &BTreeMap<String, Json>, k: &str) -> Result<Option<bool>, String> {
    match map.get(k) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(format!("{k:?} must be a boolean")),
    }
}

fn obj_u64_list(map: &BTreeMap<String, Json>, k: &str) -> Result<Option<Vec<u64>>, String> {
    match map.get(k) {
        None => Ok(None),
        Some(v) => {
            let ns = v
                .as_f64_vec()
                .ok_or_else(|| format!("{k:?} must be an array of numbers"))?;
            if ns.iter().any(|&n| n < 0.0) {
                return Err(format!("{k:?} entries must be non-negative"));
            }
            Ok(Some(ns.into_iter().map(|n| n as u64).collect()))
        }
    }
}

fn parse_pair_list(
    map: &BTreeMap<String, Json>,
    k: &str,
) -> Result<Option<Vec<PairConfig>>, String> {
    let Some(v) = map.get(k) else {
        return Ok(None);
    };
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("{k:?} must be an array of N or [P, C] entries"))?;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        match e {
            Json::Num(n) if *n >= 1.0 => out.push(PairConfig::symmetric(*n as usize)),
            Json::Arr(pc) if pc.len() == 2 => {
                let p = pc[0].as_usize().filter(|&p| p >= 1);
                let c = pc[1].as_usize().filter(|&c| c >= 1);
                match (p, c) {
                    (Some(producers), Some(consumers)) => out.push(PairConfig {
                        producers,
                        consumers,
                    }),
                    _ => {
                        return Err(format!(
                            "{k:?} [P, C] entries must be two positive integers"
                        ))
                    }
                }
            }
            _ => {
                return Err(format!(
                    "{k:?} entries must be a positive integer N or a [P, C] pair"
                ))
            }
        }
    }
    if out.is_empty() {
        return Err(format!("{k:?} must not be empty"));
    }
    Ok(Some(out))
}

impl WorkloadSpec {
    /// Parse one workload spec from JSON text.
    pub fn parse(text: &str) -> Result<WorkloadSpec, String> {
        let json = Json::parse(text).map_err(|e| format!("workload spec: {e}"))?;
        Self::from_json(&json)
    }

    /// Parse from an already-parsed [`Json`] value. Unknown keys —
    /// top-level or inside `arrival` — are rejected with the offending
    /// key named; combination rules are enforced by
    /// [`WorkloadSpec::validate`].
    pub fn from_json(json: &Json) -> Result<WorkloadSpec, String> {
        let Json::Obj(map) = json else {
            return Err("workload spec: top level is not an object".into());
        };
        for k in map.keys() {
            if !KNOWN_KEYS.contains(&k.as_str()) {
                return Err(format!("workload spec: unknown key {k:?}"));
            }
        }
        let name = map
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or("workload spec: missing required string \"name\"")?
            .to_string();
        let err = |e: String| format!("workload {name:?}: {e}");

        let target = match map.get("target") {
            None => Target::Queue,
            Some(v) => Target::parse(
                v.as_str()
                    .ok_or_else(|| err("\"target\" must be a string".into()))?,
            )
            .map_err(err)?,
        };
        let measure = match map.get("measure") {
            None => Measure::Throughput,
            Some(v) => Measure::parse(
                v.as_str()
                    .ok_or_else(|| err("\"measure\" must be a string".into()))?,
            )
            .map_err(err)?,
        };
        let impls = match map.get("impls") {
            None => vec![Impl::Cmp, Impl::Segmented, Impl::MsHp, Impl::Mutex],
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| err("\"impls\" must be an array of strings".into()))?;
                let mut out = Vec::with_capacity(arr.len());
                for e in arr {
                    let s = e
                        .as_str()
                        .ok_or_else(|| err("\"impls\" entries must be strings".into()))?;
                    out.push(
                        Impl::parse(s).ok_or_else(|| err(format!("unknown impl {s:?}")))?,
                    );
                }
                if out.is_empty() {
                    return Err(err("\"impls\" must not be empty".into()));
                }
                out
            }
        };
        let pairs = parse_pair_list(map, "pairs")
            .map_err(err)?
            .unwrap_or_else(|| vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]);
        let smoke_pairs = parse_pair_list(map, "smoke_pairs")
            .map_err(err)?
            .unwrap_or_else(|| pairs.clone());
        let ops = obj_u64(map, "ops").map_err(err)?.unwrap_or(60_000).max(1);
        let smoke_ops = obj_u64(map, "smoke_ops")
            .map_err(err)?
            .unwrap_or((ops / 10).max(1000))
            .max(1);
        let rounds = obj_u64(map, "rounds").map_err(err)?.unwrap_or(3).max(1) as usize;
        let warmup_rounds = obj_u64(map, "warmup_rounds").map_err(err)?.unwrap_or(1) as usize;
        let batches = match obj_u64_list(map, "batches").map_err(err)? {
            None => vec![1usize],
            Some(bs) => {
                if bs.is_empty() || bs.iter().any(|&b| b == 0) {
                    return Err(err("\"batches\" must be non-empty positive integers".into()));
                }
                bs.into_iter().map(|b| b as usize).collect()
            }
        };
        let arrival = match map.get("arrival") {
            None => Arrival::Closed,
            Some(v) => Arrival::from_json(v).map_err(err)?,
        };
        let keys = obj_u64(map, "keys").map_err(err)?.unwrap_or(0) as usize;
        let zipf_s = obj_f64(map, "zipf_s").map_err(err)?.unwrap_or(0.0);
        let latency = obj_bool(map, "latency")
            .map_err(err)?
            .unwrap_or_else(|| arrival.measures_latency());
        let shards = obj_u64(map, "shards").map_err(err)?.unwrap_or(4).max(1) as usize;
        let max_rank_error = obj_u64(map, "max_rank_error")
            .map_err(err)?
            .unwrap_or(4096)
            .max(1);
        let sweep_max_rank_error = obj_u64_list(map, "sweep_max_rank_error")
            .map_err(err)?
            .unwrap_or_else(|| vec![0, 4096]);
        let clients = obj_u64(map, "clients").map_err(err)?.unwrap_or(8).max(1) as usize;
        let workers = obj_u64(map, "workers").map_err(err)?.unwrap_or(2).max(1) as usize;
        let io_threads = obj_u64(map, "io_threads").map_err(err)?.unwrap_or(2).max(1) as usize;
        let features = obj_u64(map, "features").map_err(err)?.unwrap_or(64).max(1) as usize;
        let capacity_hint = obj_u64(map, "capacity_hint")
            .map_err(err)?
            .unwrap_or(1 << 16)
            .max(1) as usize;

        let spec = WorkloadSpec {
            name,
            target,
            measure,
            impls,
            pairs,
            smoke_pairs,
            ops,
            smoke_ops,
            rounds,
            warmup_rounds,
            batches,
            arrival,
            keys,
            zipf_s,
            latency,
            shards,
            max_rank_error,
            sweep_max_rank_error,
            clients,
            workers,
            io_threads,
            features,
            capacity_hint,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Combination rules a structurally-valid spec must still satisfy.
    /// Called by [`WorkloadSpec::from_json`]; public so tests can
    /// probe the rules directly.
    pub fn validate(&self) -> Result<(), String> {
        let err = |e: &str| Err(format!("workload {:?}: {e}", self.name));
        if self.name.is_empty() {
            return err("\"name\" must not be empty");
        }
        if self.measure == Measure::RankError {
            if self.target != Target::Queue {
                return err("measure \"rank_error\" requires target \"queue\"");
            }
            if self.impls != [Impl::Sharded] {
                return err("measure \"rank_error\" requires impls [\"sharded\"]");
            }
            if self.sweep_max_rank_error.is_empty() {
                return err("measure \"rank_error\" requires a non-empty sweep_max_rank_error");
            }
        }
        if self.keys > 0 {
            if self.impls != [Impl::Sharded] {
                return err("\"keys\" (zipf routing) requires impls [\"sharded\"]");
            }
            if self.measure != Measure::Throughput {
                return err("\"keys\" (zipf routing) requires measure \"throughput\"");
            }
        }
        if self.zipf_s != 0.0 {
            if self.zipf_s < 0.0 {
                return err("\"zipf_s\" must be non-negative");
            }
            if self.keys == 0 {
                return err("\"zipf_s\" requires \"keys\" > 0");
            }
        }
        Ok(())
    }

    /// Serialize back to JSON with every field explicit, such that
    /// `parse(spec.to_json()) == spec`.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        fn write_pairs(out: &mut String, pairs: &[PairConfig]) {
            use std::fmt::Write as _;
            out.push('[');
            for (i, p) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if p.producers == p.consumers {
                    let _ = write!(out, "{}", p.producers);
                } else {
                    let _ = write!(out, "[{},{}]", p.producers, p.consumers);
                }
            }
            out.push(']');
        }
        let mut s = String::from("{");
        let _ = write!(s, "\"name\":\"{}\"", json_escape(&self.name));
        let _ = write!(s, ",\"target\":\"{}\"", self.target.name());
        let _ = write!(s, ",\"measure\":\"{}\"", self.measure.name());
        s.push_str(",\"impls\":[");
        for (i, imp) in self.impls.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{}\"", imp.name());
        }
        s.push(']');
        s.push_str(",\"pairs\":");
        write_pairs(&mut s, &self.pairs);
        s.push_str(",\"smoke_pairs\":");
        write_pairs(&mut s, &self.smoke_pairs);
        let _ = write!(s, ",\"ops\":{}", self.ops);
        let _ = write!(s, ",\"smoke_ops\":{}", self.smoke_ops);
        let _ = write!(s, ",\"rounds\":{}", self.rounds);
        let _ = write!(s, ",\"warmup_rounds\":{}", self.warmup_rounds);
        s.push_str(",\"batches\":[");
        for (i, b) in self.batches.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{b}");
        }
        s.push(']');
        s.push_str(",\"arrival\":");
        self.arrival.write_json(&mut s);
        let _ = write!(s, ",\"keys\":{}", self.keys);
        let _ = write!(s, ",\"zipf_s\":{}", self.zipf_s);
        let _ = write!(s, ",\"latency\":{}", self.latency);
        let _ = write!(s, ",\"shards\":{}", self.shards);
        let _ = write!(s, ",\"max_rank_error\":{}", self.max_rank_error);
        s.push_str(",\"sweep_max_rank_error\":[");
        for (i, k) in self.sweep_max_rank_error.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{k}");
        }
        s.push(']');
        let _ = write!(s, ",\"clients\":{}", self.clients);
        let _ = write!(s, ",\"workers\":{}", self.workers);
        let _ = write!(s, ",\"io_threads\":{}", self.io_threads);
        let _ = write!(s, ",\"features\":{}", self.features);
        let _ = write!(s, ",\"capacity_hint\":{}", self.capacity_hint);
        s.push('}');
        s
    }

    /// Apply the deprecated `BENCH_OPS` / `BENCH_PAIRS` env overrides
    /// (kept so old invocations keep working): when set, they shadow
    /// the spec's `ops`/`smoke_ops` and `pairs`/`smoke_pairs` with a
    /// one-line deprecation note. The other pre-library `BENCH_*`
    /// knobs (`BENCH_BATCHES`, `BENCH_SCENARIOS`, `BENCH_FULL`,
    /// `BENCH_ROUNDS`) are gone from the throughput bench — their
    /// axes are spec fields now. (`benches/latency.rs` and friends
    /// keep their own `BENCH_OPS`/`BENCH_ROUNDS` readers.)
    pub fn apply_env_overrides(&mut self) {
        let ops = std::env::var("BENCH_OPS").ok();
        let pairs = std::env::var("BENCH_PAIRS").ok();
        self.apply_overrides(ops.as_deref(), pairs.as_deref());
    }

    /// Testable core of [`WorkloadSpec::apply_env_overrides`]: the
    /// raw override strings, already read from wherever.
    pub fn apply_overrides(&mut self, ops: Option<&str>, pairs: Option<&str>) {
        if let Some(n) = ops.and_then(|s| s.parse::<u64>().ok()).filter(|&n| n > 0) {
            eprintln!(
                "workload {}: deprecated BENCH_OPS={n} shadows spec ops={} — move it into the spec",
                self.name, self.ops
            );
            self.ops = n;
            self.smoke_ops = n;
        }
        if let Some(ps) = pairs.map(|s| {
            s.split(',')
                .filter_map(|p| p.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .map(PairConfig::symmetric)
                .collect::<Vec<_>>()
        }) {
            if !ps.is_empty() {
                eprintln!(
                    "workload {}: deprecated BENCH_PAIRS shadows spec pairs ({} entries) — move it into the spec",
                    self.name,
                    self.pairs.len()
                );
                self.pairs = ps.clone();
                self.smoke_pairs = ps;
            }
        }
    }
}

/// Load every `*.json` spec in `dir`, sorted by file name (so row
/// order is deterministic), rejecting duplicate workload names. Errors
/// name the offending file.
pub fn load_workload_dir(dir: &Path) -> Result<Vec<WorkloadSpec>, String> {
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read workload dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!("no *.json workloads in {}", dir.display()));
    }
    let mut specs: Vec<WorkloadSpec> = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        let spec = WorkloadSpec::parse(&text).map_err(|e| format!("{}: {e}", p.display()))?;
        if specs.iter().any(|s| s.name == spec.name) {
            return Err(format!(
                "{}: duplicate workload name {:?}",
                p.display(),
                spec.name
            ));
        }
        specs.push(spec);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_spec_gets_defaults() {
        let s = WorkloadSpec::parse(r#"{"name":"t"}"#).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.target, Target::Queue);
        assert_eq!(s.measure, Measure::Throughput);
        assert_eq!(s.impls, vec![Impl::Cmp, Impl::Segmented, Impl::MsHp, Impl::Mutex]);
        assert_eq!(s.pairs, vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]);
        assert_eq!(s.smoke_pairs, s.pairs);
        assert_eq!(s.ops, 60_000);
        assert_eq!(s.smoke_ops, 6_000);
        assert_eq!(s.batches, vec![1]);
        assert_eq!(s.arrival, Arrival::Closed);
        assert!(!s.latency, "closed loop defaults latency off");
    }

    #[test]
    fn unknown_key_is_named() {
        let e = WorkloadSpec::parse(r#"{"name":"t","opz":1}"#).unwrap_err();
        assert!(e.contains("\"opz\""), "must name the key: {e}");
        let e = WorkloadSpec::parse(r#"{"name":"t","arrival":{"kind":"open","gapms":3}}"#)
            .unwrap_err();
        assert!(e.contains("\"gapms\""), "must name the nested key: {e}");
    }

    #[test]
    fn asymmetric_pairs_parse() {
        let s = WorkloadSpec::parse(r#"{"name":"t","pairs":[2,[4,1]]}"#).unwrap();
        assert_eq!(
            s.pairs,
            vec![
                PairConfig::symmetric(2),
                PairConfig {
                    producers: 4,
                    consumers: 1
                }
            ]
        );
    }

    #[test]
    fn latency_defaults_follow_arrival() {
        let open =
            WorkloadSpec::parse(r#"{"name":"t","arrival":{"kind":"open"}}"#).unwrap();
        assert!(open.latency);
        assert_eq!(open.arrival, Arrival::Open { burst: 512, gap_ms: 2 });
        let idle =
            WorkloadSpec::parse(r#"{"name":"t","arrival":{"kind":"idle"}}"#).unwrap();
        assert!(!idle.latency);
        // Explicit value wins over the arrival-derived default.
        let forced = WorkloadSpec::parse(
            r#"{"name":"t","arrival":{"kind":"idle"},"latency":true}"#,
        )
        .unwrap();
        assert!(forced.latency);
    }

    #[test]
    fn combination_rules_enforced() {
        let e = WorkloadSpec::parse(r#"{"name":"t","measure":"rank_error"}"#).unwrap_err();
        assert!(e.contains("sharded"), "{e}");
        let e = WorkloadSpec::parse(r#"{"name":"t","keys":8}"#).unwrap_err();
        assert!(e.contains("sharded"), "{e}");
        let e = WorkloadSpec::parse(
            r#"{"name":"t","impls":["sharded"],"zipf_s":1.0}"#,
        )
        .unwrap_err();
        assert!(e.contains("keys"), "{e}");
        assert!(WorkloadSpec::parse(
            r#"{"name":"t","impls":["sharded"],"keys":8,"zipf_s":1.0}"#
        )
        .is_ok());
    }

    #[test]
    fn to_json_round_trips() {
        let s = WorkloadSpec::parse(
            r#"{"name":"rt","impls":["cmp","mutex"],"pairs":[1,[3,2]],"ops":5000,
                "batches":[1,8],"arrival":{"kind":"open","burst":64,"gap_ms":5},
                "rounds":2,"zipf_s":0}"#,
        )
        .unwrap();
        let back = WorkloadSpec::parse(&s.to_json()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn env_overrides_shadow_with_note() {
        let mut s = WorkloadSpec::parse(r#"{"name":"t","ops":9999,"pairs":[8]}"#).unwrap();
        s.apply_overrides(Some("1234"), Some("1,2"));
        assert_eq!(s.ops, 1234);
        assert_eq!(s.smoke_ops, 1234);
        assert_eq!(s.pairs, vec![PairConfig::symmetric(1), PairConfig::symmetric(2)]);
        assert_eq!(s.smoke_pairs, s.pairs);
        // Garbage overrides are ignored, spec values survive.
        let mut s2 = WorkloadSpec::parse(r#"{"name":"t","ops":9999}"#).unwrap();
        s2.apply_overrides(Some("banana"), None);
        assert_eq!(s2.ops, 9999);
    }
}
