//! Deterministic concurrency model checker for the wait/claim layer
//! (DESIGN.md §9).
//!
//! The container vendors no `loom`, and the correctness arguments for
//! the §8 eventcount (4-access lost-wakeup race) and the CMP
//! claim/frontier core lived only in prose — exactly the kind of
//! argument related queues get wrong. This module is a hand-rolled
//! replacement: virtual atomics ([`atomics`]) and mutex/condvar shims
//! ([`sync`]) that yield to a cooperative virtual-thread scheduler at
//! every shared-memory operation, plus two explorers ([`explore`]):
//! bounded-exhaustive DFS over schedule prefixes and seeded
//! random-schedule fuzzing, both with full-schedule counterexample
//! replay.
//!
//! The production code under test is *parameterized*, not forked: with
//! the `model-check` cargo feature, `util/wait.rs` and the CMP
//! claim/frontier core import their synchronization types through the
//! crate-internal `shim` alias layer and run unmodified under the
//! scheduler.
//! Without the feature the aliases are the `std` types — release
//! builds pay nothing.
//!
//! Scope: the checker enumerates **sequentially consistent**
//! interleavings. The wait/claim fast paths pair their publication
//! with `SeqCst` fences, whose correctness argument is an SC-order
//! argument (wait.rs module docs), so SC enumeration covers the races
//! these layers actually defend against; weaker-than-SC reordering of
//! independent accesses is out of scope (see DESIGN.md §9).

pub mod atomics;
pub mod explore;
mod sched;
pub(crate) mod shim;
pub mod sync;

pub use atomics::{fence, MAtomicBool, MAtomicPtr, MAtomicU32, MAtomicU64};
pub use explore::{
    explore_dfs, fuzz, replay, Check, DfsReport, ExecResult, ExploreConfig, FuzzReport, Outcome,
    Scenario, ThreadBody,
};
pub use sync::{MCondvar, MMutex, MMutexGuard, MWaitTimeoutResult};

/// True when the calling thread is a model virtual thread **and** the
/// `model-check` feature routed the production sync primitives through
/// the shims. Without the feature this compiles to a constant `false`
/// (no TLS lookup), so production hot paths can branch on it for free.
///
/// This is the gate production code uses for behavior that must only
/// change while the code under test is actually being
/// schedule-explored: `CmpQueue::park_wait` skips its perf-only spin
/// phase and its wall-clock deadline expiry, `WaitStrategy`'s deadline
/// sleep becomes wakeup-edge only, and the pool bypasses its
/// thread-local magazines (whose thread-exit flush would run outside
/// the schedule and break replay determinism).
#[inline]
pub fn shims_active() -> bool {
    cfg!(feature = "model-check") && sched::in_model()
}
