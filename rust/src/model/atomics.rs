//! Virtual atomics: drop-in stand-ins for `std::sync::atomic` types
//! that yield to the model scheduler before every operation.
//!
//! Each type wraps the corresponding `std` atomic. On an ordinary
//! thread every method is a plain passthrough (one TLS lookup of
//! overhead), so code built with the `model-check` feature still
//! behaves normally outside the checker. On a model virtual thread
//! every operation first takes a scheduling decision, making the
//! operation's placement in the global interleaving an explicit choice
//! the explorers can enumerate.
//!
//! The model executes operations under **sequential consistency**: the
//! caller's `Ordering` argument is accepted (so production code
//! compiles unchanged) but the underlying operation always runs
//! `SeqCst`. The checker therefore explores all SC interleavings; it
//! does not model weaker-than-SC reorderings (see DESIGN.md §9 for the
//! scope argument).

use std::sync::atomic::Ordering;

use super::sched::yield_point;

/// Model stand-in for [`std::sync::atomic::AtomicU64`].
#[derive(Debug, Default)]
pub struct MAtomicU64 {
    inner: std::sync::atomic::AtomicU64,
}

impl MAtomicU64 {
    /// A new atomic with the given initial value.
    pub const fn new(v: u64) -> Self {
        Self {
            inner: std::sync::atomic::AtomicU64::new(v),
        }
    }

    /// Load (a scheduling point under the model).
    pub fn load(&self, _order: Ordering) -> u64 {
        yield_point();
        self.inner.load(Ordering::SeqCst)
    }

    /// Store (a scheduling point under the model).
    pub fn store(&self, v: u64, _order: Ordering) {
        yield_point();
        self.inner.store(v, Ordering::SeqCst);
    }

    /// Swap (a scheduling point under the model).
    pub fn swap(&self, v: u64, _order: Ordering) -> u64 {
        yield_point();
        self.inner.swap(v, Ordering::SeqCst)
    }

    /// Fetch-add (a scheduling point under the model).
    pub fn fetch_add(&self, v: u64, _order: Ordering) -> u64 {
        yield_point();
        self.inner.fetch_add(v, Ordering::SeqCst)
    }

    /// Fetch-sub (a scheduling point under the model).
    pub fn fetch_sub(&self, v: u64, _order: Ordering) -> u64 {
        yield_point();
        self.inner.fetch_sub(v, Ordering::SeqCst)
    }

    /// Compare-exchange (a scheduling point under the model).
    pub fn compare_exchange(
        &self,
        current: u64,
        new: u64,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<u64, u64> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Weak compare-exchange. The model deliberately runs the *strong*
    /// variant so spurious failures do not inflate the schedule space.
    pub fn compare_exchange_weak(
        &self,
        current: u64,
        new: u64,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<u64, u64> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }
}

/// Model stand-in for [`std::sync::atomic::AtomicU32`].
#[derive(Debug, Default)]
pub struct MAtomicU32 {
    inner: std::sync::atomic::AtomicU32,
}

impl MAtomicU32 {
    /// A new atomic with the given initial value.
    pub const fn new(v: u32) -> Self {
        Self {
            inner: std::sync::atomic::AtomicU32::new(v),
        }
    }

    /// Load (a scheduling point under the model).
    pub fn load(&self, _order: Ordering) -> u32 {
        yield_point();
        self.inner.load(Ordering::SeqCst)
    }

    /// Store (a scheduling point under the model).
    pub fn store(&self, v: u32, _order: Ordering) {
        yield_point();
        self.inner.store(v, Ordering::SeqCst);
    }

    /// Swap (a scheduling point under the model).
    pub fn swap(&self, v: u32, _order: Ordering) -> u32 {
        yield_point();
        self.inner.swap(v, Ordering::SeqCst)
    }

    /// Compare-exchange (a scheduling point under the model).
    pub fn compare_exchange(
        &self,
        current: u32,
        new: u32,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<u32, u32> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Weak compare-exchange; strong under the model (see
    /// [`MAtomicU64::compare_exchange_weak`]).
    pub fn compare_exchange_weak(
        &self,
        current: u32,
        new: u32,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<u32, u32> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }
}

/// Model stand-in for [`std::sync::atomic::AtomicBool`].
#[derive(Debug, Default)]
pub struct MAtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

impl MAtomicBool {
    /// A new atomic with the given initial value.
    pub const fn new(v: bool) -> Self {
        Self {
            inner: std::sync::atomic::AtomicBool::new(v),
        }
    }

    /// Load (a scheduling point under the model).
    pub fn load(&self, _order: Ordering) -> bool {
        yield_point();
        self.inner.load(Ordering::SeqCst)
    }

    /// Store (a scheduling point under the model).
    pub fn store(&self, v: bool, _order: Ordering) {
        yield_point();
        self.inner.store(v, Ordering::SeqCst);
    }

    /// Swap (a scheduling point under the model).
    pub fn swap(&self, v: bool, _order: Ordering) -> bool {
        yield_point();
        self.inner.swap(v, Ordering::SeqCst)
    }

    /// Compare-exchange (a scheduling point under the model).
    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<bool, bool> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }
}

/// Model stand-in for [`std::sync::atomic::AtomicPtr`].
#[derive(Debug)]
pub struct MAtomicPtr<T> {
    inner: std::sync::atomic::AtomicPtr<T>,
}

impl<T> Default for MAtomicPtr<T> {
    /// A null pointer, matching `std`'s `AtomicPtr::default()`.
    fn default() -> Self {
        Self::new(std::ptr::null_mut())
    }
}

impl<T> MAtomicPtr<T> {
    /// A new atomic holding `p`.
    pub const fn new(p: *mut T) -> Self {
        Self {
            inner: std::sync::atomic::AtomicPtr::new(p),
        }
    }

    /// Load (a scheduling point under the model).
    pub fn load(&self, _order: Ordering) -> *mut T {
        yield_point();
        self.inner.load(Ordering::SeqCst)
    }

    /// Store (a scheduling point under the model).
    pub fn store(&self, p: *mut T, _order: Ordering) {
        yield_point();
        self.inner.store(p, Ordering::SeqCst);
    }

    /// Swap (a scheduling point under the model).
    pub fn swap(&self, p: *mut T, _order: Ordering) -> *mut T {
        yield_point();
        self.inner.swap(p, Ordering::SeqCst)
    }

    /// Compare-exchange (a scheduling point under the model).
    pub fn compare_exchange(
        &self,
        current: *mut T,
        new: *mut T,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Weak compare-exchange; strong under the model (see
    /// [`MAtomicU64::compare_exchange_weak`]).
    pub fn compare_exchange_weak(
        &self,
        current: *mut T,
        new: *mut T,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        yield_point();
        self.inner
            .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }
}

/// Model stand-in for [`std::sync::atomic::fence`]: a scheduling point
/// followed by the real fence. Under the model's SC execution the
/// fence's ordering role is played by the interleaving itself; the
/// scheduling point preserves the fence's position as an explorable
/// event (the §8 eventcount race is four accesses *and two fences*).
pub fn fence(order: Ordering) {
    yield_point();
    std::sync::atomic::fence(order);
}
