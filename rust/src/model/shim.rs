//! Type-alias layer selecting real vs. model synchronization primitives.
//!
//! The wait/claim core (`util/wait.rs`, `queue/cmp/{queue,node,pool}.rs`)
//! imports its atomics, mutexes, and condvars from this module instead
//! of `std::sync`. Without the `model-check` feature the aliases *are*
//! the `std` types — a pure re-export, zero cost. With the feature they
//! are the model stand-ins, which pass through to `std` on ordinary
//! threads and yield to the schedule enumerator on model virtual
//! threads (DESIGN.md §9).
//!
//! `Ordering` intentionally stays `std::sync::atomic::Ordering` in both
//! configurations; the model accepts and records the requested ordering
//! but executes sequentially consistently.

#[cfg(not(feature = "model-check"))]
pub(crate) use std::sync::atomic::{fence, AtomicBool, AtomicPtr, AtomicU32, AtomicU64};
#[cfg(not(feature = "model-check"))]
pub(crate) use std::sync::{Condvar, Mutex};

#[cfg(feature = "model-check")]
pub(crate) use super::atomics::{
    fence, MAtomicBool as AtomicBool, MAtomicPtr as AtomicPtr, MAtomicU32 as AtomicU32,
    MAtomicU64 as AtomicU64,
};
#[cfg(feature = "model-check")]
pub(crate) use super::sync::{MCondvar as Condvar, MMutex as Mutex};
