//! Virtual `Mutex`/`Condvar`: cooperative, deadlock-detecting shims
//! with the same call surface as `std::sync`.
//!
//! On ordinary threads every call passes straight through to the
//! wrapped `std` primitive (including poison propagation, which the
//! `WaitStrategy` unwind tests rely on). On a model virtual thread,
//! blocking is *logical*: a contended [`MMutex::lock`] or an
//! [`MCondvar::wait`] marks the thread blocked in the scheduler and
//! simply never runs until another thread's unlock/notify re-enables
//! it — so a lost wakeup shows up as a detected deadlock instead of a
//! hung test.
//!
//! Model-mode fidelity notes (see DESIGN.md §9):
//!
//! * `wait` has **no spurious wakeups**. Spurious wakeups only add
//!   wakeups, so they cannot hide a lost-wakeup bug; omitting them
//!   keeps the schedule space tight.
//! * `wait_timeout` never times out under the model (virtual time does
//!   not advance). Deadline paths are checked by their wakeup edges,
//!   not their expiry edges.
//! * Unlock and the release half of `wait` are bookkeeping, not
//!   scheduling points: they happen atomically with the caller's
//!   current turn slice, which matches the condvar atomic
//!   release-and-sleep contract.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::Ordering;
use std::sync::{LockResult, PoisonError};
use std::time::Duration;

use super::sched::{self, BlockReason};

/// Model stand-in for [`std::sync::Mutex`].
pub struct MMutex<T> {
    inner: std::sync::Mutex<T>,
    /// Model-level ownership flag. Only mutated by the single running
    /// virtual thread (or during abort teardown, when outcomes no
    /// longer matter), so a plain SeqCst atomic suffices.
    model_locked: std::sync::atomic::AtomicBool,
}

impl<T> MMutex<T> {
    /// A new unlocked mutex holding `t`.
    pub const fn new(t: T) -> Self {
        Self {
            inner: std::sync::Mutex::new(t),
            model_locked: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn addr(&self) -> usize {
        self as *const Self as *const () as usize
    }

    fn wrap<'a>(
        &'a self,
        res: LockResult<std::sync::MutexGuard<'a, T>>,
        model_held: bool,
    ) -> LockResult<MMutexGuard<'a, T>> {
        match res {
            Ok(g) => Ok(MMutexGuard {
                owner: self,
                inner: Some(g),
                model_held,
            }),
            Err(p) => Err(PoisonError::new(MMutexGuard {
                owner: self,
                inner: Some(p.into_inner()),
                model_held,
            })),
        }
    }

    /// Acquire the lock. On a virtual thread this is a scheduling point
    /// and blocks logically while contended; otherwise it is the plain
    /// `std` lock. Poisoning propagates exactly like `std` (the
    /// returned guard still holds the lock either way).
    ///
    /// Invariant: a mutex used by model virtual threads must not also
    /// be locked from ordinary threads (or from unwind-time `Drop`
    /// code) while an execution is in flight. The passthrough arm
    /// takes the OS lock directly; if a *parked* virtual thread held
    /// the model lock across its yield, such a caller would OS-block
    /// on an owner that is never scheduled, hanging the execution
    /// instead of producing an outcome. Scenario checks run after all
    /// virtual threads join, so the explorers never hit this; no code
    /// in the shimmed layers locks from `Drop`.
    pub fn lock(&self) -> LockResult<MMutexGuard<'_, T>> {
        match sched::current() {
            Some(ctx) if !std::thread::panicking() => {
                loop {
                    ctx.schedule_point();
                    if !self.model_locked.swap(true, Ordering::SeqCst) {
                        break;
                    }
                    ctx.block(BlockReason::Mutex(self.addr()));
                }
                // Uncontended among virtual threads: the model flag
                // already serializes them.
                self.wrap(self.inner.lock(), true)
            }
            _ => self.wrap(self.inner.lock(), false),
        }
    }
}

impl<T: Default> Default for MMutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

/// RAII guard for [`MMutex`]; releases the model-level lock (waking
/// blocked virtual threads) and the OS lock on drop.
pub struct MMutexGuard<'a, T> {
    owner: &'a MMutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    model_held: bool,
}

impl<T> Deref for MMutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_deref().expect("guard accessed after release")
    }
}

impl<T> DerefMut for MMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner
            .as_deref_mut()
            .expect("guard accessed after release")
    }
}

impl<T> Drop for MMutexGuard<'_, T> {
    fn drop(&mut self) {
        // OS lock first, then the model flag, then wake the queue —
        // never a scheduling point, so drops during unwind are safe.
        self.inner.take();
        if self.model_held {
            self.owner.model_locked.store(false, Ordering::SeqCst);
            if let Some(ctx) = sched::current() {
                ctx.wake_matching(BlockReason::Mutex(self.owner.addr()));
            }
        }
    }
}

/// Result of [`MCondvar::wait_timeout`], mirroring
/// [`std::sync::WaitTimeoutResult`] (which has no public constructor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MWaitTimeoutResult(bool);

impl MWaitTimeoutResult {
    /// Whether the wait ended by timeout rather than notification.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model stand-in for [`std::sync::Condvar`].
#[derive(Default)]
pub struct MCondvar {
    inner: std::sync::Condvar,
}

impl MCondvar {
    /// A new condition variable.
    pub const fn new() -> Self {
        Self {
            inner: std::sync::Condvar::new(),
        }
    }

    fn addr(&self) -> usize {
        self as *const Self as *const () as usize
    }

    /// Release `guard` and sleep until notified. Under the model the
    /// release and the park are atomic within the caller's turn slice
    /// (no notify can slip between them), and the thread stays
    /// logically blocked until an [`MCondvar::notify_all`] /
    /// [`MCondvar::notify_one`] re-enables it.
    pub fn wait<'a, T>(&self, mut guard: MMutexGuard<'a, T>) -> LockResult<MMutexGuard<'a, T>> {
        match sched::current() {
            Some(ctx) if guard.model_held => {
                let owner = guard.owner;
                drop(guard); // release OS + model lock, wake lock waiters
                ctx.block(BlockReason::Condvar(self.addr()));
                owner.lock() // woken: reacquire cooperatively
            }
            _ => {
                let owner = guard.owner;
                let inner = guard.inner.take().expect("guard accessed after release");
                drop(guard); // inert: OS guard moved out, no model lock
                match self.inner.wait(inner) {
                    Ok(g) => Ok(MMutexGuard {
                        owner,
                        inner: Some(g),
                        model_held: false,
                    }),
                    Err(p) => Err(PoisonError::new(MMutexGuard {
                        owner,
                        inner: Some(p.into_inner()),
                        model_held: false,
                    })),
                }
            }
        }
    }

    /// Timed wait. Under the model this never times out (virtual time
    /// does not advance); on ordinary threads it is the real
    /// `wait_timeout`.
    #[allow(clippy::type_complexity)]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MMutexGuard<'a, T>,
        dur: Duration,
    ) -> Result<
        (MMutexGuard<'a, T>, MWaitTimeoutResult),
        PoisonError<(MMutexGuard<'a, T>, MWaitTimeoutResult)>,
    > {
        match sched::current() {
            Some(ctx) if guard.model_held => {
                let _ = (ctx, dur);
                match self.wait(guard) {
                    Ok(g) => Ok((g, MWaitTimeoutResult(false))),
                    Err(p) => Err(PoisonError::new((p.into_inner(), MWaitTimeoutResult(false)))),
                }
            }
            _ => {
                let owner = guard.owner;
                let mut guard = guard;
                let inner = guard.inner.take().expect("guard accessed after release");
                drop(guard);
                match self.inner.wait_timeout(inner, dur) {
                    Ok((g, t)) => Ok((
                        MMutexGuard {
                            owner,
                            inner: Some(g),
                            model_held: false,
                        },
                        MWaitTimeoutResult(t.timed_out()),
                    )),
                    Err(p) => {
                        let (g, t) = p.into_inner();
                        Err(PoisonError::new((
                            MMutexGuard {
                                owner,
                                inner: Some(g),
                                model_held: false,
                            },
                            MWaitTimeoutResult(t.timed_out()),
                        )))
                    }
                }
            }
        }
    }

    /// Wake every virtual thread parked on this condvar (a scheduling
    /// point), then the real `notify_all` for ordinary threads.
    pub fn notify_all(&self) {
        if let Some(ctx) = sched::current() {
            ctx.schedule_point();
            ctx.wake_matching(BlockReason::Condvar(self.addr()));
        }
        self.inner.notify_all();
    }

    /// Like [`MCondvar::notify_all`] under the model (waking all is a
    /// conservative over-approximation the condvar contract permits as
    /// spurious wakeups); the real `notify_one` on ordinary threads.
    pub fn notify_one(&self) {
        if let Some(ctx) = sched::current() {
            ctx.schedule_point();
            ctx.wake_matching(BlockReason::Condvar(self.addr()));
        }
        self.inner.notify_one();
    }
}
