//! Schedule explorers: bounded-exhaustive DFS, seeded random fuzzing,
//! and deterministic replay of pinned schedules.
//!
//! A [`Scenario`] is a fresh set of virtual-thread bodies plus a
//! post-condition check, built by a factory closure once per
//! execution. The explorers drive the cooperative scheduler with
//! different choosers:
//!
//! * [`explore_dfs`] — depth-first search over *schedule prefixes*: the
//!   first [`ExploreConfig::max_depth`] scheduling decisions are
//!   enumerated exhaustively; deeper decisions fall back to a fixed
//!   deterministic rule (first enabled thread). With `max_depth` at or
//!   above the longest execution this is a complete enumeration of all
//!   sequentially consistent interleavings.
//! * [`fuzz`] — seeded uniform-random schedules, for states deeper
//!   than the DFS bound. Deterministic given the seed.
//! * [`replay`] — run one pinned schedule (a counterexample or a
//!   hand-built adversarial interleaving) as a regression test.
//!
//! Every counterexample carries its full schedule, so it can be
//! replayed exactly.

use crate::util::XorShift64;

use super::sched::{self, RawOutcome};

/// One virtual-thread body.
pub type ThreadBody = Box<dyn FnOnce() + Send + 'static>;

/// Post-execution property check; `Err` is a counterexample.
pub type Check = Box<dyn FnOnce() -> Result<(), String>>;

/// A fresh instance of the system under test: thread bodies sharing
/// whatever state the factory captured, plus a final-state check run by
/// the controller after all threads finish.
pub struct Scenario {
    /// Virtual-thread bodies; thread ids in schedules index this list.
    pub threads: Vec<ThreadBody>,
    /// Post-condition over the shared state.
    pub check: Check,
}

/// Verdict of one execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Outcome {
    /// All threads finished and the post-condition held.
    Pass,
    /// No runnable thread remained — e.g. a lost wakeup left a
    /// consumer parked forever.
    Deadlock {
        /// Human-readable `thread N blocked on ...` descriptions.
        blocked: Vec<String>,
    },
    /// A virtual thread panicked (failed in-thread assertion).
    Panicked {
        /// Index of the panicking thread.
        thread: usize,
        /// The panic message.
        message: String,
    },
    /// The execution exceeded the per-execution step budget
    /// (livelock, or a budget set too low).
    StepLimit {
        /// Steps taken when the budget ran out.
        steps: u64,
    },
    /// All threads finished but the post-condition failed.
    CheckFailed {
        /// The check's error message.
        message: String,
    },
}

impl Outcome {
    /// True for [`Outcome::Pass`].
    pub fn is_pass(&self) -> bool {
        matches!(self, Outcome::Pass)
    }
}

/// Result of one execution: the verdict plus the schedule that
/// produced it (replayable via [`replay`]).
#[derive(Debug, Clone)]
pub struct ExecResult {
    /// The verdict.
    pub outcome: Outcome,
    /// Absolute thread id granted at each scheduling step.
    pub schedule: Vec<usize>,
    /// Total scheduling steps taken.
    pub steps: u64,
}

/// Exploration budgets.
#[derive(Debug, Clone, Copy)]
pub struct ExploreConfig {
    /// Scheduling decisions enumerated exhaustively per execution;
    /// deeper decisions use the deterministic first-enabled completion.
    pub max_depth: usize,
    /// Per-execution step budget (livelock backstop).
    pub max_steps: usize,
    /// Total executions the DFS may run before giving up
    /// (`complete = false`).
    pub max_executions: u64,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        Self {
            max_depth: 10,
            max_steps: 5_000,
            max_executions: 500_000,
        }
    }
}

impl ExploreConfig {
    /// Config with the given exhaustive depth and default budgets.
    pub fn with_depth(depth: usize) -> Self {
        Self {
            max_depth: depth,
            ..Self::default()
        }
    }
}

/// Result of a [`explore_dfs`] pass.
#[derive(Debug, Clone)]
pub struct DfsReport {
    /// Executions run.
    pub executions: u64,
    /// First failing execution, if any.
    pub counterexample: Option<ExecResult>,
    /// True when every schedule prefix within `max_depth` was explored
    /// (exhaustive at the bound). False when `max_executions` ran out
    /// first or a counterexample stopped the search.
    pub complete: bool,
    /// True when at least one execution had scheduling decisions beyond
    /// `max_depth` (coverage is exhaustive *at the bound*, not total).
    pub depth_truncated: bool,
    /// Longest execution observed, in scheduling steps.
    pub max_steps_seen: u64,
}

fn run_one(
    scenario: Scenario,
    chooser: impl FnMut(usize, &[usize]) -> usize,
    max_steps: usize,
) -> ExecResult {
    let Scenario { threads, check } = scenario;
    let out = sched::run_execution(threads, chooser, max_steps);
    let outcome = match out.outcome {
        RawOutcome::AllFinished => match check() {
            Ok(()) => Outcome::Pass,
            Err(message) => Outcome::CheckFailed { message },
        },
        RawOutcome::Deadlock(blocked) => Outcome::Deadlock {
            // Deliberately address-free (BlockReason carries the
            // primitive's address): outcomes must compare equal across
            // a counterexample run and its replay, which allocate
            // fresh scenario state.
            blocked: blocked
                .into_iter()
                .map(|(i, r)| {
                    let what = match r {
                        sched::BlockReason::Mutex(_) => "a model mutex",
                        sched::BlockReason::Condvar(_) => "a model condvar",
                    };
                    format!("thread {i} blocked on {what}")
                })
                .collect(),
        },
        RawOutcome::Panicked(thread, message) => Outcome::Panicked { thread, message },
        RawOutcome::StepLimit => Outcome::StepLimit { steps: out.steps },
    };
    ExecResult {
        outcome,
        schedule: out.schedule,
        steps: out.steps,
    }
}

/// Bounded-exhaustive DFS over schedule prefixes. Stops at the first
/// counterexample (its schedule is in the report), or when all
/// prefixes within [`ExploreConfig::max_depth`] are explored, or when
/// [`ExploreConfig::max_executions`] runs out.
pub fn explore_dfs<F: Fn() -> Scenario>(factory: F, cfg: ExploreConfig) -> DfsReport {
    // Each entry is (choice index into the enabled set, enabled-set
    // size, granted absolute thread id) for one scheduling step of the
    // current prefix. The id is redundant for exploration but is the
    // replay-determinism witness: cardinality alone could mask a
    // nondeterministic enabled set of the same size.
    let mut prefix: Vec<(usize, usize, usize)> = Vec::new();
    let mut report = DfsReport {
        executions: 0,
        counterexample: None,
        complete: false,
        depth_truncated: false,
        max_steps_seen: 0,
    };
    loop {
        let scenario = factory();
        let mut decisions: Vec<(usize, usize, usize)> = Vec::new();
        let mut truncated = false;
        let result = {
            let prefix_ref = &prefix;
            let decisions_ref = &mut decisions;
            let truncated_ref = &mut truncated;
            run_one(
                scenario,
                move |step, enabled| {
                    if let Some(&(choice, len, id)) = prefix_ref.get(step) {
                        // Hard asserts (not debug_assert): the whole
                        // "exhaustive at the bound" guarantee rests on
                        // prefix replay being deterministic, and CI
                        // runs this in --release. Checking the granted
                        // id (not just the set size) catches
                        // same-cardinality nondeterminism too.
                        assert_eq!(
                            len,
                            enabled.len(),
                            "nondeterministic replay at step {step}: enabled-set size changed"
                        );
                        // `usize::MAX` marks the one entry whose id is
                        // not yet known: the choice the backtracker
                        // just incremented (it is learned right here).
                        if id != usize::MAX {
                            assert_eq!(
                                enabled[choice], id,
                                "nondeterministic replay at step {step}: enabled set changed"
                            );
                        }
                        decisions_ref.push((choice, enabled.len(), enabled[choice]));
                        enabled[choice]
                    } else if decisions_ref.len() < cfg.max_depth {
                        decisions_ref.push((0, enabled.len(), enabled[0]));
                        enabled[0]
                    } else {
                        *truncated_ref = true;
                        enabled[0]
                    }
                },
                cfg.max_steps,
            )
        };
        report.executions += 1;
        report.max_steps_seen = report.max_steps_seen.max(result.steps);
        report.depth_truncated |= truncated;
        if !result.outcome.is_pass() {
            report.counterexample = Some(result);
            return report;
        }
        prefix = decisions;
        // Backtrack to the deepest step with an unexplored alternative.
        loop {
            match prefix.pop() {
                None => {
                    report.complete = true;
                    return report;
                }
                Some((choice, len, _id)) => {
                    if choice + 1 < len {
                        // The granted id for the new choice is learned
                        // on the next run (sentinel skips the check).
                        prefix.push((choice + 1, len, usize::MAX));
                        break;
                    }
                }
            }
        }
        if report.executions >= cfg.max_executions {
            return report;
        }
    }
}

/// Report of a [`fuzz`] pass.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    /// Executions run.
    pub executions: u64,
    /// First failing execution, if any.
    pub counterexample: Option<ExecResult>,
}

/// Seeded random-schedule fuzzing: `iterations` executions, each
/// picking uniformly among enabled threads at every step.
/// Deterministic given `seed`.
pub fn fuzz<F: Fn() -> Scenario>(
    factory: F,
    cfg: ExploreConfig,
    seed: u64,
    iterations: u64,
) -> FuzzReport {
    let mut rng = XorShift64::new(seed);
    for i in 0..iterations {
        let result = run_one(
            factory(),
            |_, enabled| enabled[rng.next_usize(enabled.len())],
            cfg.max_steps,
        );
        if !result.outcome.is_pass() {
            return FuzzReport {
                executions: i + 1,
                counterexample: Some(result),
            };
        }
    }
    FuzzReport {
        executions: iterations,
        counterexample: None,
    }
}

/// Replay a pinned schedule. Steps past the end of `schedule` — or
/// entries naming a thread that is not currently enabled (it finished
/// or blocked earlier than when the schedule was recorded) — fall back
/// to the first enabled thread, so approximate hand-written schedules
/// are still fully deterministic.
pub fn replay<F: FnOnce() -> Scenario>(
    factory: F,
    schedule: &[usize],
    max_steps: usize,
) -> ExecResult {
    run_one(
        factory(),
        |step, enabled| match schedule.get(step) {
            Some(&id) if enabled.contains(&id) => id,
            _ => enabled[0],
        },
        max_steps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::atomics::{fence, MAtomicU64};
    use crate::model::sync::{MCondvar, MMutex};
    use std::sync::atomic::Ordering::SeqCst;
    use std::sync::Arc;

    /// Two threads doing a non-atomic increment (load; store) — the
    /// canonical lost update. The checker must find the interleaving
    /// where one increment vanishes.
    #[test]
    fn dfs_finds_lost_update() {
        let factory = || {
            let c = Arc::new(MAtomicU64::new(0));
            let mut threads: Vec<ThreadBody> = Vec::new();
            for _ in 0..2 {
                let c = c.clone();
                threads.push(Box::new(move || {
                    let v = c.load(SeqCst);
                    c.store(v + 1, SeqCst);
                }));
            }
            let c2 = c.clone();
            Scenario {
                threads,
                check: Box::new(move || {
                    let v = c2.load(SeqCst);
                    if v == 2 {
                        Ok(())
                    } else {
                        Err(format!("lost update: counter = {v}"))
                    }
                }),
            }
        };
        let report = explore_dfs(factory, ExploreConfig::with_depth(8));
        let cx = report.counterexample.expect("lost update must be found");
        assert!(matches!(cx.outcome, Outcome::CheckFailed { .. }), "{cx:?}");
        // The counterexample replays to the same verdict.
        let again = replay(factory, &cx.schedule, 1000);
        assert_eq!(again.outcome, cx.outcome, "replay must be deterministic");
    }

    /// The same program with a proper atomic RMW has no bad schedule.
    #[test]
    fn dfs_passes_atomic_increment() {
        let factory = || {
            let c = Arc::new(MAtomicU64::new(0));
            let mut threads: Vec<ThreadBody> = Vec::new();
            for _ in 0..2 {
                let c = c.clone();
                threads.push(Box::new(move || {
                    c.fetch_add(1, SeqCst);
                }));
            }
            let c2 = c.clone();
            Scenario {
                threads,
                check: Box::new(move || {
                    if c2.load(SeqCst) == 2 {
                        Ok(())
                    } else {
                        Err("lost update".into())
                    }
                }),
            }
        };
        let report = explore_dfs(factory, ExploreConfig::with_depth(8));
        assert!(report.counterexample.is_none(), "{report:?}");
        assert!(report.complete, "tiny state space must be exhausted");
        assert!(!report.depth_truncated);
    }

    /// Classic lock-ordering deadlock: the checker must report it with
    /// both threads blocked on a mutex.
    #[test]
    fn dfs_finds_lock_order_deadlock() {
        let factory = || {
            let a = Arc::new(MMutex::new(()));
            let b = Arc::new(MMutex::new(()));
            let (a1, b1) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            let threads: Vec<ThreadBody> = vec![
                Box::new(move || {
                    let _ga = a1.lock().unwrap();
                    let _gb = b1.lock().unwrap();
                }),
                Box::new(move || {
                    let _gb = b2.lock().unwrap();
                    let _ga = a2.lock().unwrap();
                }),
            ];
            Scenario {
                threads,
                check: Box::new(|| Ok(())),
            }
        };
        let report = explore_dfs(factory, ExploreConfig::with_depth(8));
        let cx = report.counterexample.expect("deadlock must be found");
        assert!(matches!(cx.outcome, Outcome::Deadlock { .. }), "{cx:?}");
    }

    /// A runaway thread trips the step budget instead of hanging the
    /// test suite.
    #[test]
    fn step_limit_catches_livelock() {
        let factory = || {
            let c = Arc::new(MAtomicU64::new(0));
            let c1 = c.clone();
            let threads: Vec<ThreadBody> = vec![Box::new(move || loop {
                if c1.load(SeqCst) == u64::MAX {
                    break; // unreachable: spins forever
                }
            })];
            Scenario {
                threads,
                check: Box::new(|| Ok(())),
            }
        };
        let result = replay(factory, &[], 64);
        assert!(matches!(result.outcome, Outcome::StepLimit { .. }));
    }

    /// In-thread assertion failures surface as `Panicked`
    /// counterexamples with the offending thread id.
    #[test]
    fn vthread_panic_is_reported() {
        let factory = || {
            let threads: Vec<ThreadBody> =
                vec![Box::new(|| {}), Box::new(|| panic!("boom from vthread"))];
            Scenario {
                threads,
                check: Box::new(|| Ok(())),
            }
        };
        let report = explore_dfs(factory, ExploreConfig::with_depth(4));
        let cx = report.counterexample.expect("panic must surface");
        match cx.outcome {
            Outcome::Panicked { thread, message } => {
                assert_eq!(thread, 1);
                assert!(message.contains("boom"), "{message}");
            }
            other => panic!("expected Panicked, got {other:?}"),
        }
    }

    /// Miniature eventcount over the model primitives. `repoll = false`
    /// drops the re-check between register and sleep — the §8
    /// 4-access lost-wakeup bug — and the checker must exhibit it as a
    /// deadlock. `repoll = true` is the correct protocol and must
    /// survive the same exhaustive pass.
    struct MiniEc {
        items: MAtomicU64,
        waiters: MAtomicU64,
        epoch: MAtomicU64,
        lock: MMutex<()>,
        cv: MCondvar,
    }

    impl MiniEc {
        fn new() -> Self {
            Self {
                items: MAtomicU64::new(0),
                waiters: MAtomicU64::new(0),
                epoch: MAtomicU64::new(0),
                lock: MMutex::new(()),
                cv: MCondvar::new(),
            }
        }

        fn try_take(&self) -> bool {
            let mut cur = self.items.load(SeqCst);
            while cur > 0 {
                match self.items.compare_exchange(cur, cur - 1, SeqCst, SeqCst) {
                    Ok(_) => return true,
                    Err(now) => cur = now,
                }
            }
            false
        }

        fn produce(&self) {
            self.items.fetch_add(1, SeqCst);
            fence(SeqCst);
            if self.waiters.load(SeqCst) == 0 {
                return;
            }
            {
                let _g = self.lock.lock().unwrap();
                self.epoch.fetch_add(1, SeqCst);
            }
            self.cv.notify_all();
        }

        fn consume(&self, repoll: bool) {
            loop {
                if self.try_take() {
                    return;
                }
                self.waiters.fetch_add(1, SeqCst);
                fence(SeqCst);
                let token = self.epoch.load(SeqCst);
                if repoll && self.try_take() {
                    self.waiters.fetch_sub(1, SeqCst);
                    return;
                }
                {
                    let mut g = self.lock.lock().unwrap();
                    while self.epoch.load(SeqCst) == token {
                        g = self.cv.wait(g).unwrap();
                    }
                    drop(g);
                }
                self.waiters.fetch_sub(1, SeqCst);
            }
        }
    }

    fn mini_ec_scenario(repoll: bool) -> Scenario {
        let ec = Arc::new(MiniEc::new());
        let p = ec.clone();
        let c = ec.clone();
        let threads: Vec<ThreadBody> = vec![
            Box::new(move || p.produce()),
            Box::new(move || c.consume(repoll)),
        ];
        let ec2 = ec.clone();
        Scenario {
            threads,
            check: Box::new(move || {
                if ec2.items.load(SeqCst) == 0 {
                    Ok(())
                } else {
                    Err("item left behind".into())
                }
            }),
        }
    }

    #[test]
    fn broken_eventcount_loses_a_wakeup() {
        let report = explore_dfs(|| mini_ec_scenario(false), ExploreConfig::with_depth(12));
        let cx = report
            .counterexample
            .expect("missing re-poll must lose a wakeup");
        assert!(
            matches!(cx.outcome, Outcome::Deadlock { .. }),
            "lost wakeup should strand the consumer: {cx:?}"
        );
    }

    #[test]
    fn fixed_eventcount_is_exhaustively_clean() {
        // Depth 12 keeps this tier-1 test under a couple of seconds;
        // the unbounded pass over the real WaitStrategy runs in the CI
        // model-check job (tests/model_wait.rs).
        let report = explore_dfs(|| mini_ec_scenario(true), ExploreConfig::with_depth(12));
        assert!(report.counterexample.is_none(), "{:?}", report.counterexample);
        assert!(report.complete, "depth-12 prefix space must be exhausted");
    }

    #[test]
    fn fuzz_is_deterministic_and_clean_on_fixed_eventcount() {
        let a = fuzz(|| mini_ec_scenario(true), ExploreConfig::default(), 42, 50);
        assert!(a.counterexample.is_none());
        let b = fuzz(|| mini_ec_scenario(false), ExploreConfig::default(), 42, 400);
        let c = fuzz(|| mini_ec_scenario(false), ExploreConfig::default(), 42, 400);
        // Same seed → same verdict, including the schedule if one fails.
        match (&b.counterexample, &c.counterexample) {
            (Some(x), Some(y)) => {
                assert_eq!(x.schedule, y.schedule);
                assert_eq!(b.executions, c.executions);
            }
            (None, None) => {}
            _ => panic!("fuzz nondeterminism: {b:?} vs {c:?}"),
        }
    }
}
