//! Cooperative virtual-thread scheduler — the execution engine under
//! the model checker (DESIGN.md §9).
//!
//! An *execution* runs N closures ("virtual threads") as real OS
//! threads, but strictly one at a time: every model-level shared-memory
//! operation ([`crate::model::atomics`], [`crate::model::sync`]) first
//! calls [`Ctx::schedule_point`], which parks the thread and hands
//! control to the controller. The controller picks the next thread to
//! run from the set of *enabled* (runnable, unblocked, unfinished)
//! threads via a caller-supplied chooser — a DFS prefix, a replayed
//! schedule, or a seeded RNG (see [`crate::model::explore`]).
//!
//! Because exactly one virtual thread runs between schedule points, an
//! execution is a *sequentially consistent interleaving* of the
//! threads' shared-memory operations, fully determined by the chooser's
//! decisions. That makes executions replayable: the same schedule
//! always produces the same outcome.
//!
//! Blocking is purely logical: a thread blocked on a model mutex or
//! condvar is marked [`VState::Blocked`] and simply never granted a
//! turn until another thread's unlock/notify flips it back to `Ready`.
//! If no thread is enabled and not all have finished, the controller
//! reports a deadlock — which is exactly how a lost wakeup manifests.
//!
//! Teardown: on deadlock, panic, or step-limit the controller sets an
//! `abort` flag and wakes everyone; parked virtual threads unwind via a
//! [`ModelAbort`] panic (caught by their wrapper), so no OS thread is
//! ever leaked across the tens of thousands of executions an
//! exhaustive pass runs.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Why a virtual thread is not currently runnable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockReason {
    /// Waiting to acquire the model mutex at this address.
    Mutex(usize),
    /// Parked on the model condvar at this address.
    Condvar(usize),
}

/// Lifecycle of one virtual thread within an execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VState {
    /// Eligible for the next turn.
    Ready,
    /// Currently holding the turn (between grant and next yield).
    Running,
    /// Logically blocked; not schedulable until woken.
    Blocked(BlockReason),
    /// Body returned (or unwound).
    Finished,
}

/// Sentinel panic payload used to unwind parked virtual threads at
/// teardown. Never reported as a user panic.
pub(crate) struct ModelAbort;

struct SchedState {
    /// Thread currently granted the right to run, if any.
    turn: Option<usize>,
    states: Vec<VState>,
    /// Set on deadlock / panic / step-limit; parked threads unwind.
    abort: bool,
    /// First user panic observed (thread id, message).
    panic: Option<(usize, String)>,
}

struct SchedShared {
    m: Mutex<SchedState>,
    cv: Condvar,
}

impl SchedShared {
    fn lock(&self) -> std::sync::MutexGuard<'_, SchedState> {
        // Poison-tolerant: a panicking virtual thread may have been
        // holding this lock; the state itself stays consistent.
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Per-thread handle into the running execution. Cloned into TLS by the
/// virtual-thread wrapper; model atomics and sync shims look it up via
/// [`current`].
#[derive(Clone)]
pub(crate) struct Ctx {
    shared: Arc<SchedShared>,
    id: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// The calling thread's model context, if it is a registered virtual
/// thread of a running execution.
pub(crate) fn current() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// True when the calling thread is a model virtual thread.
pub(crate) fn in_model() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Yield at a shared-memory operation if (and only if) the calling
/// thread is a virtual thread. No-op on ordinary threads and during
/// unwinds, so Drop code can always run to completion. Borrows the TLS
/// context in place — no per-operation `Arc` refcount traffic on the
/// hot path (this runs before *every* model atomic op).
pub(crate) fn yield_point() {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow().as_ref() {
            ctx.schedule_point();
        }
    });
}

impl Ctx {
    /// Core wait: publish `entry` as this thread's state, surrender the
    /// turn (when `yielding`), and sleep until the controller grants the
    /// turn back. Panics with [`ModelAbort`] if the execution aborts.
    fn enter_wait(&self, entry: VState, yielding: bool) {
        let mut st = self.shared.lock();
        if st.abort {
            drop(st);
            std::panic::panic_any(ModelAbort);
        }
        st.states[self.id] = entry;
        if yielding && st.turn == Some(self.id) {
            st.turn = None;
        }
        self.shared.cv.notify_all();
        loop {
            if st.turn == Some(self.id) {
                break;
            }
            if st.abort {
                drop(st);
                std::panic::panic_any(ModelAbort);
            }
            st = self.shared.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.states[self.id] = VState::Running;
    }

    /// One scheduling decision: park, let the controller pick the next
    /// thread (possibly us again), resume when granted. Call *before*
    /// every model-level shared-memory operation. No-op while the
    /// thread is unwinding, so guards dropped during teardown never
    /// re-enter the scheduler.
    pub(crate) fn schedule_point(&self) {
        if std::thread::panicking() {
            return;
        }
        self.enter_wait(VState::Ready, true);
    }

    /// Logically block this thread until another thread's
    /// [`Ctx::wake_matching`] flips it back to `Ready` *and* the
    /// controller grants it a turn.
    pub(crate) fn block(&self, reason: BlockReason) {
        if std::thread::panicking() {
            return;
        }
        self.enter_wait(VState::Blocked(reason), true);
    }

    /// Flip every thread blocked for `reason` back to `Ready`. Runs
    /// within the caller's turn (or during teardown unwinds); it never
    /// waits.
    pub(crate) fn wake_matching(&self, reason: BlockReason) {
        let mut st = self.shared.lock();
        for s in st.states.iter_mut() {
            if *s == VState::Blocked(reason) {
                *s = VState::Ready;
            }
        }
    }
}

/// Outcome of one execution, before the scenario's post-condition check
/// is applied.
#[derive(Debug)]
pub(crate) enum RawOutcome {
    /// Every virtual thread ran to completion.
    AllFinished,
    /// No thread enabled, at least one unfinished: `(id, reason)` pairs.
    Deadlock(Vec<(usize, BlockReason)>),
    /// A virtual thread panicked: `(id, message)`.
    Panicked(usize, String),
    /// The controller hit the per-execution step budget.
    StepLimit,
}

/// Raw result of [`run_execution`].
#[derive(Debug)]
pub(crate) struct ExecOutput {
    pub outcome: RawOutcome,
    /// Absolute thread id chosen at each scheduling step.
    pub schedule: Vec<usize>,
    pub steps: u64,
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Unwind-proof execution teardown: aborts the execution and joins
/// every virtual thread when dropped. The controller's normal exit
/// path drops it explicitly; if the chooser (or an internal assert)
/// panics mid-execution, the drop still runs — without it, parked
/// virtual threads (512 KiB stack each, plus the scenario state they
/// hold) would leak on every such failure.
struct Teardown {
    shared: Arc<SchedShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for Teardown {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.abort = true;
            self.shared.cv.notify_all();
            drop(st);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Suppress panic-hook output from model virtual threads: user panics
/// there are *expected counterexamples* (reported via
/// [`RawOutcome::Panicked`]), and [`ModelAbort`] unwinds are routine
/// teardown. Panics on every other thread keep the previous hook.
fn install_quiet_panic_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let in_vthread = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("cmpq-vthread"));
            if !in_vthread {
                prev(info);
            }
        }));
    });
}

fn vthread_main(ctx: Ctx, body: Box<dyn FnOnce() + Send>) {
    CTX.with(|c| *c.borrow_mut() = Some(ctx.clone()));
    let result = catch_unwind(AssertUnwindSafe(|| {
        // Wait for the controller's first grant so thread startup order
        // cannot leak nondeterminism into the execution.
        ctx.enter_wait(VState::Ready, false);
        body();
    }));
    let user_panic = match result {
        Ok(()) => None,
        Err(p) => {
            if p.downcast_ref::<ModelAbort>().is_some() {
                None
            } else {
                Some(panic_message(p.as_ref()))
            }
        }
    };
    let mut st = ctx.shared.lock();
    if let Some(msg) = user_panic {
        if !st.abort && st.panic.is_none() {
            st.panic = Some((ctx.id, msg));
        }
    }
    st.states[ctx.id] = VState::Finished;
    if st.turn == Some(ctx.id) {
        st.turn = None;
    }
    ctx.shared.cv.notify_all();
    drop(st);
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Run one execution of `bodies` under the scheduler. At every
/// quiescent point the controller hands the enabled-thread set to
/// `choose`, which returns the absolute id to grant next. Returns the
/// outcome, the full schedule taken, and the step count.
pub(crate) fn run_execution(
    bodies: Vec<Box<dyn FnOnce() + Send + 'static>>,
    mut choose: impl FnMut(usize, &[usize]) -> usize,
    max_steps: usize,
) -> ExecOutput {
    install_quiet_panic_hook();
    let n = bodies.len();
    assert!(n > 0, "an execution needs at least one virtual thread");
    let shared = Arc::new(SchedShared {
        m: Mutex::new(SchedState {
            turn: None,
            states: vec![VState::Ready; n],
            abort: false,
            panic: None,
        }),
        cv: Condvar::new(),
    });
    let mut handles = Vec::with_capacity(n);
    for (id, body) in bodies.into_iter().enumerate() {
        let ctx = Ctx {
            shared: shared.clone(),
            id,
        };
        let h = std::thread::Builder::new()
            .name(format!("cmpq-vthread-{id}"))
            .stack_size(512 * 1024)
            .spawn(move || vthread_main(ctx, body))
            .expect("spawn model virtual thread");
        handles.push(h);
    }
    // From here on, every exit path — including a panicking chooser or
    // a tripped internal assert — aborts and joins the fleet.
    let teardown = Teardown {
        shared: shared.clone(),
        handles,
    };

    let mut schedule: Vec<usize> = Vec::new();
    let mut steps = 0usize;
    let outcome = loop {
        let mut st = shared.lock();
        // Wait until the previous grant is fully consumed.
        while st.turn.is_some() || st.states.iter().any(|s| *s == VState::Running) {
            st = shared.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if let Some((tid, msg)) = st.panic.take() {
            st.abort = true;
            shared.cv.notify_all();
            break RawOutcome::Panicked(tid, msg);
        }
        let enabled: Vec<usize> = st
            .states
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == VState::Ready)
            .map(|(i, _)| i)
            .collect();
        if enabled.is_empty() {
            if st.states.iter().all(|s| *s == VState::Finished) {
                break RawOutcome::AllFinished;
            }
            let blocked = st
                .states
                .iter()
                .enumerate()
                .filter_map(|(i, s)| match s {
                    VState::Blocked(r) => Some((i, *r)),
                    _ => None,
                })
                .collect();
            st.abort = true;
            shared.cv.notify_all();
            break RawOutcome::Deadlock(blocked);
        }
        if steps >= max_steps {
            st.abort = true;
            shared.cv.notify_all();
            break RawOutcome::StepLimit;
        }
        let pick = choose(steps, &enabled);
        assert!(
            enabled.contains(&pick),
            "chooser picked thread {pick} outside enabled set {enabled:?}"
        );
        schedule.push(pick);
        steps += 1;
        st.turn = Some(pick);
        shared.cv.notify_all();
        drop(st);
    };
    drop(teardown); // abort (no-op when all finished) + join everyone
    ExecOutput {
        outcome,
        schedule,
        steps: steps as u64,
    }
}
