//! Timing helpers for the benchmark harness.

use std::time::{Duration, Instant};

/// Monotonic nanosecond timestamp relative to an anchor. Cheaper to pass
/// around than `Instant` in per-op latency recording.
#[derive(Clone, Copy)]
pub struct Anchor(Instant);

impl Anchor {
    /// Anchor at the current instant.
    pub fn now() -> Self {
        Self(Instant::now())
    }

    /// Nanoseconds elapsed since the anchor.
    #[inline]
    pub fn ns(&self) -> u64 {
        self.0.elapsed().as_nanos() as u64
    }
}

/// Measure the wall-clock duration of `f`, returning `(result, duration)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// Throughput in items/second given a count and a duration.
pub fn items_per_sec(items: u64, dur: Duration) -> f64 {
    if dur.is_zero() {
        return f64::INFINITY;
    }
    items as f64 / dur.as_secs_f64()
}

/// Human-readable rate, e.g. "6.49M items/s".
pub fn fmt_rate(items_per_s: f64) -> String {
    if items_per_s >= 1e9 {
        format!("{:.2}G/s", items_per_s / 1e9)
    } else if items_per_s >= 1e6 {
        format!("{:.2}M/s", items_per_s / 1e6)
    } else if items_per_s >= 1e3 {
        format!("{:.2}K/s", items_per_s / 1e3)
    } else {
        format!("{:.1}/s", items_per_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_monotonic() {
        let a = Anchor::now();
        let t1 = a.ns();
        let t2 = a.ns();
        assert!(t2 >= t1);
    }

    #[test]
    fn timed_returns_result() {
        let (v, d) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn rate_math() {
        let r = items_per_sec(1_000_000, Duration::from_secs(1));
        assert!((r - 1e6).abs() < 1.0);
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(6.49e6), "6.49M/s");
        assert_eq!(fmt_rate(1.19e3), "1.19K/s");
        assert_eq!(fmt_rate(2.5e9), "2.50G/s");
        assert_eq!(fmt_rate(12.0), "12.0/s");
    }

    #[test]
    fn zero_duration_is_infinite() {
        assert!(items_per_sec(1, Duration::ZERO).is_infinite());
    }
}
