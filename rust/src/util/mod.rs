//! Small substrates the offline image forces us to own: PRNG, backoff,
//! CLI parsing, and timing helpers.

pub mod backoff;
pub mod cli;
pub mod json;
pub mod rng;
pub mod time;

pub use backoff::Backoff;
pub use rng::XorShift64;
