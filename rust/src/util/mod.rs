//! Small substrates the offline image forces us to own: PRNG, backoff,
//! consumer parking, CPU accounting, CLI parsing, and timing helpers.

pub mod backoff;
pub mod cli;
pub mod cpu;
pub mod json;
pub mod rng;
pub mod time;
pub mod wait;

pub use backoff::Backoff;
pub use rng::XorShift64;
pub use wait::WaitStrategy;
