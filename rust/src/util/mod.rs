//! Small substrates the offline image forces us to own: PRNG, backoff,
//! consumer parking (thread and async), a minimal executor, CPU
//! accounting, CLI parsing, and timing helpers.

pub mod backoff;
pub mod cli;
pub mod cpu;
pub mod executor;
pub mod failpoint;
pub mod json;
pub mod rng;
pub mod time;
pub mod wait;

pub use backoff::Backoff;
pub use executor::{block_on, Executor};
pub use rng::XorShift64;
pub use wait::{WaitStrategy, WakerSet};
