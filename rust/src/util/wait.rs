//! Lost-wakeup-safe consumer parking (DESIGN.md §8): an eventcount.
//!
//! The empty-queue wait path is a first-class design surface at serving
//! scale — a fleet that busy-spins through idle gaps burns whole cores
//! doing nothing. [`WaitStrategy`] lets consumers escalate spin → yield
//! → sleep *without* ever missing a wakeup, while producers that find
//! no waiters pay a single relaxed load (plus one fence) per push.
//!
//! # Protocol
//!
//! A waiter that found the queue empty:
//!
//! 1. [`WaitStrategy::register`] — announce itself (`waiters += 1`) and
//!    snapshot the current wakeup *epoch*.
//! 2. Re-check the queue. If an item appeared, [`WaitStrategy::cancel`]
//!    and take it — no sleep.
//! 3. [`WaitStrategy::wait`] / [`WaitStrategy::wait_deadline`] — sleep
//!    until the epoch moves past the snapshot.
//!
//! A producer, after publishing an item, calls
//! [`WaitStrategy::notify_if_waiting`]: a sequentially-consistent fence
//! followed by a relaxed load of the waiter count; only when waiters
//! are present does it take the lock, bump the epoch, and notify.
//!
//! # Async waiters
//!
//! The same edge drives futures (DESIGN.md §10): an async consumer
//! registers a [`std::task::Waker`] in the strategy's [`WakerSet`] via
//! [`WaitStrategy::register_waker`] — which participates in the *same*
//! `waiters` count and fence pair as a parking thread — re-polls its
//! wait condition, and only then returns `Pending`. Notifications
//! drain the set and wake every registered task, so a push between the
//! future's poll and its `Pending` cannot be lost, and the producer
//! fast path stays exactly one fence + one relaxed load when nobody
//! (thread *or* task) waits.
//!
//! # Why no wakeup is ever lost
//!
//! The race to exclude: producer publishes, consumer decides to sleep,
//! nobody ever wakes it. Both sides carry a seq-cst fence — the
//! consumer between its `waiters += 1` and its queue re-check (inside
//! [`WaitStrategy::register`]), the producer between its publication
//! and its waiter-count load (inside
//! [`WaitStrategy::notify_if_waiting`]) — so the two fences are
//! ordered in the single SC total order. If the producer's fence comes
//! first, the consumer's re-check (step 2) observes the publication:
//! it cancels and never sleeps. Otherwise the consumer's increment is
//! before the producer's fence, the producer's load reads
//! `waiters ≥ 1`, and it bumps the epoch under the lock; the sleeper
//! either observes the bump before blocking (the epoch check in step 3
//! runs under the same lock) or is woken by the notification. Either
//! way, progress.

// `AtomicUsize` is deliberately the raw std type (the `WakerSet` gate
// stays invisible to the model checker — see its docs); `Ordering` is
// shared by the shim and std types alike.
use std::sync::atomic::{AtomicUsize, Ordering};
use std::task::Waker;
use std::time::Instant;

// Real std primitives normally; model-checker shims under the
// `model-check` feature (the whole protocol below then runs, byte for
// byte, under the exhaustive schedule enumerator — DESIGN.md §9).
use crate::model::shim::{fence, AtomicU64, Condvar, Mutex};

/// Epoch snapshot returned by [`WaitStrategy::register`]; consumed by
/// [`WaitStrategy::wait`] / [`WaitStrategy::wait_deadline`].
#[derive(Debug, Clone, Copy)]
pub struct WaitToken(u64);

/// Eventcount-style parking primitive: spin-phase decisions happen at
/// the call site (see [`crate::util::Backoff::is_yielding`]); this type
/// owns the sleep phase and its lost-wakeup guarantee.
#[derive(Default)]
pub struct WaitStrategy {
    /// Wakeup epoch: bumped (under `lock`) by every notification.
    epoch: AtomicU64,
    /// Registered (parked or about-to-park) waiters — threads *and*
    /// async waker slots; the producer fast path checks only this.
    waiters: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
    /// Slow-path registry of async waiters (DESIGN.md §10). Touched
    /// only by registering futures and by notifications that already
    /// observed `waiters > 0`.
    wakers: WakerSet,
    /// Monotone count of sleep calls (threads that went past the
    /// re-check and into the condvar path). A raw `std` atomic, like
    /// the `WakerSet` gate, so the metrics plumbing stays invisible to
    /// the §9 model checker.
    sleeps: AtomicUsize,
}

impl WaitStrategy {
    /// A fresh strategy with no waiters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Announce this thread as a waiter and snapshot the wakeup epoch.
    ///
    /// The caller **must** re-check its wait condition (e.g. re-poll the
    /// queue) after this call and before sleeping; that re-check is what
    /// closes the lost-wakeup window (see the module docs). Every
    /// `register` must be paired with exactly one [`Self::cancel`] or
    /// one wait call; when the code between the two can unwind, use
    /// [`Self::registration`] instead, which pairs them by RAII.
    pub fn register(&self) -> WaitToken {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        // Fence-pair with `notify_if_waiting`'s fence: an SC RMW alone
        // does not order the caller's *subsequent* (acquire) re-check
        // against the producer's publication on weakly-ordered targets.
        // With both fences, whichever comes first in the SC order,
        // either the producer's load observes the increment (→ it
        // notifies) or the re-check observes the publication.
        fence(Ordering::SeqCst);
        WaitToken(self.epoch.load(Ordering::SeqCst))
    }

    /// Deregister without sleeping (the re-check found the condition
    /// satisfied).
    pub fn cancel(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Announce this thread as a waiter with RAII deregistration: the
    /// returned [`WaitRegistration`] cancels on drop, so a panic (or a
    /// poisoned-lock unwind inside a wait) between registration and
    /// sleep can never leak the `waiters` count. Prefer this over the
    /// raw [`Self::register`]/[`Self::cancel`] pair whenever arbitrary
    /// code (a queue re-poll, say) runs between the two.
    pub fn registration(&self) -> WaitRegistration<'_> {
        WaitRegistration {
            ws: self,
            token: self.register(),
        }
    }

    /// Sleep until the epoch moves past `token`'s snapshot. Returns
    /// immediately if it already has. Deregisters on return — including
    /// by unwind, if the internal lock was poisoned by a panicking
    /// waiter (the panic propagates, the waiter count does not leak).
    pub fn wait(&self, token: WaitToken) {
        WaitRegistration { ws: self, token }.wait();
    }

    /// Sleep until the epoch moves past `token`'s snapshot or `deadline`
    /// passes. Returns `true` when woken by a notification, `false` on
    /// deadline expiry. Deregisters on return (unwind included, as with
    /// [`Self::wait`]).
    pub fn wait_deadline(&self, token: WaitToken, deadline: Instant) -> bool {
        WaitRegistration { ws: self, token }.wait_deadline(deadline)
    }

    /// The sleep loop of [`Self::wait`]; panics (propagating poison)
    /// without touching the waiter count — callers hold a
    /// [`WaitRegistration`] for that.
    fn sleep_until_notified(&self, token: WaitToken) {
        self.sleeps.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.lock.lock().unwrap();
        while self.epoch.load(Ordering::SeqCst) == token.0 {
            guard = self.cv.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// The sleep loop of [`Self::wait_deadline`]; same unwind contract
    /// as [`Self::sleep_until_notified`].
    ///
    /// Under the model checker the expiry edge is not modeled (virtual
    /// time does not advance — mirroring the model condvar's
    /// never-times-out rule), so the wait is wakeup-edge only there;
    /// a wall-clock check would make identical schedules diverge on a
    /// loaded machine. `shims_active()` is constant `false` in normal
    /// builds.
    fn sleep_until_notified_or_deadline(&self, token: WaitToken, deadline: Instant) -> bool {
        self.sleeps.fetch_add(1, Ordering::Relaxed);
        let model = crate::model::shims_active();
        let mut guard = self.lock.lock().unwrap();
        let mut woken = true;
        while self.epoch.load(Ordering::SeqCst) == token.0 {
            if model {
                guard = self.cv.wait(guard).unwrap();
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                woken = false;
                break;
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
        drop(guard);
        woken
    }

    /// Producer-side fast path: wake all waiters iff any are registered.
    ///
    /// Call *after* publishing the state change waiters poll for. Costs
    /// one seq-cst fence plus one relaxed load when nobody is waiting —
    /// the common case for a busy queue — and only touches the lock and
    /// condvar when a consumer is (about to be) parked.
    pub fn notify_if_waiting(&self) {
        fence(Ordering::SeqCst);
        if self.waiters.load(Ordering::Relaxed) == 0 {
            return;
        }
        self.notify_all();
    }

    /// Unconditionally bump the epoch and wake every waiter (shutdown /
    /// drain paths, where "no waiters registered *yet*" must still
    /// prevent a later sleeper from stranding: the sleeper's epoch
    /// snapshot happens after this bump, so its own re-check covers it).
    ///
    /// Async waiters are woken too: every waker registered in the
    /// strategy's [`WakerSet`] is drained and invoked. As with parked
    /// threads, this is a *wake*, not a cancellation — a woken future
    /// that still finds its condition unmet re-registers on its next
    /// poll (DESIGN.md §10).
    pub fn notify_all(&self) {
        let guard = self.lock.lock().unwrap();
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(guard);
        self.cv.notify_all();
        let drained = self.wakers.drain();
        if !drained.is_empty() {
            // One decrement per drained slot — the slot's registration
            // incremented `waiters` exactly once, and `deregister_waker`
            // on a drained key is a no-op (the slot is gone).
            self.waiters.fetch_sub(drained.len() as u64, Ordering::SeqCst);
            for waker in drained {
                waker.wake();
            }
        }
    }

    /// Announce an async waiter: store `waker` in the strategy's
    /// [`WakerSet`] and count it in the same `waiters` total the
    /// producer fast path checks. The slot is stamped with the current
    /// wakeup epoch.
    ///
    /// The caller **must** re-check its wait condition after this call
    /// and before returning `Pending` — exactly like the thread
    /// protocol's step 2 (see the module docs): the seq-cst fence at
    /// the end of this call pairs with [`Self::notify_if_waiting`]'s,
    /// so either the re-check observes the publication or the producer
    /// observes the registration and wakes the stored waker.
    ///
    /// Every registration is balanced by exactly one of: a
    /// notification draining the slot, or one successful
    /// [`Self::deregister_waker`] (futures call it on completion and
    /// from `Drop`, so cancellation never leaks a slot).
    pub fn register_waker(&self, waker: &Waker) -> WakerKey {
        // Count first, slot second: a concurrent notification that
        // drains the fresh slot decrements a count we have already
        // added (never underflows), while a drain that misses the slot
        // ordered the slot mutex before our insert — in which case the
        // caller's re-poll is ordered after the state change that
        // prompted the notification and observes it (DESIGN.md §10).
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let epoch = self.epoch.load(Ordering::SeqCst);
        let key = self.wakers.insert(epoch, waker);
        fence(Ordering::SeqCst);
        key
    }

    /// Refresh the waker stored under `key` (tasks may migrate between
    /// polls). Returns `false` when the slot no longer exists — i.e. a
    /// notification consumed it since registration — in which case the
    /// caller must [`Self::register_waker`] afresh before it may return
    /// `Pending` again.
    pub fn update_waker(&self, key: WakerKey, waker: &Waker) -> bool {
        self.wakers.update(key, waker)
    }

    /// Remove the waker slot `key` if it is still registered,
    /// decrementing the waiter count it contributed. Returns whether
    /// the slot was present (a `false` means a notification already
    /// drained — and accounted for — it). Idempotent per key.
    pub fn deregister_waker(&self, key: WakerKey) -> bool {
        let removed = self.wakers.remove(key);
        if removed {
            self.waiters.fetch_sub(1, Ordering::SeqCst);
        }
        removed
    }

    /// Currently registered async waker slots (diagnostics).
    pub fn registered_wakers(&self) -> usize {
        self.wakers.len()
    }

    /// Currently registered waiters — parked/parking threads plus
    /// registered async waker slots (diagnostics; racy by nature).
    pub fn waiters(&self) -> u64 {
        self.waiters.load(Ordering::Relaxed)
    }

    /// Monotone count of wait calls that reached the sleep loop —
    /// registrations whose re-check still found nothing (exported as a
    /// counter by the `/metrics` endpoint).
    pub fn sleeps(&self) -> u64 {
        self.sleeps.load(Ordering::Relaxed) as u64
    }
}

/// RAII waiter registration from [`WaitStrategy::registration`].
///
/// Holds the `waiters` increment; dropping it — normally, or during an
/// unwind from a panicking re-poll or a poisoned internal lock —
/// performs exactly one decrement. Without this guard, a panic between
/// `register` and `cancel`/`wait` would permanently inflate the waiter
/// count and force every future
/// [`WaitStrategy::notify_if_waiting`] onto the lock path.
pub struct WaitRegistration<'a> {
    ws: &'a WaitStrategy,
    token: WaitToken,
}

impl WaitRegistration<'_> {
    /// The epoch snapshot taken at registration.
    pub fn token(&self) -> WaitToken {
        self.token
    }

    /// Sleep until the epoch moves past the registration's snapshot
    /// (consumes the registration; deregisters on return or unwind).
    pub fn wait(self) {
        self.ws.sleep_until_notified(self.token);
        // `self` drops here → the single decrement.
    }

    /// Sleep until notified or `deadline` passes; `true` = woken.
    /// Consumes the registration; deregisters on return or unwind.
    pub fn wait_deadline(self, deadline: Instant) -> bool {
        self.ws.sleep_until_notified_or_deadline(self.token, deadline)
        // `self` drops here → the single decrement.
    }
}

impl Drop for WaitRegistration<'_> {
    fn drop(&mut self) {
        self.ws.cancel();
    }
}

/// Key naming one registered slot in a [`WakerSet`] (returned by
/// [`WaitStrategy::register_waker`] / [`WakerSet::insert`]). Keys are
/// never reused within one set, so a stale key held after its slot was
/// drained simply misses (`update`/`remove` return `false`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WakerKey(u64);

/// One registered async waiter: its key, the wakeup epoch observed at
/// registration (diagnostics — a drained slot's stamp is always ≤ the
/// epoch of the notification that drained it), and the waker to invoke.
struct WakerSlot {
    key: u64,
    epoch: u64,
    waker: Waker,
}

/// Slow-path registry of [`Waker`]s awaiting a notification — the
/// async half of the eventcount (DESIGN.md §10).
///
/// All mutation goes through an internal mutex: registration, refresh
/// and removal happen only on futures' slow paths (a queue that came
/// up empty), and draining happens only inside a notification that
/// already observed a nonzero waiter count. A `len` gate kept outside
/// the mutex lets notifiers skip the lock entirely when no async
/// waiter exists; the seq-cst fence pair of the surrounding eventcount
/// protocol is what makes that gate safe to trust (see
/// [`WaitStrategy::register_waker`] and DESIGN.md §10).
///
/// Deliberately built on `std` primitives rather than the model-check
/// shims: the §9 schedule enumerator never drives async waiters, and
/// keeping this registry invisible to it leaves the enumerated state
/// spaces of the thread protocol unchanged.
#[derive(Default)]
pub struct WakerSet {
    slots: std::sync::Mutex<WakerSlots>,
    /// Mirror of `slots.len()`, maintained under the mutex, readable
    /// without it (the notifier's skip gate).
    len: AtomicUsize,
}

#[derive(Default)]
struct WakerSlots {
    slots: Vec<WakerSlot>,
    next_key: u64,
}

impl WakerSet {
    /// An empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `waker` stamped with `epoch`; returns the slot's key.
    pub fn insert(&self, epoch: u64, waker: &Waker) -> WakerKey {
        let mut inner = self.slots.lock().unwrap();
        let key = inner.next_key;
        inner.next_key += 1;
        inner.slots.push(WakerSlot {
            key,
            epoch,
            waker: waker.clone(),
        });
        self.len.store(inner.slots.len(), Ordering::Release);
        WakerKey(key)
    }

    /// Replace the waker stored under `key`; `false` when the slot no
    /// longer exists (a drain consumed it).
    pub fn update(&self, key: WakerKey, waker: &Waker) -> bool {
        let mut inner = self.slots.lock().unwrap();
        match inner.slots.iter_mut().find(|s| s.key == key.0) {
            Some(slot) => {
                if !slot.waker.will_wake(waker) {
                    slot.waker = waker.clone();
                }
                true
            }
            None => false,
        }
    }

    /// Remove the slot under `key`; `false` when it no longer exists.
    pub fn remove(&self, key: WakerKey) -> bool {
        let mut inner = self.slots.lock().unwrap();
        match inner.slots.iter().position(|s| s.key == key.0) {
            Some(i) => {
                inner.slots.swap_remove(i);
                self.len.store(inner.slots.len(), Ordering::Release);
                true
            }
            None => false,
        }
    }

    /// Take every registered waker out of the set (the notification
    /// edge). Callers invoke the returned wakers *after* releasing
    /// their own locks. Returns an empty vector — without touching the
    /// mutex — when the gate shows no registrations.
    pub fn drain(&self) -> Vec<Waker> {
        if self.len.load(Ordering::Acquire) == 0 {
            return Vec::new();
        }
        let mut inner = self.slots.lock().unwrap();
        self.len.store(0, Ordering::Release);
        let slots = std::mem::take(&mut inner.slots);
        slots.into_iter().map(|s| s.waker).collect()
    }

    /// Registered slot count (diagnostics; racy by nature).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    /// Whether no waker is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Epoch stamp of the slot under `key` (diagnostics/tests); `None`
    /// when the slot no longer exists.
    pub fn epoch_of(&self, key: WakerKey) -> Option<u64> {
        let inner = self.slots.lock().unwrap();
        inner.slots.iter().find(|s| s.key == key.0).map(|s| s.epoch)
    }
}

/// Reusable waker-slot handle for futures parking on a
/// [`WaitStrategy`]: tracks the [`WakerKey`] across polls so each
/// `Pending` return refreshes (rather than re-registers) the slot, and
/// a consumed slot — a notification drained it — is transparently
/// re-registered. This is the async half of the eventcount protocol
/// packaged for reuse: the CMP pop futures and the Vyukov
/// producer-side `push_async` both park through it.
///
/// The owner must call [`WakerRegistration::clear`] when the future
/// resolves or drops; leaking a registered slot inflates the waiter
/// count and turns every producer notification into a locked drain.
#[derive(Default)]
pub struct WakerRegistration {
    key: Option<WakerKey>,
}

impl WakerRegistration {
    /// An empty (unregistered) handle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Make sure `waker` is registered on `ws`: refresh the existing
    /// slot when it survives, register afresh when it was drained (or
    /// never existed). Call *before* re-checking the wait condition,
    /// per the eventcount protocol — register, re-check, then
    /// `Pending`.
    pub fn ensure(&mut self, ws: &WaitStrategy, waker: &Waker) {
        match self.key {
            Some(key) if ws.update_waker(key, waker) => {}
            _ => self.key = Some(ws.register_waker(waker)),
        }
    }

    /// Drop the slot if still registered. Idempotent; a slot already
    /// consumed by a notification is a no-op.
    pub fn clear(&mut self, ws: &WaitStrategy) {
        if let Some(key) = self.key.take() {
            ws.deregister_waker(key);
        }
    }

    /// Whether a slot key is currently held (it may already have been
    /// consumed by a notification — `ensure` repairs that).
    pub fn is_registered(&self) -> bool {
        self.key.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn register_cancel_balances_waiters() {
        let ws = WaitStrategy::new();
        assert_eq!(ws.waiters(), 0);
        let _t = ws.register();
        assert_eq!(ws.waiters(), 1);
        ws.cancel();
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn notify_if_waiting_skips_lock_when_idle() {
        let ws = WaitStrategy::new();
        // No waiters: must not bump the epoch (fast path taken).
        ws.notify_if_waiting();
        let t = ws.register();
        ws.cancel();
        // Epoch unchanged → a wait on the stale token would block, so
        // check it via the atomic instead.
        assert_eq!(ws.epoch.load(Ordering::SeqCst), t.0);
    }

    #[test]
    fn wait_returns_immediately_after_missed_epoch() {
        let ws = WaitStrategy::new();
        let t = ws.register();
        ws.notify_all(); // epoch moves while we are "re-checking"
        ws.wait(t); // must not block
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn parked_thread_is_woken_by_notify() {
        let ws = Arc::new(WaitStrategy::new());
        let ready = Arc::new(AtomicBool::new(false));
        let (ws2, ready2) = (ws.clone(), ready.clone());
        let h = std::thread::spawn(move || {
            let t = ws2.register();
            ready2.store(true, Ordering::Release);
            ws2.wait(t);
        });
        while !ready.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        // The waiter is registered; notify_if_waiting must take the
        // slow path and wake it.
        ws.notify_if_waiting();
        h.join().unwrap();
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn registration_cancels_on_drop() {
        let ws = WaitStrategy::new();
        {
            let reg = ws.registration();
            assert_eq!(ws.waiters(), 1);
            let _ = reg.token();
        }
        assert_eq!(ws.waiters(), 0, "drop must deregister");
    }

    #[test]
    fn registration_cancels_on_unwind() {
        let ws = WaitStrategy::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _reg = ws.registration();
            panic!("re-poll blew up");
        }));
        assert!(r.is_err());
        assert_eq!(ws.waiters(), 0, "unwind must deregister");
    }

    #[test]
    fn poisoned_lock_does_not_leak_waiters() {
        let ws = Arc::new(WaitStrategy::new());
        // Poison the internal lock with a panicking holder.
        let ws2 = ws.clone();
        let _ = std::thread::spawn(move || {
            let _guard = ws2.lock.lock().unwrap();
            panic!("poison the wait lock");
        })
        .join();
        let token = ws.register();
        assert_eq!(ws.waiters(), 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ws.wait(token)));
        assert!(r.is_err(), "poison must propagate as a panic");
        assert_eq!(
            ws.waiters(),
            0,
            "waiter count must not leak through the poison unwind"
        );
        // The deadline path unwinds identically.
        let token = ws.register();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ws.wait_deadline(token, Instant::now() + Duration::from_millis(5))
        }));
        assert!(r.is_err());
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn sleeps_counter_counts_wait_calls() {
        let ws = WaitStrategy::new();
        assert_eq!(ws.sleeps(), 0);
        let t = ws.register();
        ws.notify_all(); // epoch moves: the wait below returns at once…
        ws.wait(t);
        assert_eq!(ws.sleeps(), 1, "…but still reached the sleep loop");
        let t = ws.register();
        let _ = ws.wait_deadline(t, Instant::now() + Duration::from_millis(1));
        assert_eq!(ws.sleeps(), 2);
        ws.notify_if_waiting(); // fast path: no waiters, no sleep
        assert_eq!(ws.sleeps(), 2);
    }

    #[test]
    fn wait_deadline_times_out() {
        let ws = WaitStrategy::new();
        let t = ws.register();
        let t0 = Instant::now();
        let woken = ws.wait_deadline(t, t0 + Duration::from_millis(30));
        assert!(!woken, "nobody notified");
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(ws.waiters(), 0);
    }

    /// Test waker that counts its wakes.
    struct CountWake(std::sync::atomic::AtomicUsize);

    impl std::task::Wake for CountWake {
        fn wake(self: Arc<Self>) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn count_waker() -> (Arc<CountWake>, Waker) {
        let cw = Arc::new(CountWake(std::sync::atomic::AtomicUsize::new(0)));
        let waker = Waker::from(cw.clone());
        (cw, waker)
    }

    #[test]
    fn register_waker_counts_as_waiter() {
        let ws = WaitStrategy::new();
        let (_cw, waker) = count_waker();
        let key = ws.register_waker(&waker);
        assert_eq!(ws.waiters(), 1, "waker slots share the waiter count");
        assert_eq!(ws.registered_wakers(), 1);
        assert!(ws.deregister_waker(key));
        assert_eq!(ws.waiters(), 0);
        assert_eq!(ws.registered_wakers(), 0);
        assert!(!ws.deregister_waker(key), "second deregister is a no-op");
        assert_eq!(ws.waiters(), 0, "no double decrement");
    }

    #[test]
    fn notify_drains_and_wakes_registered_wakers() {
        let ws = WaitStrategy::new();
        let (cw, waker) = count_waker();
        let key = ws.register_waker(&waker);
        ws.notify_if_waiting();
        assert_eq!(cw.0.load(Ordering::SeqCst), 1, "waker invoked");
        assert_eq!(ws.waiters(), 0, "drain decremented the count");
        assert_eq!(ws.registered_wakers(), 0);
        assert!(!ws.update_waker(key, &waker), "slot consumed by the drain");
        assert!(!ws.deregister_waker(key), "nothing left to deregister");
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn update_waker_refreshes_live_slot() {
        let ws = WaitStrategy::new();
        let (cw1, waker1) = count_waker();
        let (cw2, waker2) = count_waker();
        let key = ws.register_waker(&waker1);
        assert!(ws.update_waker(key, &waker2), "slot still live");
        ws.notify_all();
        assert_eq!(cw1.0.load(Ordering::SeqCst), 0, "replaced waker not woken");
        assert_eq!(cw2.0.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn notify_wakes_threads_and_wakers_together() {
        let ws = Arc::new(WaitStrategy::new());
        let (cw, waker) = count_waker();
        let _key = ws.register_waker(&waker);
        let ws2 = ws.clone();
        let h = std::thread::spawn(move || {
            let t = ws2.register();
            ws2.wait(t);
        });
        while ws.waiters() < 2 {
            std::thread::yield_now();
        }
        ws.notify_if_waiting();
        h.join().unwrap();
        assert_eq!(cw.0.load(Ordering::SeqCst), 1);
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn idle_notify_leaves_waker_set_untouched() {
        let ws = WaitStrategy::new();
        ws.notify_if_waiting(); // fast path: no waiters of either kind
        let (cw, waker) = count_waker();
        let key = ws.register_waker(&waker);
        assert_eq!(cw.0.load(Ordering::SeqCst), 0, "nothing woke it yet");
        assert!(ws.deregister_waker(key));
    }

    #[test]
    fn waker_set_standalone_semantics() {
        let set = WakerSet::new();
        assert!(set.is_empty());
        let (cw, waker) = count_waker();
        let k1 = set.insert(3, &waker);
        let k2 = set.insert(5, &waker);
        assert_eq!(set.len(), 2);
        assert_eq!(set.epoch_of(k1), Some(3));
        assert_eq!(set.epoch_of(k2), Some(5));
        assert!(set.remove(k1));
        assert!(!set.remove(k1), "keys are not reused");
        let drained = set.drain();
        assert_eq!(drained.len(), 1);
        for w in drained {
            w.wake();
        }
        assert_eq!(cw.0.load(Ordering::SeqCst), 1);
        assert!(set.is_empty());
        assert_eq!(set.epoch_of(k2), None);
        assert!(set.drain().is_empty(), "gate short-circuits when empty");
    }

    #[test]
    fn wait_deadline_wakes_early_on_notify() {
        let ws = Arc::new(WaitStrategy::new());
        let ws2 = ws.clone();
        let h = std::thread::spawn(move || {
            let t = ws2.register();
            ws2.wait_deadline(t, Instant::now() + Duration::from_secs(30))
        });
        while ws.waiters() == 0 {
            std::thread::yield_now();
        }
        ws.notify_all();
        assert!(h.join().unwrap(), "woken, not timed out");
    }
}
