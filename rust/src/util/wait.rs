//! Lost-wakeup-safe consumer parking (DESIGN.md §8): an eventcount.
//!
//! The empty-queue wait path is a first-class design surface at serving
//! scale — a fleet that busy-spins through idle gaps burns whole cores
//! doing nothing. [`WaitStrategy`] lets consumers escalate spin → yield
//! → sleep *without* ever missing a wakeup, while producers that find
//! no waiters pay a single relaxed load (plus one fence) per push.
//!
//! # Protocol
//!
//! A waiter that found the queue empty:
//!
//! 1. [`WaitStrategy::register`] — announce itself (`waiters += 1`) and
//!    snapshot the current wakeup *epoch*.
//! 2. Re-check the queue. If an item appeared, [`WaitStrategy::cancel`]
//!    and take it — no sleep.
//! 3. [`WaitStrategy::wait`] / [`WaitStrategy::wait_deadline`] — sleep
//!    until the epoch moves past the snapshot.
//!
//! A producer, after publishing an item, calls
//! [`WaitStrategy::notify_if_waiting`]: a sequentially-consistent fence
//! followed by a relaxed load of the waiter count; only when waiters
//! are present does it take the lock, bump the epoch, and notify.
//!
//! # Why no wakeup is ever lost
//!
//! The race to exclude: producer publishes, consumer decides to sleep,
//! nobody ever wakes it. Both sides carry a seq-cst fence — the
//! consumer between its `waiters += 1` and its queue re-check (inside
//! [`WaitStrategy::register`]), the producer between its publication
//! and its waiter-count load (inside
//! [`WaitStrategy::notify_if_waiting`]) — so the two fences are
//! ordered in the single SC total order. If the producer's fence comes
//! first, the consumer's re-check (step 2) observes the publication:
//! it cancels and never sleeps. Otherwise the consumer's increment is
//! before the producer's fence, the producer's load reads
//! `waiters ≥ 1`, and it bumps the epoch under the lock; the sleeper
//! either observes the bump before blocking (the epoch check in step 3
//! runs under the same lock) or is woken by the notification. Either
//! way, progress.

use std::sync::atomic::Ordering;
use std::time::Instant;

// Real std primitives normally; model-checker shims under the
// `model-check` feature (the whole protocol below then runs, byte for
// byte, under the exhaustive schedule enumerator — DESIGN.md §9).
use crate::model::shim::{fence, AtomicU64, Condvar, Mutex};

/// Epoch snapshot returned by [`WaitStrategy::register`]; consumed by
/// [`WaitStrategy::wait`] / [`WaitStrategy::wait_deadline`].
#[derive(Debug, Clone, Copy)]
pub struct WaitToken(u64);

/// Eventcount-style parking primitive: spin-phase decisions happen at
/// the call site (see [`crate::util::Backoff::is_yielding`]); this type
/// owns the sleep phase and its lost-wakeup guarantee.
#[derive(Default)]
pub struct WaitStrategy {
    /// Wakeup epoch: bumped (under `lock`) by every notification.
    epoch: AtomicU64,
    /// Registered (parked or about-to-park) waiters.
    waiters: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

impl WaitStrategy {
    /// A fresh strategy with no waiters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Announce this thread as a waiter and snapshot the wakeup epoch.
    ///
    /// The caller **must** re-check its wait condition (e.g. re-poll the
    /// queue) after this call and before sleeping; that re-check is what
    /// closes the lost-wakeup window (see the module docs). Every
    /// `register` must be paired with exactly one [`Self::cancel`] or
    /// one wait call; when the code between the two can unwind, use
    /// [`Self::registration`] instead, which pairs them by RAII.
    pub fn register(&self) -> WaitToken {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        // Fence-pair with `notify_if_waiting`'s fence: an SC RMW alone
        // does not order the caller's *subsequent* (acquire) re-check
        // against the producer's publication on weakly-ordered targets.
        // With both fences, whichever comes first in the SC order,
        // either the producer's load observes the increment (→ it
        // notifies) or the re-check observes the publication.
        fence(Ordering::SeqCst);
        WaitToken(self.epoch.load(Ordering::SeqCst))
    }

    /// Deregister without sleeping (the re-check found the condition
    /// satisfied).
    pub fn cancel(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Announce this thread as a waiter with RAII deregistration: the
    /// returned [`WaitRegistration`] cancels on drop, so a panic (or a
    /// poisoned-lock unwind inside a wait) between registration and
    /// sleep can never leak the `waiters` count. Prefer this over the
    /// raw [`Self::register`]/[`Self::cancel`] pair whenever arbitrary
    /// code (a queue re-poll, say) runs between the two.
    pub fn registration(&self) -> WaitRegistration<'_> {
        WaitRegistration {
            ws: self,
            token: self.register(),
        }
    }

    /// Sleep until the epoch moves past `token`'s snapshot. Returns
    /// immediately if it already has. Deregisters on return — including
    /// by unwind, if the internal lock was poisoned by a panicking
    /// waiter (the panic propagates, the waiter count does not leak).
    pub fn wait(&self, token: WaitToken) {
        WaitRegistration { ws: self, token }.wait();
    }

    /// Sleep until the epoch moves past `token`'s snapshot or `deadline`
    /// passes. Returns `true` when woken by a notification, `false` on
    /// deadline expiry. Deregisters on return (unwind included, as with
    /// [`Self::wait`]).
    pub fn wait_deadline(&self, token: WaitToken, deadline: Instant) -> bool {
        WaitRegistration { ws: self, token }.wait_deadline(deadline)
    }

    /// The sleep loop of [`Self::wait`]; panics (propagating poison)
    /// without touching the waiter count — callers hold a
    /// [`WaitRegistration`] for that.
    fn sleep_until_notified(&self, token: WaitToken) {
        let mut guard = self.lock.lock().unwrap();
        while self.epoch.load(Ordering::SeqCst) == token.0 {
            guard = self.cv.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// The sleep loop of [`Self::wait_deadline`]; same unwind contract
    /// as [`Self::sleep_until_notified`].
    ///
    /// Under the model checker the expiry edge is not modeled (virtual
    /// time does not advance — mirroring the model condvar's
    /// never-times-out rule), so the wait is wakeup-edge only there;
    /// a wall-clock check would make identical schedules diverge on a
    /// loaded machine. `shims_active()` is constant `false` in normal
    /// builds.
    fn sleep_until_notified_or_deadline(&self, token: WaitToken, deadline: Instant) -> bool {
        let model = crate::model::shims_active();
        let mut guard = self.lock.lock().unwrap();
        let mut woken = true;
        while self.epoch.load(Ordering::SeqCst) == token.0 {
            if model {
                guard = self.cv.wait(guard).unwrap();
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                woken = false;
                break;
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
        drop(guard);
        woken
    }

    /// Producer-side fast path: wake all waiters iff any are registered.
    ///
    /// Call *after* publishing the state change waiters poll for. Costs
    /// one seq-cst fence plus one relaxed load when nobody is waiting —
    /// the common case for a busy queue — and only touches the lock and
    /// condvar when a consumer is (about to be) parked.
    pub fn notify_if_waiting(&self) {
        fence(Ordering::SeqCst);
        if self.waiters.load(Ordering::Relaxed) == 0 {
            return;
        }
        self.notify_all();
    }

    /// Unconditionally bump the epoch and wake every waiter (shutdown /
    /// drain paths, where "no waiters registered *yet*" must still
    /// prevent a later sleeper from stranding: the sleeper's epoch
    /// snapshot happens after this bump, so its own re-check covers it).
    pub fn notify_all(&self) {
        let guard = self.lock.lock().unwrap();
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(guard);
        self.cv.notify_all();
    }

    /// Currently registered waiters (diagnostics; racy by nature).
    pub fn waiters(&self) -> u64 {
        self.waiters.load(Ordering::Relaxed)
    }
}

/// RAII waiter registration from [`WaitStrategy::registration`].
///
/// Holds the `waiters` increment; dropping it — normally, or during an
/// unwind from a panicking re-poll or a poisoned internal lock —
/// performs exactly one decrement. Without this guard, a panic between
/// `register` and `cancel`/`wait` would permanently inflate the waiter
/// count and force every future
/// [`WaitStrategy::notify_if_waiting`] onto the lock path.
pub struct WaitRegistration<'a> {
    ws: &'a WaitStrategy,
    token: WaitToken,
}

impl WaitRegistration<'_> {
    /// The epoch snapshot taken at registration.
    pub fn token(&self) -> WaitToken {
        self.token
    }

    /// Sleep until the epoch moves past the registration's snapshot
    /// (consumes the registration; deregisters on return or unwind).
    pub fn wait(self) {
        self.ws.sleep_until_notified(self.token);
        // `self` drops here → the single decrement.
    }

    /// Sleep until notified or `deadline` passes; `true` = woken.
    /// Consumes the registration; deregisters on return or unwind.
    pub fn wait_deadline(self, deadline: Instant) -> bool {
        self.ws.sleep_until_notified_or_deadline(self.token, deadline)
        // `self` drops here → the single decrement.
    }
}

impl Drop for WaitRegistration<'_> {
    fn drop(&mut self) {
        self.ws.cancel();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn register_cancel_balances_waiters() {
        let ws = WaitStrategy::new();
        assert_eq!(ws.waiters(), 0);
        let _t = ws.register();
        assert_eq!(ws.waiters(), 1);
        ws.cancel();
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn notify_if_waiting_skips_lock_when_idle() {
        let ws = WaitStrategy::new();
        // No waiters: must not bump the epoch (fast path taken).
        ws.notify_if_waiting();
        let t = ws.register();
        ws.cancel();
        // Epoch unchanged → a wait on the stale token would block, so
        // check it via the atomic instead.
        assert_eq!(ws.epoch.load(Ordering::SeqCst), t.0);
    }

    #[test]
    fn wait_returns_immediately_after_missed_epoch() {
        let ws = WaitStrategy::new();
        let t = ws.register();
        ws.notify_all(); // epoch moves while we are "re-checking"
        ws.wait(t); // must not block
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn parked_thread_is_woken_by_notify() {
        let ws = Arc::new(WaitStrategy::new());
        let ready = Arc::new(AtomicBool::new(false));
        let (ws2, ready2) = (ws.clone(), ready.clone());
        let h = std::thread::spawn(move || {
            let t = ws2.register();
            ready2.store(true, Ordering::Release);
            ws2.wait(t);
        });
        while !ready.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        // The waiter is registered; notify_if_waiting must take the
        // slow path and wake it.
        ws.notify_if_waiting();
        h.join().unwrap();
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn registration_cancels_on_drop() {
        let ws = WaitStrategy::new();
        {
            let reg = ws.registration();
            assert_eq!(ws.waiters(), 1);
            let _ = reg.token();
        }
        assert_eq!(ws.waiters(), 0, "drop must deregister");
    }

    #[test]
    fn registration_cancels_on_unwind() {
        let ws = WaitStrategy::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _reg = ws.registration();
            panic!("re-poll blew up");
        }));
        assert!(r.is_err());
        assert_eq!(ws.waiters(), 0, "unwind must deregister");
    }

    #[test]
    fn poisoned_lock_does_not_leak_waiters() {
        let ws = Arc::new(WaitStrategy::new());
        // Poison the internal lock with a panicking holder.
        let ws2 = ws.clone();
        let _ = std::thread::spawn(move || {
            let _guard = ws2.lock.lock().unwrap();
            panic!("poison the wait lock");
        })
        .join();
        let token = ws.register();
        assert_eq!(ws.waiters(), 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ws.wait(token)));
        assert!(r.is_err(), "poison must propagate as a panic");
        assert_eq!(
            ws.waiters(),
            0,
            "waiter count must not leak through the poison unwind"
        );
        // The deadline path unwinds identically.
        let token = ws.register();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ws.wait_deadline(token, Instant::now() + Duration::from_millis(5))
        }));
        assert!(r.is_err());
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn wait_deadline_times_out() {
        let ws = WaitStrategy::new();
        let t = ws.register();
        let t0 = Instant::now();
        let woken = ws.wait_deadline(t, t0 + Duration::from_millis(30));
        assert!(!woken, "nobody notified");
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(ws.waiters(), 0);
    }

    #[test]
    fn wait_deadline_wakes_early_on_notify() {
        let ws = Arc::new(WaitStrategy::new());
        let ws2 = ws.clone();
        let h = std::thread::spawn(move || {
            let t = ws2.register();
            ws2.wait_deadline(t, Instant::now() + Duration::from_secs(30))
        });
        while ws.waiters() == 0 {
            std::thread::yield_now();
        }
        ws.notify_all();
        assert!(h.join().unwrap(), "woken, not timed out");
    }
}
