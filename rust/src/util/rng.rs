//! Deterministic PRNGs for workload generation and the property-test
//! harness (proptest is not vendored in this image — see DESIGN.md §3).

/// xorshift64* — fast, decent-quality 64-bit PRNG with a 2^64-1 period.
/// Deterministic given a seed, which is what the property tests and the
/// benchmark workload generator need for reproducibility.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a PRNG. The state is guaranteed nonzero: zero is
    /// xorshift's fixed point, so a zero *state* (not just a zero seed
    /// — `splitmix64` is a bijection, and exactly one seed,
    /// `0x61C8864680B583EB`, spreads to 0) would emit an all-zero
    /// stream forever and silently wedge every consumer, e.g. the CMP
    /// Bernoulli reclamation trigger.
    pub fn new(seed: u64) -> Self {
        let spread = splitmix64(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed });
        Self {
            // Golden-ratio fallback for the one seed that spreads to 0.
            state: if spread == 0 { 0x9E3779B97F4A7C15 } else { spread },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift range reduction (Lemire). Slight modulo bias is
        // irrelevant for workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn next_usize(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

/// splitmix64 — used to spread seeds so nearby seeds give unrelated streams.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn zero_state_preimage_seed_still_streams() {
        // splitmix64 is a bijection; this is the unique seed it maps to
        // 0 — previously that seed produced an all-zero xorshift state,
        // i.e. a PRNG stuck at 0 forever (`chance(p)` then returns a
        // constant, wedging the Bernoulli reclamation trigger for any
        // thread whose id hashed to this value).
        const PREIMAGE_OF_ZERO: u64 = 0x61C8864680B583EB;
        assert_eq!(splitmix64(PREIMAGE_OF_ZERO), 0, "preimage constant");
        let mut r = XorShift64::new(PREIMAGE_OF_ZERO);
        let (a, b) = (r.next_u64(), r.next_u64());
        assert_ne!(a, 0, "state must not be the all-zero fixed point");
        assert_ne!(b, 0);
        assert_ne!(a, b, "stream must advance");
        // And the Bernoulli consumer behaves sanely again.
        let mut r = XorShift64::new(PREIMAGE_OF_ZERO);
        let hits = (0..1000).filter(|_| r.chance(0.5)).count();
        assert!((300..700).contains(&hits), "hits={hits}");
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_small_range() {
        let mut r = XorShift64::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.next_below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = XorShift64::new(5);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }

    #[test]
    fn chance_is_roughly_calibrated() {
        let mut r = XorShift64::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(13);
        let mut v: Vec<u32> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
        assert_ne!(v, (0..64).collect::<Vec<_>>(), "64 elements should move");
    }

    #[test]
    fn splitmix_spreads_consecutive_seeds() {
        let a = splitmix64(1);
        let b = splitmix64(2);
        assert_ne!(a ^ b, 0);
        assert!((a ^ b).count_ones() > 8);
    }
}
