//! Process CPU-time accounting for the ops-per-CPU-second benchmark
//! metric (DESIGN.md §8).
//!
//! Wall-clock throughput cannot distinguish a consumer that parks
//! through idle gaps from one that burns a core spinning; CPU time can.
//! Linux exposes the process totals in `/proc/self/stat` as `utime` /
//! `stime` in USER_HZ ticks; the USER_HZ userspace ABI is fixed at 100
//! regardless of the kernel's internal tick rate. On platforms without
//! procfs the probe returns `None` and callers report the metric as
//! unavailable instead of guessing.

/// Linux USER_HZ: the `/proc` clock-tick ABI, fixed at 100 ticks/s.
const USER_HZ: f64 = 100.0;

/// CPU seconds (user + system) consumed by this process so far, or
/// `None` when `/proc/self/stat` is unavailable or unparseable.
///
/// Resolution is one tick (10 ms); take differences across work that
/// runs long enough to amortize it.
pub fn process_cpu_seconds() -> Option<f64> {
    parse_stat_cpu_ticks(&std::fs::read_to_string("/proc/self/stat").ok()?)
        .map(|ticks| ticks as f64 / USER_HZ)
}

/// `utime + stime` ticks out of a `/proc/<pid>/stat` line. The comm
/// field (field 2) may itself contain spaces or parentheses, so fields
/// are counted from the *last* `)`: `state` is field 3, `utime` and
/// `stime` are fields 14 and 15.
fn parse_stat_cpu_ticks(stat: &str) -> Option<u64> {
    let rest = stat.rsplit_once(')')?.1;
    let mut fields = rest.split_whitespace();
    let utime: u64 = fields.nth(11)?.parse().ok()?;
    let stime: u64 = fields.next()?.parse().ok()?;
    Some(utime + stime)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_stat_line_with_hostile_comm() {
        // comm containing spaces and a ')' — fields must still line up.
        let line = "1234 (a b) c) R 1 1 1 0 -1 4194304 100 0 0 0 \
                    7 3 0 0 20 0 1 0 100 1000 10 18446744073709551615";
        assert_eq!(parse_stat_cpu_ticks(line), Some(10));
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(parse_stat_cpu_ticks("no parens here"), None);
        assert_eq!(parse_stat_cpu_ticks("1 (x) R 1"), None);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn live_probe_is_monotonic() {
        let a = process_cpu_seconds().expect("/proc/self/stat readable");
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i ^ (acc >> 3));
        }
        std::hint::black_box(acc);
        let b = process_cpu_seconds().unwrap();
        assert!(b >= a, "CPU time went backwards: {a} -> {b}");
    }
}
