//! Process CPU-time accounting for the ops-per-CPU-second benchmark
//! metric (DESIGN.md §8), plus best-effort thread→core pinning for the
//! sharded fabric (DESIGN.md §13).
//!
//! Wall-clock throughput cannot distinguish a consumer that parks
//! through idle gaps from one that burns a core spinning; CPU time can.
//! Linux exposes the process totals in `/proc/self/stat` as `utime` /
//! `stime` in USER_HZ ticks; the USER_HZ userspace ABI is fixed at 100
//! regardless of the kernel's internal tick rate. On platforms without
//! procfs the probe returns `None` and callers report the metric as
//! unavailable instead of guessing.
//!
//! Pinning goes straight to glibc's `sched_setaffinity` (already
//! linked through `std` — the offline image forbids a `libc` crate);
//! failures are reported, never fatal, because affinity is a
//! performance hint, not a correctness requirement.

/// Linux USER_HZ: the `/proc` clock-tick ABI, fixed at 100 ticks/s.
const USER_HZ: f64 = 100.0;

/// CPU seconds (user + system) consumed by this process so far, or
/// `None` when `/proc/self/stat` is unavailable or unparseable.
///
/// Resolution is one tick (10 ms); take differences across work that
/// runs long enough to amortize it.
pub fn process_cpu_seconds() -> Option<f64> {
    parse_stat_cpu_ticks(&std::fs::read_to_string("/proc/self/stat").ok()?)
        .map(|ticks| ticks as f64 / USER_HZ)
}

/// `utime + stime` ticks out of a `/proc/<pid>/stat` line. The comm
/// field (field 2) may itself contain spaces or parentheses, so fields
/// are counted from the *last* `)`: `state` is field 3, `utime` and
/// `stime` are fields 14 and 15.
fn parse_stat_cpu_ticks(stat: &str) -> Option<u64> {
    let rest = stat.rsplit_once(')')?.1;
    let mut fields = rest.split_whitespace();
    let utime: u64 = fields.nth(11)?.parse().ok()?;
    let stime: u64 = fields.next()?.parse().ok()?;
    Some(utime + stime)
}

/// Words in a glibc `cpu_set_t` (1024 bits / 64).
#[cfg(target_os = "linux")]
const CPU_SET_WORDS: usize = 16;

/// Pin the calling thread to `cpu`. Returns `false` when the CPU index
/// is out of the 1024-bit `cpu_set_t` range, the CPU is offline, or
/// the platform has no `sched_setaffinity` — callers treat pinning as
/// advisory and proceed unpinned.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpu: usize) -> bool {
    if cpu >= CPU_SET_WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; CPU_SET_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    set_affinity(&mask)
}

/// Non-Linux stub: pinning is unavailable, report `false`.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Undo [`pin_current_thread`]: allow the calling thread on every CPU
/// the kernel will accept (offline bits in the mask are ignored).
#[cfg(target_os = "linux")]
pub fn unpin_current_thread() -> bool {
    set_affinity(&[u64::MAX; CPU_SET_WORDS])
}

/// Non-Linux stub: nothing to undo.
#[cfg(not(target_os = "linux"))]
pub fn unpin_current_thread() -> bool {
    false
}

#[cfg(target_os = "linux")]
fn set_affinity(mask: &[u64; CPU_SET_WORDS]) -> bool {
    extern "C" {
        // glibc, linked through std; pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: `mask` points to CPU_SET_WORDS * 8 valid, initialized
    // bytes, matching the cpusetsize argument; the call only reads it.
    unsafe { sched_setaffinity(0, CPU_SET_WORDS * 8, mask.as_ptr()) == 0 }
}

/// CPUs available to this process (affinity-mask aware on Linux);
/// never 0.
pub fn online_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_stat_line_with_hostile_comm() {
        // comm containing spaces and a ')' — fields must still line up.
        let line = "1234 (a b) c) R 1 1 1 0 -1 4194304 100 0 0 0 \
                    7 3 0 0 20 0 1 0 100 1000 10 18446744073709551615";
        assert_eq!(parse_stat_cpu_ticks(line), Some(10));
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(parse_stat_cpu_ticks("no parens here"), None);
        assert_eq!(parse_stat_cpu_ticks("1 (x) R 1"), None);
    }

    #[test]
    fn online_cpus_is_positive() {
        assert!(online_cpus() >= 1);
    }

    #[test]
    fn pin_rejects_out_of_range_cpu() {
        assert!(!pin_current_thread(1 << 20));
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pin_and_unpin_round_trip() {
        // Pin to the first available CPU, then restore the full mask so
        // this test thread doesn't skew later tests on the same worker.
        assert!(pin_current_thread(0));
        assert!(unpin_current_thread());
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn live_probe_is_monotonic() {
        let a = process_cpu_seconds().expect("/proc/self/stat readable");
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i ^ (acc >> 3));
        }
        std::hint::black_box(acc);
        let b = process_cpu_seconds().unwrap();
        assert!(b >= a, "CPU time went backwards: {a} -> {b}");
    }
}
