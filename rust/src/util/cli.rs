//! Minimal std-only CLI argument parser (clap is not vendored in this
//! image). Supports `--flag`, `--key value`, `--key=value`, and
//! positional arguments, which is all the `repro` binary needs.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options (flags store `"true"`).
    /// Last occurrence wins; see [`Args::get_all`] for every one.
    pub options: BTreeMap<String, String>,
    /// Every `(key, value)` occurrence in command-line order, for
    /// options that may repeat (e.g. `--workload a.json --workload
    /// b.json`).
    pub multi: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.multi.push((k.to_string(), v.to_string()));
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another option
                    // or missing → boolean flag.
                    let takes_value = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    let v = if takes_value {
                        iter.next().unwrap()
                    } else {
                        String::from("true")
                    };
                    out.multi.push((stripped.to_string(), v.clone()));
                    out.options.insert(stripped.to_string(), v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Whether boolean option `name` was passed (`--name`, `--name=1`).
    pub fn flag(&self, name: &str) -> bool {
        matches!(self.options.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Raw value of option `name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Raw value of option `name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup, falling back to `default` when absent.
    /// Panics with a readable message on malformed values (CLI surface).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
        }
    }

    /// Every value passed for option `name`, in command-line order —
    /// for options that may repeat. Empty when the option was absent.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.multi
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// Comma-separated list option, e.g. `--threads 1,2,4` → `[1,2,4]`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Option<Vec<T>> {
        self.get(name).map(|s| {
            s.split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: cannot parse element {p:?}"))
                })
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|w| w.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("bench fig1 --json");
        assert_eq!(a.positional, vec!["bench", "fig1"]);
        assert!(a.flag("json"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("--ops 5000 --window=1024");
        assert_eq!(a.get("ops"), Some("5000"));
        assert_eq!(a.get("window"), Some("1024"));
    }

    #[test]
    fn typed_parse_with_default() {
        let a = parse("--ops 5000");
        assert_eq!(a.get_parse::<u64>("ops", 1), 5000);
        assert_eq!(a.get_parse::<u64>("missing", 7), 7);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn typed_parse_rejects_garbage() {
        let a = parse("--ops banana");
        let _ = a.get_parse::<u64>("ops", 1);
    }

    #[test]
    fn list_option() {
        let a = parse("--threads 1,2,4,8");
        assert_eq!(a.get_list::<usize>("threads"), Some(vec![1, 2, 4, 8]));
        assert_eq!(a.get_list::<usize>("absent"), None);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("--fast --ops 10");
        assert!(a.flag("fast"));
        assert_eq!(a.get("ops"), Some("10"));
    }

    #[test]
    fn get_or_default() {
        let a = parse("--impl cmp");
        assert_eq!(a.get_or("impl", "all"), "cmp");
        assert_eq!(a.get_or("mode", "baseline"), "baseline");
    }

    #[test]
    fn repeated_options_accumulate_last_wins_in_map() {
        let a = parse("--workload a.json --workload b.json --workload=c.json");
        assert_eq!(a.get("workload"), Some("c.json"), "map keeps the last");
        assert_eq!(a.get_all("workload"), vec!["a.json", "b.json", "c.json"]);
        assert!(a.get_all("absent").is_empty());
    }
}
