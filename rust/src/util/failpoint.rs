//! Dependency-free fail-point injection (the `fail` crate is not
//! vendored in this image).
//!
//! A *fail point* is a named site in the code — e.g.
//! `fail_point!("worker/pre-infer")` — where a fault can be injected at
//! runtime for chaos testing. Each site can be armed with a
//! [`FailAction`] (panic, delay, or error) and a trigger probability
//! drawn from the crate's own deterministic [`XorShift64`], either
//! through the API ([`arm`]) or the [`ENV_VAR`] environment variable.
//!
//! Cost model (the whole point of the design):
//!
//! * **Without the `failpoints` feature** the [`fail_point!`] macro
//!   expands to nothing — the site does not exist in the binary.
//! * **With the feature, nothing armed**: one relaxed atomic load (the
//!   global armed-site count is zero) and an untaken branch.
//! * **Armed**: the slow path takes a registry mutex, rolls the
//!   per-thread PRNG against the site's probability, and performs the
//!   action. Chaos runs are not benchmarks; this is fine.
//!
//! The registry itself is always compiled (it is tiny and lets the
//! `repro chaos` subcommand and tests link without feature gymnastics);
//! only the *sites* are feature-gated.
//!
//! # Environment arming
//!
//! `REPRO_FAILPOINTS` holds a `;`-separated list of
//! `site=action[:prob[:micros]]` entries, parsed on first use:
//!
//! ```text
//! REPRO_FAILPOINTS='worker/pre-infer=panic:0.01;batcher/flush=delay:0.2:500'
//! ```
//!
//! `action` is one of `off`, `panic`, `error`, `delay`; `prob` defaults
//! to 1.0; `micros` (delay only) defaults to 100. The PRNG seed can be
//! pinned with `REPRO_FAILPOINTS_SEED=<u64>` for reproducible
//! schedules.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::rng::{splitmix64, XorShift64};

/// Environment variable holding the fail-point arming spec.
pub const ENV_VAR: &str = "REPRO_FAILPOINTS";
/// Environment variable pinning the injection PRNG seed.
pub const ENV_SEED: &str = "REPRO_FAILPOINTS_SEED";

/// What an armed fail point does when its probability trips.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailAction {
    /// Registered but inert (same behaviour as never-armed).
    Off,
    /// Panic at the site — the injected-crash case the supervision
    /// layer (DESIGN.md §11) must absorb.
    Panic,
    /// Sleep for the given number of microseconds — models a stalled
    /// or wedged participant without killing it.
    Delay(u64),
    /// Make the site fail its fallible operation: the two-argument form
    /// of [`fail_point!`] returns its error expression. At a
    /// non-fallible (one-argument) site this escalates to a panic so a
    /// misconfigured schedule is loud, not silent.
    Error,
}

struct Site {
    name: String,
    action: FailAction,
    p: f64,
    hits: u64,
    trips: u64,
}

/// Armed-site registry. Locked only on the armed slow path.
static SITES: Mutex<Vec<Site>> = Mutex::new(Vec::new());

/// Fast-path gate: number of sites whose action is not `Off`.
/// `UNINIT` forces the first check through env-var initialisation.
const UNINIT: u64 = u64::MAX;
static ARMED: AtomicU64 = AtomicU64::new(UNINIT);

/// Seed for the per-thread injection PRNGs ([`set_seed`]).
static SEED: AtomicU64 = AtomicU64::new(0x5EED_FA17);
/// Monotonic thread counter: each thread's PRNG stream is
/// `splitmix64(seed ^ splitmix64(thread_index))`.
static THREAD_IDX: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static RNG: RefCell<Option<XorShift64>> = const { RefCell::new(None) };
}

fn with_rng<R>(f: impl FnOnce(&mut XorShift64) -> R) -> R {
    RNG.with(|cell| {
        let mut slot = cell.borrow_mut();
        let rng = slot.get_or_insert_with(|| {
            let idx = THREAD_IDX.fetch_add(1, Ordering::Relaxed);
            XorShift64::new(SEED.load(Ordering::Relaxed) ^ splitmix64(idx + 1))
        });
        f(rng)
    })
}

/// Parse and apply the [`ENV_VAR`]/[`ENV_SEED`] variables exactly once.
///
/// Every public registry entry point funnels through here, so the
/// `Once` closure must never call back into one of them — a reentrant
/// `Once::call_once` on the same `Once` deadlocks. It therefore uses
/// the `*_inner` variants, which touch `SITES` directly.
fn init_from_env() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        if let Some(seed) = std::env::var(ENV_SEED).ok().and_then(|s| s.parse().ok()) {
            SEED.store(seed, Ordering::Relaxed);
        }
        if let Ok(spec) = std::env::var(ENV_VAR) {
            if let Err(e) = apply_spec_inner(&spec) {
                eprintln!("failpoints: ignoring malformed {ENV_VAR} entry: {e}");
            }
        }
        recount_locked(&SITES.lock().unwrap());
    });
}

/// Recompute the fast-path gate from the registry (caller holds lock).
fn recount_locked(sites: &[Site]) {
    let armed = sites.iter().filter(|s| s.action != FailAction::Off).count() as u64;
    ARMED.store(armed, Ordering::Relaxed);
}

/// Seed the per-thread injection PRNGs. Call before the first trip on
/// any thread for a fully reproducible schedule; threads that already
/// rolled keep their old stream.
pub fn set_seed(seed: u64) {
    SEED.store(seed, Ordering::Relaxed);
}

/// Arm (or re-arm) `site` with `action`, tripping with probability `p`
/// (clamped to `[0, 1]`). Arming with [`FailAction::Off`] disarms.
pub fn arm(site: &str, action: FailAction, p: f64) {
    init_from_env();
    arm_inner(site, action, p);
}

/// [`arm`] without the env-init hook — the form [`init_from_env`]'s
/// `Once` closure may safely call.
fn arm_inner(site: &str, action: FailAction, p: f64) {
    let p = p.clamp(0.0, 1.0);
    let mut sites = SITES.lock().unwrap();
    match sites.iter_mut().find(|s| s.name == site) {
        Some(s) => {
            s.action = action;
            s.p = p;
        }
        None => sites.push(Site {
            name: site.to_string(),
            action,
            p,
            hits: 0,
            trips: 0,
        }),
    }
    recount_locked(&sites);
}

/// Disarm `site` (it stays registered so its counters survive).
pub fn disarm(site: &str) {
    arm(site, FailAction::Off, 0.0);
}

/// Disarm every site. Counters are kept; use [`reset`] to wipe them.
pub fn disarm_all() {
    init_from_env();
    let mut sites = SITES.lock().unwrap();
    for s in sites.iter_mut() {
        s.action = FailAction::Off;
    }
    recount_locked(&sites);
}

/// Disarm every site and zero all counters (test isolation).
pub fn reset() {
    init_from_env();
    let mut sites = SITES.lock().unwrap();
    sites.clear();
    recount_locked(&sites);
}

/// Apply a `site=action[:prob[:micros]]` spec list (the [`ENV_VAR`]
/// grammar); entries are `;`-separated. Returns the first parse error.
pub fn apply_spec(spec: &str) -> Result<(), String> {
    init_from_env();
    apply_spec_inner(spec)
}

/// [`apply_spec`] without the env-init hook (see [`arm_inner`]).
fn apply_spec_inner(spec: &str) -> Result<(), String> {
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, rest) = entry
            .split_once('=')
            .ok_or_else(|| format!("{entry:?}: expected site=action"))?;
        let mut parts = rest.split(':');
        let kind = parts.next().unwrap_or("");
        let p: f64 = match parts.next() {
            Some(s) => s.parse().map_err(|_| format!("{entry:?}: bad probability {s:?}"))?,
            None => 1.0,
        };
        let micros: u64 = match parts.next() {
            Some(s) => s.parse().map_err(|_| format!("{entry:?}: bad delay {s:?}"))?,
            None => 100,
        };
        let action = match kind {
            "off" => FailAction::Off,
            "panic" => FailAction::Panic,
            "error" => FailAction::Error,
            "delay" => FailAction::Delay(micros),
            other => return Err(format!("{entry:?}: unknown action {other:?}")),
        };
        arm_inner(name.trim(), action, p);
    }
    Ok(())
}

/// Fail-point check: `None` when the site is disarmed or the
/// probability did not trip, `Some(action)` when the caller (the
/// [`fail_point!`] expansion) must perform `action`. Disarmed-registry
/// fast path is a single relaxed load.
#[inline]
pub fn check(site: &str) -> Option<FailAction> {
    match ARMED.load(Ordering::Relaxed) {
        0 => None,
        UNINIT => {
            init_from_env();
            check_slow(site)
        }
        _ => check_slow(site),
    }
}

#[cold]
fn check_slow(site: &str) -> Option<FailAction> {
    let mut sites = SITES.lock().unwrap();
    let s = sites
        .iter_mut()
        .find(|s| s.name == site && s.action != FailAction::Off)?;
    s.hits += 1;
    let trip = s.p >= 1.0 || with_rng(|rng| rng.chance(s.p));
    if !trip {
        return None;
    }
    s.trips += 1;
    Some(s.action)
}

/// Perform `action` at `site`: panics on [`FailAction::Panic`], sleeps
/// on [`FailAction::Delay`], and returns `true` iff the caller should
/// take its error path ([`FailAction::Error`]).
pub fn perform(site: &str, action: FailAction) -> bool {
    match action {
        FailAction::Off => false,
        FailAction::Panic => panic!("fail point {site:?} fired (injected panic)"),
        FailAction::Delay(us) => {
            std::thread::sleep(Duration::from_micros(us));
            false
        }
        FailAction::Error => true,
    }
}

/// `(hits, trips)` counters for `site` — hits count armed evaluations,
/// trips count fired actions. `(0, 0)` for unknown sites.
pub fn counters(site: &str) -> (u64, u64) {
    init_from_env();
    let sites = SITES.lock().unwrap();
    sites
        .iter()
        .find(|s| s.name == site)
        .map(|s| (s.hits, s.trips))
        .unwrap_or((0, 0))
}

/// Snapshot of every registered site: `(name, armed, hits, trips)`.
/// Feeds the `repro chaos` conservation report.
pub fn snapshot() -> Vec<(String, bool, u64, u64)> {
    init_from_env();
    let sites = SITES.lock().unwrap();
    sites
        .iter()
        .map(|s| (s.name.clone(), s.action != FailAction::Off, s.hits, s.trips))
        .collect()
}

/// Whether the crate was built with fail-point sites compiled in.
pub fn compiled_in() -> bool {
    cfg!(feature = "failpoints")
}

/// Mark a fail-point site.
///
/// `fail_point!("name")` may panic or delay in place;
/// `fail_point!("name", expr)` additionally supports the
/// [`FailAction::Error`] action by `return`ing `expr` from the
/// enclosing function. Without the `failpoints` feature both forms
/// expand to nothing (the error expression is not evaluated).
#[macro_export]
macro_rules! fail_point {
    ($name:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            if let Some(__fp_action) = $crate::util::failpoint::check($name) {
                if $crate::util::failpoint::perform($name, __fp_action) {
                    panic!(
                        "fail point {:?} armed with an `error` action at a non-fallible site",
                        $name
                    );
                }
            }
        }
    }};
    ($name:expr, $ret:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            if let Some(__fp_action) = $crate::util::failpoint::check($name) {
                if $crate::util::failpoint::perform($name, __fp_action) {
                    return $ret;
                }
            }
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests use synthetic "test/..." site names only: the registry
    // is process-global and integration tests arm the real sites.

    #[test]
    fn disarmed_site_never_triggers() {
        reset();
        assert_eq!(check("test/unarmed"), None);
        arm("test/unarmed", FailAction::Off, 1.0);
        assert_eq!(check("test/unarmed"), None);
    }

    #[test]
    fn armed_site_trips_at_p1() {
        arm("test/p1", FailAction::Delay(1), 1.0);
        assert_eq!(check("test/p1"), Some(FailAction::Delay(1)));
        let (hits, trips) = counters("test/p1");
        assert!(hits >= 1 && trips >= 1);
        disarm("test/p1");
        assert_eq!(check("test/p1"), None);
    }

    #[test]
    fn probability_zero_never_trips() {
        arm("test/p0", FailAction::Panic, 0.0);
        for _ in 0..100 {
            assert_eq!(check("test/p0"), None);
        }
        let (hits, trips) = counters("test/p0");
        assert!(hits >= 100, "armed checks count as hits: {hits}");
        assert_eq!(trips, 0);
        disarm("test/p0");
    }

    #[test]
    fn probability_is_roughly_calibrated() {
        set_seed(7);
        arm("test/half", FailAction::Error, 0.5);
        let trips_before = counters("test/half").1;
        let fired = (0..2000).filter(|_| check("test/half").is_some()).count();
        assert!((700..1300).contains(&fired), "fired={fired}");
        assert_eq!(counters("test/half").1 - trips_before, fired as u64);
        disarm("test/half");
    }

    #[test]
    fn spec_grammar_round_trips() {
        apply_spec("test/spec-a=panic:0.25; test/spec-b=delay:0.5:250 ;test/spec-c=error").unwrap();
        {
            let sites = SITES.lock().unwrap();
            let find = |n: &str| sites.iter().find(|s| s.name == n).unwrap();
            assert_eq!(find("test/spec-a").action, FailAction::Panic);
            assert!((find("test/spec-a").p - 0.25).abs() < 1e-12);
            assert_eq!(find("test/spec-b").action, FailAction::Delay(250));
            assert_eq!(find("test/spec-c").action, FailAction::Error);
            assert!((find("test/spec-c").p - 1.0).abs() < 1e-12);
        }
        apply_spec("test/spec-a=off;test/spec-b=off;test/spec-c=off").unwrap();
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(apply_spec("no-equals-sign").is_err());
        assert!(apply_spec("test/x=explode").is_err());
        assert!(apply_spec("test/x=panic:notanumber").is_err());
    }

    #[test]
    fn perform_semantics() {
        assert!(!perform("test/x", FailAction::Off));
        assert!(!perform("test/x", FailAction::Delay(1)));
        assert!(perform("test/x", FailAction::Error));
        let p = std::panic::catch_unwind(|| perform("test/x", FailAction::Panic));
        assert!(p.is_err(), "Panic action must panic");
    }

    #[test]
    fn error_action_returns_from_fallible_site() {
        fn fallible() -> Result<u32, &'static str> {
            fail_point!("test/fallible", Err("injected"));
            Ok(7)
        }
        // Without the feature the macro is a no-op and this still passes.
        if cfg!(feature = "failpoints") {
            arm("test/fallible", FailAction::Error, 1.0);
            assert_eq!(fallible(), Err("injected"));
            disarm("test/fallible");
        }
        assert_eq!(fallible(), Ok(7));
    }
}
