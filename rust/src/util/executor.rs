//! Hand-rolled, dependency-free async plumbing: [`block_on`], a
//! single-threaded round-robin [`Executor`] with a [`LocalSpawner`]
//! for injecting tasks into a running executor, a shared timer
//! ([`wake_at`] / [`sleep_until`]), and a readiness-polling
//! [`Reactor`] for nonblocking I/O tasks.
//!
//! The offline image ships no tokio (or any async runtime), and the
//! queue's async bridge (DESIGN.md §10) is deliberately
//! executor-agnostic — futures communicate only through
//! [`std::task::Waker`]s, never through runtime-specific hooks. This
//! module exists so the coordinator, the benches, the examples and the
//! tests have *an* executor to ride; swapping in tokio (or any other
//! runtime) requires no queue-side changes.
//!
//! Design notes:
//!
//! * [`block_on`] parks the calling thread between polls — the waker
//!   stores a notification flag and unparks, so a wake between "poll
//!   returned `Pending`" and "park" is never lost (`unpark` tokens
//!   make the next `park` return immediately).
//! * [`Executor`] multiplexes N tasks over the calling thread with a
//!   strict round-robin sweep over ready tasks; it parks only when no
//!   task is ready. Wakes may arrive from any thread (queue producers
//!   wake consumer tasks directly).
//! * The timer is one shared, lazily-spawned thread holding a binary
//!   heap of `(deadline, waker)` entries — deadline futures arm it
//!   once and are woken at expiry. Queue consumers never get a
//!   dedicated thread; the timer serves every deadline future in the
//!   process.

use std::cell::RefCell;
use std::collections::BinaryHeap;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::task::{Context, Poll, Wake, Waker};
use std::thread::{self, Thread};
use std::time::{Duration, Instant};

/// Parking-based notification target shared by [`block_on`] and
/// [`Executor`]: a wake stores the flag and unparks the host thread.
struct ThreadNotify {
    thread: Thread,
    notified: AtomicBool,
}

impl ThreadNotify {
    fn for_current() -> Arc<Self> {
        Arc::new(ThreadNotify {
            thread: thread::current(),
            // Start notified so the first poll runs immediately.
            notified: AtomicBool::new(true),
        })
    }

    fn notify(&self) {
        self.notified.store(true, Ordering::SeqCst);
        self.thread.unpark();
    }

    /// Consume a pending notification, parking until one arrives.
    fn await_notification(&self) {
        while !self.notified.swap(false, Ordering::SeqCst) {
            thread::park();
        }
    }
}

impl Wake for ThreadNotify {
    fn wake(self: Arc<Self>) {
        self.notify();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.notify();
    }
}

/// Drive `fut` to completion on the calling thread, parking it while
/// the future is pending. The minimal executor: one future, one
/// thread, no allocation beyond pinning.
///
/// ```
/// use cmpq::util::executor::block_on;
/// assert_eq!(block_on(async { 2 + 2 }), 4);
/// ```
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let mut fut = Box::pin(fut);
    let notify = ThreadNotify::for_current();
    let waker = Waker::from(notify.clone());
    let mut cx = Context::from_waker(&waker);
    loop {
        notify.await_notification();
        if let Poll::Ready(v) = fut.as_mut().poll(&mut cx) {
            return v;
        }
    }
}

/// Per-task wake state: marks the task ready and unparks the executor.
struct TaskState {
    ready: AtomicBool,
    parker: Arc<ThreadNotify>,
}

impl Wake for TaskState {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.ready.store(true, Ordering::SeqCst);
        self.parker.notify();
    }
}

struct Task {
    /// `None` once the task completed (its future is dropped promptly
    /// so cancellation-on-drop side effects — waker deregistration —
    /// run as soon as possible).
    fut: Option<Pin<Box<dyn Future<Output = ()>>>>,
    state: Arc<TaskState>,
}

/// Single-threaded round-robin executor over N spawned tasks.
///
/// [`Executor::run`] sweeps the tasks in spawn order, polling each one
/// whose waker fired since its last poll, and parks the thread when no
/// task is ready; it returns when every task has completed. Tasks need
/// not be `Send` — they never leave the calling thread — but wakes may
/// arrive from any thread.
///
/// ```
/// use cmpq::util::executor::{yield_now, Executor};
/// use std::cell::Cell;
/// use std::rc::Rc;
///
/// let hits = Rc::new(Cell::new(0));
/// let mut ex = Executor::new();
/// for _ in 0..3 {
///     let hits = hits.clone();
///     ex.spawn(async move {
///         yield_now().await; // interleave with the other tasks
///         hits.set(hits.get() + 1);
///     });
/// }
/// ex.run();
/// assert_eq!(hits.get(), 3);
/// ```
#[derive(Default)]
pub struct Executor {
    tasks: Vec<Task>,
    parker: Option<Arc<ThreadNotify>>,
    /// Tasks injected by [`LocalSpawner`] handles, drained into
    /// `tasks` at the top of each [`Executor::run`] sweep.
    injector: Option<Injector>,
}

type Injector = Rc<RefCell<Vec<Pin<Box<dyn Future<Output = ()>>>>>>;

/// Handle for spawning tasks into a *running* [`Executor`] — e.g. a
/// listener task spawning one connection task per accepted socket.
///
/// The handle is `!Send` (like the tasks themselves): it may only be
/// used from the executor's own thread, typically from inside a task
/// it hosts. Obtain one with [`Executor::spawner`] before calling
/// [`Executor::run`] and move clones into the spawning tasks.
#[derive(Clone)]
pub struct LocalSpawner {
    injector: Injector,
    parker: Arc<ThreadNotify>,
}

impl LocalSpawner {
    /// Queue `fut` on the host executor. It is swept into the task
    /// list (and gets its initial poll) on the executor's next loop
    /// iteration.
    pub fn spawn(&self, fut: impl Future<Output = ()> + 'static) {
        self.injector.borrow_mut().push(Box::pin(fut));
        self.parker.notify();
    }
}

impl Executor {
    /// An executor with no tasks.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue `fut` to run on the next [`Executor::run`]. Futures spawn
    /// ready, so each gets an initial poll.
    pub fn spawn(&mut self, fut: impl Future<Output = ()> + 'static) {
        let parker = self.parker.get_or_insert_with(ThreadNotify::for_current).clone();
        self.tasks.push(Task {
            fut: Some(Box::pin(fut)),
            state: Arc::new(TaskState {
                ready: AtomicBool::new(true),
                parker,
            }),
        });
    }

    /// A [`LocalSpawner`] feeding this executor. Must be called on the
    /// thread that will call [`Executor::run`] (it binds the parker to
    /// the calling thread, exactly like [`Executor::spawn`]).
    pub fn spawner(&mut self) -> LocalSpawner {
        let parker = self.parker.get_or_insert_with(ThreadNotify::for_current).clone();
        let injector = self.injector.get_or_insert_with(Injector::default).clone();
        LocalSpawner { injector, parker }
    }

    /// Number of spawned tasks not yet completed.
    pub fn pending_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.fut.is_some()).count()
    }

    /// Run until every spawned task completes. Must be called on the
    /// thread that spawned the tasks (the parker targets it).
    pub fn run(&mut self) {
        let Some(parker) = self.parker.clone() else {
            return; // nothing was ever spawned
        };
        loop {
            if let Some(injector) = &self.injector {
                let mut incoming = injector.borrow_mut();
                for fut in incoming.drain(..) {
                    self.tasks.push(Task {
                        fut: Some(fut),
                        state: Arc::new(TaskState {
                            ready: AtomicBool::new(true),
                            parker: parker.clone(),
                        }),
                    });
                }
            }
            let mut any_ready = false;
            let mut all_done = true;
            for task in &mut self.tasks {
                if task.fut.is_none() {
                    continue;
                }
                all_done = false;
                if !task.state.ready.swap(false, Ordering::SeqCst) {
                    continue;
                }
                any_ready = true;
                let waker = Waker::from(task.state.clone());
                let mut cx = Context::from_waker(&waker);
                let done = task
                    .fut
                    .as_mut()
                    .expect("checked above")
                    .as_mut()
                    .poll(&mut cx)
                    .is_ready();
                if done {
                    task.fut = None;
                }
            }
            if all_done {
                // A task may have completed in the same sweep it
                // spawned a child; don't return with queued injections.
                let more = self
                    .injector
                    .as_ref()
                    .is_some_and(|i| !i.borrow().is_empty());
                if more {
                    continue;
                }
                self.tasks.clear();
                return;
            }
            if !any_ready {
                parker.await_notification();
            }
        }
    }
}

/// Future that returns `Pending` exactly once, re-scheduling itself —
/// the cooperative yield point for round-robin executors.
pub fn yield_now() -> YieldNow {
    YieldNow { yielded: false }
}

/// Future returned by [`yield_now`].
pub struct YieldNow {
    yielded: bool,
}

impl Future for YieldNow {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.yielded {
            Poll::Ready(())
        } else {
            self.yielded = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

/// One armed timer entry. Ordered by *earliest* deadline first (the
/// comparison is reversed because [`BinaryHeap`] is a max-heap).
struct TimerEntry {
    at: Instant,
    waker: Waker,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at
    }
}

impl Eq for TimerEntry {}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.at.cmp(&self.at)
    }
}

struct TimerShared {
    heap: Mutex<BinaryHeap<TimerEntry>>,
    cv: Condvar,
}

/// The process-wide timer thread, spawned on first use.
fn timer() -> &'static Arc<TimerShared> {
    static TIMER: OnceLock<Arc<TimerShared>> = OnceLock::new();
    TIMER.get_or_init(|| {
        let shared = Arc::new(TimerShared {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
        });
        let for_thread = shared.clone();
        thread::Builder::new()
            .name("cmpq-timer".into())
            .spawn(move || timer_loop(&for_thread))
            .expect("spawn timer thread");
        shared
    })
}

fn timer_loop(shared: &TimerShared) {
    let mut guard = shared.heap.lock().unwrap();
    loop {
        // Pull everything due, then wake outside the lock (a wake may
        // re-arm the timer and would deadlock on `heap` otherwise).
        let now = Instant::now();
        let mut due = Vec::new();
        while guard.peek().is_some_and(|e| e.at <= now) {
            due.push(guard.pop().expect("peeked"));
        }
        if !due.is_empty() {
            drop(guard);
            for entry in due {
                entry.waker.wake();
            }
            guard = shared.heap.lock().unwrap();
            continue;
        }
        guard = match guard.peek().map(|e| e.at) {
            Some(at) => {
                let now = Instant::now();
                if at <= now {
                    continue;
                }
                shared.cv.wait_timeout(guard, at - now).unwrap().0
            }
            None => shared.cv.wait(guard).unwrap(),
        };
    }
}

/// Arm the shared timer: `waker` is invoked once `deadline` passes.
/// Entries are one-shot; waking a future that already completed is a
/// harmless no-op (wakers are designed for spurious wakes).
///
/// Entries cannot be cancelled: a future that resolves (or is
/// dropped) before its deadline leaves its entry — and the cloned
/// waker it pins — in the heap until the deadline passes, when it is
/// popped and fired as a spurious wake. Keep armed deadlines short on
/// high-churn paths (the queue's deadline futures use bounded slices,
/// ≤100 ms in the coordinator) or the heap grows with
/// churn-rate × deadline.
pub fn wake_at(deadline: Instant, waker: Waker) {
    let shared = timer();
    let mut heap = shared.heap.lock().unwrap();
    heap.push(TimerEntry {
        at: deadline,
        waker,
    });
    drop(heap);
    shared.cv.notify_one();
}

/// Future that resolves once `deadline` passes (via the shared timer —
/// no thread is parked per sleeper).
pub fn sleep_until(deadline: Instant) -> Sleep {
    Sleep {
        deadline,
        armed: None,
    }
}

/// Future returned by [`sleep_until`].
pub struct Sleep {
    deadline: Instant,
    /// The waker the timer currently holds for us; re-armed when the
    /// task migrates between polls (a different waker shows up).
    armed: Option<Waker>,
}

impl Future for Sleep {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if Instant::now() >= self.deadline {
            return Poll::Ready(());
        }
        let stale = match &self.armed {
            Some(w) => !w.will_wake(cx.waker()),
            None => true,
        };
        if stale {
            wake_at(self.deadline, cx.waker().clone());
            self.armed = Some(cx.waker().clone());
        }
        Poll::Pending
    }
}

/// Readiness-polling reactor for nonblocking I/O tasks (DESIGN.md §12).
///
/// The offline image ships no epoll/kqueue crate, so readiness is
/// *polled*, not notified: an I/O task that hits `WouldBlock` calls
/// [`Reactor::register`] with its waker and returns `Pending`; the
/// reactor batches every waker parked since the last tick and re-wakes
/// them all on the next tick, driven by the shared timer thread
/// ([`wake_at`]) — one timer entry per tick *per reactor*, regardless
/// of how many thousands of connections are parked on it.
///
/// The tick interval adapts: any registrant that made progress calls
/// [`Reactor::note_progress`], snapping the interval back to `min`;
/// ticks that fire with no progress reported double it up to `max`.
/// Busy reactors poll near `min` (low latency), idle ones decay toward
/// `max` (low CPU). [`Reactor::kick`] wakes everything immediately —
/// the shutdown path uses it so parked connections observe the stop
/// flag without waiting out a tick.
///
/// Cloning shares the reactor (it is an `Arc` internally); clones are
/// `Send + Sync` so one reactor can serve tasks on one executor thread
/// while being kicked from another.
#[derive(Clone)]
pub struct Reactor {
    inner: Arc<ReactorInner>,
}

struct ReactorInner {
    /// Wakers parked until the next tick, plus whether a tick is
    /// currently armed on the timer. Both live under one lock so a
    /// register racing a tick either lands in the drained batch or
    /// re-arms — never parks unarmed.
    parked: Mutex<ReactorParked>,
    /// Current adaptive tick interval, µs.
    interval_us: AtomicU64,
    min_us: u64,
    max_us: u64,
    /// Set by [`Reactor::note_progress`], consumed by the next tick.
    progress: AtomicBool,
}

#[derive(Default)]
struct ReactorParked {
    wakers: Vec<Waker>,
    tick_armed: bool,
}

impl Reactor {
    /// A reactor ticking between `min_tick` (busy) and `max_tick`
    /// (idle). `max_tick` is clamped up to at least `min_tick`.
    pub fn new(min_tick: Duration, max_tick: Duration) -> Self {
        let min_us = (min_tick.as_micros() as u64).max(1);
        let max_us = (max_tick.as_micros() as u64).max(min_us);
        Reactor {
            inner: Arc::new(ReactorInner {
                parked: Mutex::new(ReactorParked::default()),
                interval_us: AtomicU64::new(min_us),
                min_us,
                max_us,
                progress: AtomicBool::new(false),
            }),
        }
    }

    /// Park the calling task until the next tick (or [`Reactor::kick`]).
    /// Call on every `Pending` return of an I/O task — duplicate
    /// registrations within one tick only cost a spurious wake.
    pub fn register(&self, cx: &Context<'_>) {
        let arm = {
            let mut g = self.inner.parked.lock().unwrap();
            g.wakers.push(cx.waker().clone());
            !std::mem::replace(&mut g.tick_armed, true)
        };
        if arm {
            let us = self.inner.interval_us.load(Ordering::Relaxed);
            wake_at(
                Instant::now() + Duration::from_micros(us),
                Waker::from(Arc::new(ReactorTick {
                    inner: self.inner.clone(),
                })),
            );
        }
    }

    /// Report that a registrant made progress (bytes moved, connection
    /// accepted): the next tick is scheduled at the `min` interval.
    pub fn note_progress(&self) {
        self.inner.progress.store(true, Ordering::Relaxed);
        self.inner.interval_us.store(self.inner.min_us, Ordering::Relaxed);
    }

    /// Wake every parked task *now*, without waiting for the tick.
    /// An already-armed tick later fires on an empty batch — harmless.
    pub fn kick(&self) {
        let wakers = {
            let mut g = self.inner.parked.lock().unwrap();
            std::mem::take(&mut g.wakers)
        };
        for w in wakers {
            w.wake();
        }
    }

    /// Number of wakers currently parked (telemetry).
    pub fn parked(&self) -> usize {
        self.inner.parked.lock().unwrap().wakers.len()
    }

    /// Future that parks the task until the next tick (or kick): the
    /// polling analogue of "wait for readiness" — used by accept loops
    /// after `WouldBlock`. Resolves after at most one suspension, so a
    /// spurious wake just retries early.
    pub fn tick(&self) -> TickWait<'_> {
        TickWait {
            reactor: self,
            waited: false,
        }
    }
}

/// Timer-side waker that drives one reactor tick: drain the parked
/// batch, adapt the interval, wake everyone.
struct ReactorTick {
    inner: Arc<ReactorInner>,
}

impl Wake for ReactorTick {
    fn wake(self: Arc<Self>) {
        let inner = &self.inner;
        let next = if inner.progress.swap(false, Ordering::Relaxed) {
            inner.min_us
        } else {
            (inner.interval_us.load(Ordering::Relaxed) * 2).min(inner.max_us)
        };
        inner.interval_us.store(next, Ordering::Relaxed);
        let wakers = {
            let mut g = inner.parked.lock().unwrap();
            g.tick_armed = false;
            std::mem::take(&mut g.wakers)
        };
        // Outside the lock: a woken task may immediately re-register.
        for w in wakers {
            w.wake();
        }
    }
}

/// Future returned by [`Reactor::tick`].
pub struct TickWait<'a> {
    reactor: &'a Reactor,
    waited: bool,
}

impl Future for TickWait<'_> {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.waited {
            Poll::Ready(())
        } else {
            self.waited = true;
            self.reactor.register(cx);
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn block_on_ready_future() {
        assert_eq!(block_on(async { 41 + 1 }), 42);
    }

    #[test]
    fn block_on_parks_until_cross_thread_wake() {
        // A future whose readiness is flipped by another thread: the
        // first poll stores the waker, the thread wakes it later.
        struct Gate {
            open: Mutex<(bool, Option<Waker>)>,
        }
        struct GateFuture(Arc<Gate>);
        impl Future for GateFuture {
            type Output = u32;
            fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<u32> {
                let mut g = self.0.open.lock().unwrap();
                if g.0 {
                    Poll::Ready(7)
                } else {
                    g.1 = Some(cx.waker().clone());
                    Poll::Pending
                }
            }
        }
        let gate = Arc::new(Gate {
            open: Mutex::new((false, None)),
        });
        let gate2 = gate.clone();
        let opener = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            let mut g = gate2.open.lock().unwrap();
            g.0 = true;
            if let Some(w) = g.1.take() {
                w.wake();
            }
        });
        assert_eq!(block_on(GateFuture(gate)), 7);
        opener.join().unwrap();
    }

    #[test]
    fn executor_runs_all_tasks_round_robin() {
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut ex = Executor::new();
        for id in 0..3u32 {
            let order = order.clone();
            ex.spawn(async move {
                for round in 0..3u32 {
                    order.lock().unwrap().push((round, id));
                    yield_now().await;
                }
            });
        }
        assert_eq!(ex.pending_tasks(), 3);
        ex.run();
        assert_eq!(ex.pending_tasks(), 0);
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 9);
        // Round-robin: all tasks complete round r before any starts
        // round r+1.
        let expect: Vec<(u32, u32)> = (0..3).flat_map(|r| (0..3).map(move |t| (r, t))).collect();
        assert_eq!(*order, expect);
    }

    #[test]
    fn executor_with_no_tasks_returns() {
        Executor::new().run();
    }

    #[test]
    fn sleep_until_fires_via_timer() {
        let t0 = Instant::now();
        block_on(sleep_until(t0 + Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(30));
        // Already-expired deadlines resolve on the first poll.
        let t1 = Instant::now();
        block_on(sleep_until(t1));
        assert!(t1.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn timer_orders_multiple_deadlines() {
        let fired = Arc::new(Mutex::new(Vec::new()));
        let mut ex = Executor::new();
        let base = Instant::now();
        // Armed out of order; must fire nearest-first. Gaps are wide
        // (≥100ms) so a scheduler hiccup cannot reorder the sweeps.
        for (i, ms) in [300u64, 50, 150].iter().enumerate() {
            let fired = fired.clone();
            let at = base + Duration::from_millis(*ms);
            ex.spawn(async move {
                sleep_until(at).await;
                fired.lock().unwrap().push(i);
            });
        }
        ex.run();
        assert_eq!(*fired.lock().unwrap(), vec![1, 2, 0], "nearest first");
    }

    #[test]
    fn local_spawner_injects_into_running_executor() {
        use std::cell::Cell;
        use std::rc::Rc;
        let hits = Rc::new(Cell::new(0u32));
        let mut ex = Executor::new();
        let spawner = ex.spawner();
        {
            let hits = hits.clone();
            let spawner = spawner.clone();
            ex.spawn(async move {
                // Spawn a chain of children from inside a running task.
                for _ in 0..3 {
                    let hits = hits.clone();
                    let spawner = spawner.clone();
                    spawner.spawn(async move {
                        hits.set(hits.get() + 1);
                        let hits = hits.clone();
                        spawner.spawn(async move {
                            hits.set(hits.get() + 10);
                        });
                    });
                }
            });
        }
        ex.run();
        assert_eq!(hits.get(), 33, "3 children + 3 grandchildren all ran");
    }

    #[test]
    fn local_spawner_queued_before_run_executes() {
        use std::cell::Cell;
        use std::rc::Rc;
        let hit = Rc::new(Cell::new(false));
        let mut ex = Executor::new();
        let spawner = ex.spawner();
        let h = hit.clone();
        spawner.spawn(async move { h.set(true) });
        ex.run();
        assert!(hit.get());
    }

    #[test]
    fn reactor_tick_wakes_parked_task() {
        let r = Reactor::new(Duration::from_micros(200), Duration::from_millis(5));
        let t0 = Instant::now();
        block_on(async {
            r.tick().await;
            r.tick().await;
        });
        // Two ticks at ≥200µs each; bound generously for slow CI.
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(r.parked(), 0);
    }

    #[test]
    fn reactor_kick_wakes_immediately() {
        let r = Reactor::new(Duration::from_secs(60), Duration::from_secs(60));
        let r2 = r.clone();
        let kicker = thread::spawn(move || {
            while r2.parked() == 0 {
                thread::yield_now();
            }
            r2.kick();
        });
        let t0 = Instant::now();
        block_on(r.tick());
        // Far sooner than the 60s tick: the kick did it.
        assert!(t0.elapsed() < Duration::from_secs(30));
        kicker.join().unwrap();
    }

    #[test]
    fn reactor_interval_adapts() {
        let r = Reactor::new(Duration::from_micros(100), Duration::from_millis(50));
        // No progress: ticks decay the interval toward max.
        block_on(async {
            for _ in 0..4 {
                r.tick().await;
            }
        });
        let decayed = r.inner.interval_us.load(Ordering::Relaxed);
        assert!(decayed > 100, "interval grew without progress: {decayed}");
        r.note_progress();
        assert_eq!(r.inner.interval_us.load(Ordering::Relaxed), 100);
    }
}
