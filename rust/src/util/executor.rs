//! Hand-rolled, dependency-free async plumbing: [`block_on`], a
//! single-threaded round-robin [`Executor`], and a shared timer
//! ([`wake_at`] / [`sleep_until`]).
//!
//! The offline image ships no tokio (or any async runtime), and the
//! queue's async bridge (DESIGN.md §10) is deliberately
//! executor-agnostic — futures communicate only through
//! [`std::task::Waker`]s, never through runtime-specific hooks. This
//! module exists so the coordinator, the benches, the examples and the
//! tests have *an* executor to ride; swapping in tokio (or any other
//! runtime) requires no queue-side changes.
//!
//! Design notes:
//!
//! * [`block_on`] parks the calling thread between polls — the waker
//!   stores a notification flag and unparks, so a wake between "poll
//!   returned `Pending`" and "park" is never lost (`unpark` tokens
//!   make the next `park` return immediately).
//! * [`Executor`] multiplexes N tasks over the calling thread with a
//!   strict round-robin sweep over ready tasks; it parks only when no
//!   task is ready. Wakes may arrive from any thread (queue producers
//!   wake consumer tasks directly).
//! * The timer is one shared, lazily-spawned thread holding a binary
//!   heap of `(deadline, waker)` entries — deadline futures arm it
//!   once and are woken at expiry. Queue consumers never get a
//!   dedicated thread; the timer serves every deadline future in the
//!   process.

use std::collections::BinaryHeap;
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::task::{Context, Poll, Wake, Waker};
use std::thread::{self, Thread};
use std::time::Instant;

/// Parking-based notification target shared by [`block_on`] and
/// [`Executor`]: a wake stores the flag and unparks the host thread.
struct ThreadNotify {
    thread: Thread,
    notified: AtomicBool,
}

impl ThreadNotify {
    fn for_current() -> Arc<Self> {
        Arc::new(ThreadNotify {
            thread: thread::current(),
            // Start notified so the first poll runs immediately.
            notified: AtomicBool::new(true),
        })
    }

    fn notify(&self) {
        self.notified.store(true, Ordering::SeqCst);
        self.thread.unpark();
    }

    /// Consume a pending notification, parking until one arrives.
    fn await_notification(&self) {
        while !self.notified.swap(false, Ordering::SeqCst) {
            thread::park();
        }
    }
}

impl Wake for ThreadNotify {
    fn wake(self: Arc<Self>) {
        self.notify();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.notify();
    }
}

/// Drive `fut` to completion on the calling thread, parking it while
/// the future is pending. The minimal executor: one future, one
/// thread, no allocation beyond pinning.
///
/// ```
/// use cmpq::util::executor::block_on;
/// assert_eq!(block_on(async { 2 + 2 }), 4);
/// ```
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let mut fut = Box::pin(fut);
    let notify = ThreadNotify::for_current();
    let waker = Waker::from(notify.clone());
    let mut cx = Context::from_waker(&waker);
    loop {
        notify.await_notification();
        if let Poll::Ready(v) = fut.as_mut().poll(&mut cx) {
            return v;
        }
    }
}

/// Per-task wake state: marks the task ready and unparks the executor.
struct TaskState {
    ready: AtomicBool,
    parker: Arc<ThreadNotify>,
}

impl Wake for TaskState {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.ready.store(true, Ordering::SeqCst);
        self.parker.notify();
    }
}

struct Task {
    /// `None` once the task completed (its future is dropped promptly
    /// so cancellation-on-drop side effects — waker deregistration —
    /// run as soon as possible).
    fut: Option<Pin<Box<dyn Future<Output = ()>>>>,
    state: Arc<TaskState>,
}

/// Single-threaded round-robin executor over N spawned tasks.
///
/// [`Executor::run`] sweeps the tasks in spawn order, polling each one
/// whose waker fired since its last poll, and parks the thread when no
/// task is ready; it returns when every task has completed. Tasks need
/// not be `Send` — they never leave the calling thread — but wakes may
/// arrive from any thread.
///
/// ```
/// use cmpq::util::executor::{yield_now, Executor};
/// use std::cell::Cell;
/// use std::rc::Rc;
///
/// let hits = Rc::new(Cell::new(0));
/// let mut ex = Executor::new();
/// for _ in 0..3 {
///     let hits = hits.clone();
///     ex.spawn(async move {
///         yield_now().await; // interleave with the other tasks
///         hits.set(hits.get() + 1);
///     });
/// }
/// ex.run();
/// assert_eq!(hits.get(), 3);
/// ```
#[derive(Default)]
pub struct Executor {
    tasks: Vec<Task>,
    parker: Option<Arc<ThreadNotify>>,
}

impl Executor {
    /// An executor with no tasks.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue `fut` to run on the next [`Executor::run`]. Futures spawn
    /// ready, so each gets an initial poll.
    pub fn spawn(&mut self, fut: impl Future<Output = ()> + 'static) {
        let parker = self.parker.get_or_insert_with(ThreadNotify::for_current).clone();
        self.tasks.push(Task {
            fut: Some(Box::pin(fut)),
            state: Arc::new(TaskState {
                ready: AtomicBool::new(true),
                parker,
            }),
        });
    }

    /// Number of spawned tasks not yet completed.
    pub fn pending_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.fut.is_some()).count()
    }

    /// Run until every spawned task completes. Must be called on the
    /// thread that spawned the tasks (the parker targets it).
    pub fn run(&mut self) {
        let Some(parker) = self.parker.clone() else {
            return; // nothing was ever spawned
        };
        loop {
            let mut any_ready = false;
            let mut all_done = true;
            for task in &mut self.tasks {
                if task.fut.is_none() {
                    continue;
                }
                all_done = false;
                if !task.state.ready.swap(false, Ordering::SeqCst) {
                    continue;
                }
                any_ready = true;
                let waker = Waker::from(task.state.clone());
                let mut cx = Context::from_waker(&waker);
                let done = task
                    .fut
                    .as_mut()
                    .expect("checked above")
                    .as_mut()
                    .poll(&mut cx)
                    .is_ready();
                if done {
                    task.fut = None;
                }
            }
            if all_done {
                self.tasks.clear();
                return;
            }
            if !any_ready {
                parker.await_notification();
            }
        }
    }
}

/// Future that returns `Pending` exactly once, re-scheduling itself —
/// the cooperative yield point for round-robin executors.
pub fn yield_now() -> YieldNow {
    YieldNow { yielded: false }
}

/// Future returned by [`yield_now`].
pub struct YieldNow {
    yielded: bool,
}

impl Future for YieldNow {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.yielded {
            Poll::Ready(())
        } else {
            self.yielded = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

/// One armed timer entry. Ordered by *earliest* deadline first (the
/// comparison is reversed because [`BinaryHeap`] is a max-heap).
struct TimerEntry {
    at: Instant,
    waker: Waker,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at
    }
}

impl Eq for TimerEntry {}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.at.cmp(&self.at)
    }
}

struct TimerShared {
    heap: Mutex<BinaryHeap<TimerEntry>>,
    cv: Condvar,
}

/// The process-wide timer thread, spawned on first use.
fn timer() -> &'static Arc<TimerShared> {
    static TIMER: OnceLock<Arc<TimerShared>> = OnceLock::new();
    TIMER.get_or_init(|| {
        let shared = Arc::new(TimerShared {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
        });
        let for_thread = shared.clone();
        thread::Builder::new()
            .name("cmpq-timer".into())
            .spawn(move || timer_loop(&for_thread))
            .expect("spawn timer thread");
        shared
    })
}

fn timer_loop(shared: &TimerShared) {
    let mut guard = shared.heap.lock().unwrap();
    loop {
        // Pull everything due, then wake outside the lock (a wake may
        // re-arm the timer and would deadlock on `heap` otherwise).
        let now = Instant::now();
        let mut due = Vec::new();
        while guard.peek().is_some_and(|e| e.at <= now) {
            due.push(guard.pop().expect("peeked"));
        }
        if !due.is_empty() {
            drop(guard);
            for entry in due {
                entry.waker.wake();
            }
            guard = shared.heap.lock().unwrap();
            continue;
        }
        guard = match guard.peek().map(|e| e.at) {
            Some(at) => {
                let now = Instant::now();
                if at <= now {
                    continue;
                }
                shared.cv.wait_timeout(guard, at - now).unwrap().0
            }
            None => shared.cv.wait(guard).unwrap(),
        };
    }
}

/// Arm the shared timer: `waker` is invoked once `deadline` passes.
/// Entries are one-shot; waking a future that already completed is a
/// harmless no-op (wakers are designed for spurious wakes).
///
/// Entries cannot be cancelled: a future that resolves (or is
/// dropped) before its deadline leaves its entry — and the cloned
/// waker it pins — in the heap until the deadline passes, when it is
/// popped and fired as a spurious wake. Keep armed deadlines short on
/// high-churn paths (the queue's deadline futures use bounded slices,
/// ≤100 ms in the coordinator) or the heap grows with
/// churn-rate × deadline.
pub fn wake_at(deadline: Instant, waker: Waker) {
    let shared = timer();
    let mut heap = shared.heap.lock().unwrap();
    heap.push(TimerEntry {
        at: deadline,
        waker,
    });
    drop(heap);
    shared.cv.notify_one();
}

/// Future that resolves once `deadline` passes (via the shared timer —
/// no thread is parked per sleeper).
pub fn sleep_until(deadline: Instant) -> Sleep {
    Sleep {
        deadline,
        armed: None,
    }
}

/// Future returned by [`sleep_until`].
pub struct Sleep {
    deadline: Instant,
    /// The waker the timer currently holds for us; re-armed when the
    /// task migrates between polls (a different waker shows up).
    armed: Option<Waker>,
}

impl Future for Sleep {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if Instant::now() >= self.deadline {
            return Poll::Ready(());
        }
        let stale = match &self.armed {
            Some(w) => !w.will_wake(cx.waker()),
            None => true,
        };
        if stale {
            wake_at(self.deadline, cx.waker().clone());
            self.armed = Some(cx.waker().clone());
        }
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn block_on_ready_future() {
        assert_eq!(block_on(async { 41 + 1 }), 42);
    }

    #[test]
    fn block_on_parks_until_cross_thread_wake() {
        // A future whose readiness is flipped by another thread: the
        // first poll stores the waker, the thread wakes it later.
        struct Gate {
            open: Mutex<(bool, Option<Waker>)>,
        }
        struct GateFuture(Arc<Gate>);
        impl Future for GateFuture {
            type Output = u32;
            fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<u32> {
                let mut g = self.0.open.lock().unwrap();
                if g.0 {
                    Poll::Ready(7)
                } else {
                    g.1 = Some(cx.waker().clone());
                    Poll::Pending
                }
            }
        }
        let gate = Arc::new(Gate {
            open: Mutex::new((false, None)),
        });
        let gate2 = gate.clone();
        let opener = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            let mut g = gate2.open.lock().unwrap();
            g.0 = true;
            if let Some(w) = g.1.take() {
                w.wake();
            }
        });
        assert_eq!(block_on(GateFuture(gate)), 7);
        opener.join().unwrap();
    }

    #[test]
    fn executor_runs_all_tasks_round_robin() {
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut ex = Executor::new();
        for id in 0..3u32 {
            let order = order.clone();
            ex.spawn(async move {
                for round in 0..3u32 {
                    order.lock().unwrap().push((round, id));
                    yield_now().await;
                }
            });
        }
        assert_eq!(ex.pending_tasks(), 3);
        ex.run();
        assert_eq!(ex.pending_tasks(), 0);
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 9);
        // Round-robin: all tasks complete round r before any starts
        // round r+1.
        let expect: Vec<(u32, u32)> = (0..3).flat_map(|r| (0..3).map(move |t| (r, t))).collect();
        assert_eq!(*order, expect);
    }

    #[test]
    fn executor_with_no_tasks_returns() {
        Executor::new().run();
    }

    #[test]
    fn sleep_until_fires_via_timer() {
        let t0 = Instant::now();
        block_on(sleep_until(t0 + Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(30));
        // Already-expired deadlines resolve on the first poll.
        let t1 = Instant::now();
        block_on(sleep_until(t1));
        assert!(t1.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn timer_orders_multiple_deadlines() {
        let fired = Arc::new(Mutex::new(Vec::new()));
        let mut ex = Executor::new();
        let base = Instant::now();
        // Armed out of order; must fire nearest-first. Gaps are wide
        // (≥100ms) so a scheduler hiccup cannot reorder the sweeps.
        for (i, ms) in [300u64, 50, 150].iter().enumerate() {
            let fired = fired.clone();
            let at = base + Duration::from_millis(*ms);
            ex.spawn(async move {
                sleep_until(at).await;
                fired.lock().unwrap().push(i);
            });
        }
        ex.run();
        assert_eq!(*fired.lock().unwrap(), vec![1, 2, 0], "nearest first");
    }
}
