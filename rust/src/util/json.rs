//! Minimal JSON parser (serde is not vendored in this image). Supports
//! the full JSON grammar minus exotic number forms; enough for
//! `artifacts/testvec.json` and `artifacts/meta.json`.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrowed string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Borrowed elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `[1,2,3]` → `vec![1.0,2.0,3.0]` (errors on non-numbers).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Like [`Json::as_f64_vec`], narrowed to `f32`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    /// Numeric array as `usize` elements (errors on non-numbers).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {s:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn arrays_and_objects() {
        let v = Json::parse(r#"{"a": [1, 2, 3], "b": {"c": "d"}, "e": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("d"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,4],[]]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64_vec().unwrap(), vec![3.0, 4.0]);
        assert!(arr[2].as_arr().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" \n\t{ \"k\" :\r 1 } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn typed_vectors() {
        let v = Json::parse("[1.5, 2.5]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.5f32, 2.5]);
        let v = Json::parse("[8, 128]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![8, 128]);
        assert_eq!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec(), None);
    }

    #[test]
    fn real_testvec_shape() {
        // Mirror the structure aot.py emits.
        let s = r#"{"input_shape":[2,3],"output_shape":[2,2],"input":[1,2,3,4,5,6],"expected":[0.5,-0.5,1.0,2.0],"rtol":1e-4,"seed":0}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("input_shape").unwrap().as_usize_vec().unwrap(), vec![2, 3]);
        assert_eq!(v.get("input").unwrap().as_f32_vec().unwrap().len(), 6);
        assert!((v.get("rtol").unwrap().as_f64().unwrap() - 1e-4).abs() < 1e-12);
    }
}
