//! CPU-pause based exponential backoff, matching the paper's
//! `CPU_PAUSE()` usage (Algorithm 1, line 18): spin a few times on fresh
//! state, then start yielding the timeslice. On this 1-core testbed the
//! yield escalation matters — a pure spin loop would burn the whole
//! quantum while the thread that must make progress is descheduled.

use std::hint;
use std::thread;

/// Exponential backoff helper for CAS retry loops.
#[derive(Default)]
pub struct Backoff {
    step: u32,
}

/// Below this step we spin with `spin_loop` (PAUSE); at or above, yield.
const SPIN_LIMIT: u32 = 6;
/// Cap on the exponent so the spin count stays bounded.
const YIELD_LIMIT: u32 = 10;

impl Backoff {
    /// A fresh backoff at the spinning stage.
    pub fn new() -> Self {
        Self { step: 0 }
    }

    /// Reset after successful progress.
    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }

    /// Back off once: `2^step` PAUSEs while below [`SPIN_LIMIT`], a
    /// `thread::yield_now` afterwards.
    #[inline]
    pub fn spin(&mut self) {
        if self.step <= SPIN_LIMIT {
            for _ in 0..(1u32 << self.step) {
                hint::spin_loop();
            }
        } else {
            thread::yield_now();
        }
        if self.step <= YIELD_LIMIT {
            self.step += 1;
        }
    }

    /// True once the backoff has escalated past pure spinning; callers can
    /// use this to switch strategies (e.g. park, or give up a quantum).
    #[inline]
    pub fn is_yielding(&self) -> bool {
        self.step > SPIN_LIMIT
    }

    /// Number of [`Self::spin`] calls performed since the last reset
    /// (capped at `YIELD_LIMIT + 1`). The adaptive wait path
    /// (DESIGN.md §15) compares this against a learned spin budget
    /// instead of the fixed [`Self::is_yielding`] threshold.
    #[inline]
    pub fn step(&self) -> u32 {
        self.step
    }
}

/// Single CPU pause — the paper's `CPU_PAUSE()` primitive.
#[inline(always)]
pub fn cpu_pause() {
    hint::spin_loop();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_to_yielding() {
        let mut b = Backoff::new();
        assert!(!b.is_yielding());
        for _ in 0..=SPIN_LIMIT {
            b.spin();
        }
        assert!(b.is_yielding());
    }

    #[test]
    fn reset_restores_spinning() {
        let mut b = Backoff::new();
        for _ in 0..20 {
            b.spin();
        }
        assert!(b.is_yielding());
        b.reset();
        assert!(!b.is_yielding());
    }

    #[test]
    fn step_is_capped() {
        let mut b = Backoff::new();
        for _ in 0..1000 {
            b.spin(); // must not overflow the shift
        }
        assert!(b.is_yielding());
    }
}
