//! # cmpq — Cyclic Memory Protection queues
//!
//! Reproduction of *"No Cords Attached: Coordination-Free Concurrent
//! Lock-Free Queues"* (CS.DC 2025). The crate provides:
//!
//! * [`queue::cmp::CmpQueue`] — the paper's contribution: a lock-free,
//!   strict-FIFO, unbounded MPMC queue with **Cyclic Memory Protection**
//!   (bounded temporal protection windows instead of hazard-pointer /
//!   epoch coordination).
//! * [`queue::sharded::ShardedCmp`] — a sharded fabric over N CMP
//!   shards: per-consumer affinity, steal-on-empty, and a strict vs
//!   bounded-rank-error ordering knob (DESIGN.md §13).
//! * [`queue::baselines`] — every comparator the paper evaluates or
//!   discusses: Michael & Scott + hazard pointers ("Boost" stand-in),
//!   M&S + epoch-based reclamation, a per-producer segmented relaxed-FIFO
//!   queue ("moodycamel" stand-in), Vyukov's bounded MPMC ring, a
//!   mutex-protected queue (TBB/Folly stand-in), and the original M&S
//!   *with* helping (the §3.4 ablation).
//! * [`queue::reclamation`] — the reclamation substrates those baselines
//!   need (hazard-pointer domain, epoch-based-reclamation domain).
//! * [`bench`] — a criterion-style benchmark harness (offline image has no
//!   criterion) reproducing Figure 1, Tables 1–3, Figure 2 and the
//!   ablation studies, including the paper's round-robin sequencing and
//!   3-sigma filtering methodology.
//! * [`coordinator`] — an inference-serving pipeline (router → dynamic
//!   batcher → model workers) whose request fabric is CMP queues; workers
//!   execute an AOT-compiled JAX/Pallas model through [`runtime`].
//! * [`net`] — a dependency-free TCP front end for the pipeline
//!   (DESIGN.md §12): a handful of I/O threads running the crate's own
//!   reactor multiplex tens of thousands of nonblocking connections,
//!   with per-tenant admission, read/write deadlines, and
//!   disconnect-safe conservation accounting.
//! * [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`.
//! * [`util`] — owned substrates (PRNG, backoff, eventcount parking +
//!   async waker registry, a dependency-free `block_on`/executor/timer,
//!   CPU accounting, CLI/JSON helpers) the offline image forces on us.
//! * [`model`] — a hand-rolled concurrency model checker (virtual
//!   atomics + cooperative scheduler + exhaustive/fuzz schedule
//!   explorers). With the `model-check` feature the wait/claim core
//!   runs unmodified under it; without the feature it costs nothing.
//!
//! Consumers never busy-wait on an empty queue: every implementation
//! offers blocking/deadline dequeues
//! ([`ConcurrentQueue::pop_blocking`], [`ConcurrentQueue::pop_deadline`]
//! and their batch variants), and [`CmpQueue`] backs them with a
//! lost-wakeup-safe eventcount ([`util::WaitStrategy`], DESIGN.md §8)
//! so idle consumers sleep in the kernel while the lock-free fast
//! paths stay untouched. The same eventcount carries an
//! executor-agnostic async bridge (DESIGN.md §10):
//! [`ConcurrentQueue::pop_async`] (plus batch/deadline variants and
//! `Server::submit_async`) resolves through push-side waker wakeups —
//! no thread per waiter, any runtime, with [`util::executor`] as the
//! built-in fallback.
//!
//! See `DESIGN.md` for the full system inventory and experiment index,
//! and the top-level `README.md` for a quickstart.

#![warn(missing_docs)]

pub mod bench;
pub mod coordinator;
pub mod model;
pub mod net;
pub mod queue;
pub mod runtime;
pub mod util;

pub use queue::cmp::{CmpConfig, CmpQueue};
pub use queue::sharded::{ShardMode, ShardedCmp, ShardedConfig};
pub use queue::ConcurrentQueue;
