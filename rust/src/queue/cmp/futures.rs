//! Futures behind [`CmpQueue`]'s async dequeues (DESIGN.md §10).
//!
//! All three futures follow the waker-slot protocol — the async mirror
//! of the §8 eventcount's register → re-poll → sleep:
//!
//! 1. Try the lock-free claim; resolve on success.
//! 2. Register (or refresh) a waker slot on the queue's eventcount —
//!    this joins the same waiter count and seq-cst fence pair the
//!    parking threads use.
//! 3. **Re-try the claim**, and only then return `Pending`.
//!
//! Step 3 is the lost-wakeup guard: a push that lands between step 1
//! and step 2 is observed by the re-try; a push after step 2 observes
//! the registration (fence pair) and wakes the stored waker. Either
//! way the future cannot sleep through a publication.
//!
//! Cancellation is `Drop`: dropping a pending future deregisters its
//! waker slot (never leaking the waiter count). No future holds a
//! claimed element across `Pending` — claims happen inside `poll` and
//! resolve immediately — so cancellation can never strand an item.

use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll, Waker};
use std::time::Instant;

use super::queue::CmpQueue;
use crate::util::executor::wake_at;
use crate::util::wait::WakerRegistration;

/// The one copy of the waker-slot poll protocol (module docs steps
/// 1–3): claim → register/refresh → re-claim → `Pending`. Every pop
/// future funnels through this with its own `claim` expression, so a
/// protocol change lands in exactly one place. Clears the
/// registration on resolution.
fn poll_claim<T: Send + 'static, R>(
    queue: &CmpQueue<T>,
    registration: &mut WakerRegistration,
    cx: &Context<'_>,
    mut claim: impl FnMut(&CmpQueue<T>) -> Option<R>,
) -> Poll<R> {
    if let Some(v) = claim(queue) {
        registration.clear(queue.wait_strategy());
        return Poll::Ready(v);
    }
    registration.ensure(queue.wait_strategy(), cx.waker());
    // Protocol step 3: the re-try after registration.
    if let Some(v) = claim(queue) {
        registration.clear(queue.wait_strategy());
        return Poll::Ready(v);
    }
    Poll::Pending
}

/// Future returned by [`CmpQueue::pop_async`]: resolves to the
/// dequeued item once one is available, woken directly by the
/// publishing push. See the module docs for the protocol and
/// [`CmpQueue::pop_async`] for usage.
pub struct PopFuture<'a, T: Send + 'static> {
    queue: &'a CmpQueue<T>,
    registration: WakerRegistration,
}

impl<'a, T: Send + 'static> PopFuture<'a, T> {
    pub(super) fn new(queue: &'a CmpQueue<T>) -> Self {
        PopFuture {
            queue,
            registration: WakerRegistration::new(),
        }
    }
}

impl<T: Send + 'static> Future for PopFuture<'_, T> {
    type Output = T;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        let this = self.get_mut();
        poll_claim(this.queue, &mut this.registration, cx, |q| q.pop())
    }
}

impl<T: Send + 'static> Drop for PopFuture<'_, T> {
    fn drop(&mut self) {
        self.registration.clear(self.queue.wait_strategy());
    }
}

/// Future returned by [`CmpQueue::pop_async_batch`]: resolves to a
/// run of 1..=`max` items claimed through the amortized batch dequeue
/// (`max == 0` resolves immediately with an empty vector).
pub struct PopBatchFuture<'a, T: Send + 'static> {
    queue: &'a CmpQueue<T>,
    max: usize,
    registration: WakerRegistration,
}

impl<'a, T: Send + 'static> PopBatchFuture<'a, T> {
    pub(super) fn new(queue: &'a CmpQueue<T>, max: usize) -> Self {
        PopBatchFuture {
            queue,
            max,
            registration: WakerRegistration::new(),
        }
    }
}

impl<T: Send + 'static> Future for PopBatchFuture<'_, T> {
    type Output = Vec<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Vec<T>> {
        let this = self.get_mut();
        if this.max == 0 {
            this.registration.clear(this.queue.wait_strategy());
            return Poll::Ready(Vec::new());
        }
        let max = this.max;
        poll_claim(this.queue, &mut this.registration, cx, |q| {
            let mut out = Vec::new();
            if q.pop_batch_into(max, &mut out) > 0 {
                Some(out)
            } else {
                None
            }
        })
    }
}

impl<T: Send + 'static> Drop for PopBatchFuture<'_, T> {
    fn drop(&mut self) {
        self.registration.clear(self.queue.wait_strategy());
    }
}

/// Future returned by [`CmpQueue::pop_deadline_async`]: resolves to
/// `Some(item)` on a successful claim or `None` once `deadline`
/// passes. Expiry is driven by the shared timer thread
/// ([`crate::util::executor::wake_at`]) — no polling loop, no thread
/// per sleeper.
pub struct PopDeadlineFuture<'a, T: Send + 'static> {
    queue: &'a CmpQueue<T>,
    deadline: Instant,
    registration: WakerRegistration,
    /// The waker the shared timer holds for us; re-armed only if the
    /// task shows up with a different waker (executor migration).
    armed: Option<Waker>,
}

impl<'a, T: Send + 'static> PopDeadlineFuture<'a, T> {
    pub(super) fn new(queue: &'a CmpQueue<T>, deadline: Instant) -> Self {
        PopDeadlineFuture {
            queue,
            deadline,
            registration: WakerRegistration::new(),
            armed: None,
        }
    }
}

impl<T: Send + 'static> Future for PopDeadlineFuture<'_, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Option<T>> {
        let this = self.get_mut();
        if let Poll::Ready(v) = poll_claim(this.queue, &mut this.registration, cx, |q| q.pop()) {
            return Poll::Ready(Some(v));
        }
        if Instant::now() >= this.deadline {
            // The claim attempts above raced ahead of expiry; the
            // deadline passed with the queue observed empty (the slot
            // registered a moment ago is released right here).
            this.registration.clear(this.queue.wait_strategy());
            return Poll::Ready(None);
        }
        let stale = match &this.armed {
            Some(w) => !w.will_wake(cx.waker()),
            None => true,
        };
        if stale {
            wake_at(this.deadline, cx.waker().clone());
            this.armed = Some(cx.waker().clone());
        }
        Poll::Pending
    }
}

impl<T: Send + 'static> Drop for PopDeadlineFuture<'_, T> {
    fn drop(&mut self) {
        self.registration.clear(self.queue.wait_strategy());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::executor::block_on;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::task::Wake;
    use std::time::Duration;

    struct CountWake(AtomicUsize);

    impl Wake for CountWake {
        fn wake(self: Arc<Self>) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn test_waker() -> (Arc<CountWake>, Waker) {
        let cw = Arc::new(CountWake(AtomicUsize::new(0)));
        let waker = Waker::from(cw.clone());
        (cw, waker)
    }

    /// Poll `fut` once with a counting waker (manual poll harness for
    /// the registration/cancellation tests).
    fn poll_once<F: Future>(fut: Pin<&mut F>, waker: &Waker) -> Poll<F::Output> {
        let mut cx = Context::from_waker(waker);
        fut.poll(&mut cx)
    }

    #[test]
    fn resolves_immediately_when_item_present() {
        let q: CmpQueue<u32> = CmpQueue::new();
        q.push(5).unwrap();
        assert_eq!(block_on(q.pop_async()), 5);
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn pending_future_registers_exactly_one_slot() {
        let q: CmpQueue<u32> = CmpQueue::new();
        let (_cw, waker) = test_waker();
        let mut fut = q.pop_async();
        let mut fut = Pin::new(&mut fut);
        assert!(poll_once(fut.as_mut(), &waker).is_pending());
        assert_eq!(q.parked_consumers(), 1);
        // Re-polling refreshes the same slot, never stacks a second.
        assert!(poll_once(fut.as_mut(), &waker).is_pending());
        assert_eq!(q.parked_consumers(), 1);
    }

    #[test]
    fn drop_deregisters_pending_future() {
        let q: CmpQueue<u32> = CmpQueue::new();
        let (_cw, waker) = test_waker();
        {
            let mut fut = q.pop_async();
            assert!(poll_once(Pin::new(&mut fut), &waker).is_pending());
            assert_eq!(q.parked_consumers(), 1);
        } // dropped pending
        assert_eq!(q.parked_consumers(), 0, "drop must free the slot");
        // The push fast path is back to fence + relaxed load only.
        q.push(1).unwrap();
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn push_wakes_registered_future() {
        let q: CmpQueue<u32> = CmpQueue::new();
        let (cw, waker) = test_waker();
        let mut fut = q.pop_async();
        let mut fut = Pin::new(&mut fut);
        assert!(poll_once(fut.as_mut(), &waker).is_pending());
        q.push(9).unwrap();
        assert_eq!(cw.0.load(Ordering::SeqCst), 1, "push woke the task");
        assert_eq!(poll_once(fut.as_mut(), &waker), Poll::Ready(9));
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn woken_but_dropped_future_strands_nothing() {
        // Push lands after the future registered; the future is then
        // dropped without being re-polled. The item must remain
        // claimable — futures never hold claims across polls.
        let q: CmpQueue<u32> = CmpQueue::new();
        let (cw, waker) = test_waker();
        {
            let mut fut = q.pop_async();
            assert!(poll_once(Pin::new(&mut fut), &waker).is_pending());
            q.push(7).unwrap();
            assert_eq!(cw.0.load(Ordering::SeqCst), 1);
        } // dropped after the wake, before any re-poll
        assert_eq!(q.parked_consumers(), 0);
        assert_eq!(q.pop(), Some(7), "the woken item was not stranded");
    }

    #[test]
    fn batch_future_claims_a_run() {
        let q: CmpQueue<u32> = CmpQueue::new();
        q.push_batch((0..10).collect::<Vec<_>>()).unwrap();
        let run = block_on(q.pop_async_batch(4));
        assert_eq!(run, vec![0, 1, 2, 3]);
        let rest = block_on(q.pop_async_batch(100));
        assert_eq!(rest, (4..10).collect::<Vec<_>>());
        assert!(block_on(q.pop_async_batch(0)).is_empty(), "max == 0");
    }

    #[test]
    fn deadline_future_times_out_then_delivers() {
        let q: CmpQueue<u32> = CmpQueue::new();
        let t0 = Instant::now();
        let out = block_on(q.pop_deadline_async(t0 + Duration::from_millis(40)));
        assert_eq!(out, None);
        assert!(t0.elapsed() >= Duration::from_millis(40));
        assert_eq!(q.parked_consumers(), 0, "expiry freed the slot");
        q.push(3).unwrap();
        let out = block_on(q.pop_deadline_async(Instant::now() + Duration::from_secs(30)));
        assert_eq!(out, Some(3));
    }
}
