//! CMP queue node layout (§3.2.1).
//!
//! Four protection-relevant fields (`state`, `cycle`, `next`, payload)
//! plus pool bookkeeping. Nodes are **type-stable**: they live inside
//! pool segments that are never freed while the queue exists, so any
//! stale pointer still references a valid `Node` and its `cycle`/`state`
//! fields can always be read safely (possibly observing a recycled
//! incarnation — which the cycle check detects).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::Ordering;

// Real std atomics normally; model-checker shims under the
// `model-check` feature (DESIGN.md §9).
use crate::model::shim::{AtomicPtr, AtomicU32, AtomicU64};

/// Node lifecycle states (§3.1). `Free` is pool-internal: the paper's
/// two-state lifecycle (`AVAILABLE → CLAIMED`) plus the recycled state a
/// type-stable pool needs so stale claim CASes on freelist nodes fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum NodeState {
    /// In the pool freelist (or the permanent dummy).
    Free = 0,
    /// Linked and waiting to be dequeued; absolutely protected.
    Available = 1,
    /// Claimed by a dequeuer; reclaimable once outside the window.
    Claimed = 2,
}

/// Raw value of [`NodeState::Free`] (atomic CAS operand).
pub const STATE_FREE: u32 = NodeState::Free as u32;
/// Raw value of [`NodeState::Available`] (atomic CAS operand).
pub const STATE_AVAILABLE: u32 = NodeState::Available as u32;
/// Raw value of [`NodeState::Claimed`] (atomic CAS operand).
pub const STATE_CLAIMED: u32 = NodeState::Claimed as u32;

/// Payload slot state: no payload (data claim, §3.5 Phase 3).
pub const DATA_EMPTY: u32 = 0;
/// Payload slot state: payload present (data claim, §3.5 Phase 3).
pub const DATA_PRESENT: u32 = 1;

/// Cycle value of the permanent dummy node.
pub const DUMMY_CYCLE: u64 = 0;

/// A queue node. `#[repr(C)]` keeps the hot atomic fields at the front
/// of the allocation; payload storage sits last.
#[repr(C)]
pub struct Node<T> {
    /// `AVAILABLE → CLAIMED` lifecycle (state-based protection).
    pub state: AtomicU32,
    /// Payload presence flag; the data-claim CAS (`PRESENT → EMPTY`)
    /// guarantees single extraction (the paper's `CAS(data, data, NULL)`
    /// without a per-payload allocation — DESIGN.md §6).
    pub data_state: AtomicU32,
    /// Immutable temporal identity for this incarnation; written before
    /// the link CAS publishes the node, re-written on recycle.
    pub cycle: AtomicU64,
    /// FIFO list link; `null` on the tail node and on recycled nodes
    /// (reclamation nulls it so stale traversals terminate, §3.6 Ph. 5).
    pub next: AtomicPtr<Node<T>>,
    /// Pool freelist link: index+1 of the next free node, 0 = none.
    pub free_next: AtomicU32,
    /// This node's own pool index (immutable after pool construction).
    pub pool_idx: u32,
    /// Inline payload storage, valid iff `data_state == DATA_PRESENT`.
    pub data: UnsafeCell<MaybeUninit<T>>,
}

impl<T> Node<T> {
    /// A blank node in `Free` state with the given pool index.
    pub fn blank(pool_idx: u32) -> Self {
        Node {
            state: AtomicU32::new(STATE_FREE),
            data_state: AtomicU32::new(DATA_EMPTY),
            cycle: AtomicU64::new(DUMMY_CYCLE),
            next: AtomicPtr::new(std::ptr::null_mut()),
            free_next: AtomicU32::new(0),
            pool_idx,
            data: UnsafeCell::new(MaybeUninit::uninit()),
        }
    }

    /// Write the payload and mark it present. Caller must have exclusive
    /// ownership (fresh from the pool, pre-publication).
    ///
    /// # Safety
    /// The slot must not currently hold a payload.
    pub unsafe fn put_data(&self, value: T) {
        debug_assert_eq!(self.data_state.load(Ordering::Relaxed), DATA_EMPTY);
        (*self.data.get()).write(value);
        self.data_state.store(DATA_PRESENT, Ordering::Relaxed);
    }

    /// Atomically claim the payload (single winner). Returns the value
    /// if this caller won the `PRESENT → EMPTY` race.
    ///
    /// # Safety
    /// Caller must hold the node's `CLAIMED` state or otherwise know the
    /// incarnation it is claiming from wrote a payload (type stability
    /// makes the CAS itself always memory-safe).
    pub unsafe fn take_data(&self) -> Option<T> {
        if self
            .data_state
            .compare_exchange(DATA_PRESENT, DATA_EMPTY, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            Some((*self.data.get()).assume_init_read())
        } else {
            None
        }
    }

    /// Drop the payload in place if present (reclamation of nodes whose
    /// claimer stalled past the window, and queue teardown). Returns
    /// whether a payload was actually dropped.
    ///
    /// # Safety
    /// Caller must have exclusive reclamation rights to the node.
    pub unsafe fn drop_data_if_present(&self) -> bool {
        if self
            .data_state
            .compare_exchange(DATA_PRESENT, DATA_EMPTY, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            (*self.data.get()).assume_init_drop();
            true
        } else {
            false
        }
    }

    /// Current state (test/diagnostic helper).
    #[cfg(test)]
    pub fn load_state(&self, order: Ordering) -> u32 {
        self.state.load(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blank_node_is_free_and_empty() {
        let n: Node<u64> = Node::blank(3);
        assert_eq!(n.load_state(Ordering::Relaxed), STATE_FREE);
        assert_eq!(n.data_state.load(Ordering::Relaxed), DATA_EMPTY);
        assert_eq!(n.pool_idx, 3);
        assert!(n.next.load(Ordering::Relaxed).is_null());
    }

    #[test]
    fn put_take_roundtrip() {
        let n: Node<String> = Node::blank(0);
        unsafe {
            n.put_data("hello".to_string());
            assert_eq!(n.take_data(), Some("hello".to_string()));
            assert_eq!(n.take_data(), None, "second take must lose the CAS");
        }
    }

    #[test]
    fn drop_if_present_drops_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        let n: Node<D> = Node::blank(0);
        unsafe {
            n.put_data(D);
            assert!(n.drop_data_if_present());
            assert!(!n.drop_data_if_present()); // no-op
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn take_after_drop_is_none() {
        let n: Node<u32> = Node::blank(0);
        unsafe {
            n.put_data(9);
            n.drop_data_if_present();
            assert_eq!(n.take_data(), None);
        }
    }

    #[test]
    fn state_constants_match_enum() {
        assert_eq!(NodeState::Free as u32, STATE_FREE);
        assert_eq!(NodeState::Available as u32, STATE_AVAILABLE);
        assert_eq!(NodeState::Claimed as u32, STATE_CLAIMED);
    }
}
