//! Algorithm 4 — Coordination-Free Memory Reclamation (§3.6).
//!
//! Safety predicate: a node is reclaimed iff
//! `(state ≠ AVAILABLE) ∧ (node.cycle < safe_cycle)` where
//! `safe_cycle = deque_cycle − W`. Reclamation walks from `head.next`,
//! batches eligible nodes, commits the batch with a single CAS on
//! `head.next`, and recycles the nodes to the type-stable pool.
//!
//! Deviation (defensive hardening, DESIGN.md §6): we additionally stop
//! at the observed `tail` pointer. The paper argues the cycle check
//! already protects the tail ("the tail always holds the latest cycle
//! value"); that argument needs `W > producer count` — which our
//! `MIN_WINDOW` guarantees — but the explicit check makes even absurd
//! configurations (`W = 1`) corruption-free at the cost of one load per
//! reclamation pass.

use std::sync::atomic::Ordering;

use super::node::{Node, STATE_AVAILABLE, STATE_FREE};
use super::queue::CmpQueue;
use super::stats::CmpStats;

impl<T: Send + 'static> CmpQueue<T> {
    /// Run one reclamation pass (non-blocking: returns immediately if
    /// another thread holds the reclaimer slot). Returns the number of
    /// nodes recycled.
    pub fn reclaim(&self) -> u64 {
        // Fault injection: delay here widens the reclaim/claim race
        // window (§3.6); panic exercises a reclaimer dying mid-pipeline.
        crate::fail_point!("cmp/reclaim");
        // Single-reclaimer try-lock (§3.3 Phase 3). `swap` rather than a
        // CAS loop: either we get it or we leave.
        if self.reclaim_busy.swap(true, Ordering::Acquire) {
            CmpStats::bump(&self.stats.reclaim_contended, self.config.track_stats);
            return 0;
        }
        let freed = unsafe { self.reclaim_pass() };
        self.reclaim_busy.store(false, Ordering::Release);
        CmpStats::bump(&self.stats.reclaim_passes, self.config.track_stats);
        CmpStats::add(&self.stats.nodes_reclaimed, freed, self.config.track_stats);
        // Occupancy feedback (DESIGN.md §15): publish a live Bernoulli
        // probability for the *next* trigger decisions. Occupancy is
        // the live backlog (enqueue cycle minus the dequeue frontier)
        // as a fraction of the protection window — NOT `nodes_in_use`,
        // which stays ≈ W even on a drained queue because consumed
        // nodes remain linked until they exit the window. Only the
        // single reclaimer writes it — once per pass, never on the
        // lock-free enqueue/dequeue paths — and only in adaptive mode;
        // the fixed path keeps the configured constant untouched.
        if self.config.adaptive {
            let backlog = self.enqueue_cycle().saturating_sub(self.dequeue_cycle());
            let occ = backlog as f64 / self.config.window.max(1) as f64;
            self.adaptive
                .set_live_p(crate::runtime::adaptive::reclaim_p_for(
                    self.config.bernoulli_p,
                    occ,
                ));
        }
        freed
    }

    /// The pass body. Caller holds the reclaimer slot.
    unsafe fn reclaim_pass(&self) -> u64 {
        // Phase 1: protection boundary calculation.
        let deque_cycle = self.dequeue_cycle();
        let safe_cycle = deque_cycle.saturating_sub(self.config.window);
        if safe_cycle == 0 {
            return 0; // window still covers everything ever claimed
        }
        // Defensive tail guard (see module docs). A stale observation is
        // only *more* conservative — tail never moves backwards.
        let tail_guard = self.tail_ptr();
        let head = self.head_ptr(); // permanent dummy

        let mut total = 0u64;
        let mut batch: Vec<*mut Node<T>> = Vec::with_capacity(64);
        loop {
            let first = (*head).next.load(Ordering::Acquire);
            let mut current = first;
            batch.clear();

            // Phases 2+3: collect the maximal prefix of nodes that are
            // both temporally (cycle) and state safe.
            while !current.is_null() && current != tail_guard {
                // Phase 2: cycle-based protection check (immutable field
                // for this incarnation — fast read).
                if (*current).cycle.load(Ordering::Acquire) >= safe_cycle {
                    break;
                }
                // Phase 3: state-based protection check. AVAILABLE nodes
                // are absolutely protected; stopping at the first one
                // also preserves FIFO prefix structure.
                if (*current).state.load(Ordering::Acquire) == STATE_AVAILABLE {
                    break;
                }
                // Phase 4: add to the batch.
                batch.push(current);
                current = (*current).next.load(Ordering::Acquire);
            }

            // Enforce minimum batch size for efficiency.
            if batch.len() < self.config.min_reclaim_batch {
                break;
            }

            // Phase 5: single CAS advances head.next across the batch.
            // A failure means a concurrent head.next change — abandon
            // (another pass will retry later).
            if (*head)
                .next
                .compare_exchange(first, current, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                break;
            }
            for &node in &batch {
                self.reset_node(node);
            }
            // Return the whole reclaimed batch with a single spliced
            // push — one freelist CAS per pass instead of one per node
            // (DESIGN.md §7).
            // SAFETY: every node in `batch` came from this queue's own
            // linked list (hence this pool), was detached from it by
            // the head-advance CAS above (sole reclamation rights,
            // §3.6), and was just reset by `reset_node` — FREE state,
            // payload dropped, `next` nulled.
            unsafe { self.pool.free_chain(&batch) };
            total += batch.len() as u64;
            if current.is_null() || current == tail_guard {
                break;
            }
        }
        total
    }

    /// Reset a detached node for recycling (§3.6 Phase 5: "next and
    /// data pointers set to NULL before returning the free node", so
    /// stale traversals terminate safely). The caller batches the
    /// actual freelist return via [`NodePool::free_chain`].
    unsafe fn reset_node(&self, node: *mut Node<T>) {
        // FREE first: any in-flight claim CAS (AVAILABLE→CLAIMED) on a
        // stale pointer now fails fast.
        (*node).state.store(STATE_FREE, Ordering::Release);
        // Drop a payload whose claimer stalled past the window — the
        // paper's automatic-recovery semantics (§3.6).
        if (*node).drop_data_if_present() {
            CmpStats::bump(&self.stats.payloads_reclaimed, self.config.track_stats);
        }
        (*node).next.store(std::ptr::null_mut(), Ordering::Release);
    }

    pub(super) fn head_ptr(&self) -> *mut Node<T> {
        // head never changes after construction (always the dummy).
        self.head.load(Ordering::Acquire)
    }

    pub(super) fn tail_ptr(&self) -> *mut Node<T> {
        self.tail.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use crate::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

    fn manual_cfg(window: u64) -> CmpConfig {
        CmpConfig::default()
            .with_window(window)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Manual)
    }

    #[test]
    fn nothing_reclaimed_inside_window() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(1 << 30));
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        for _ in 0..1000 {
            q.pop().unwrap();
        }
        assert_eq!(q.reclaim(), 0, "window covers all claimed nodes");
        assert_eq!(q.nodes_in_use(), 1001, "dummy + 1000 claimed nodes retained");
    }

    #[test]
    fn claimed_nodes_outside_window_are_reclaimed() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(100));
        let n = 5000u64;
        for i in 0..n {
            q.push(i).unwrap();
        }
        for _ in 0..n {
            q.pop().unwrap();
        }
        let freed = q.reclaim();
        // deque_cycle = n, safe = n-100 ⇒ nodes with cycle < n-100 go.
        assert!(freed >= n - 101, "freed={freed}");
        assert!(freed <= n, "cannot exceed total");
        assert!(q.nodes_in_use() <= 102, "window + dummy retained");
    }

    #[test]
    fn available_nodes_never_reclaimed() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(4));
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        // Dequeue only half; the rest stay AVAILABLE.
        for _ in 0..500 {
            q.pop().unwrap();
        }
        q.reclaim();
        // All 500 AVAILABLE nodes must survive; verify by draining.
        for i in 500..1000 {
            assert_eq!(q.pop(), Some(i), "AVAILABLE prefix intact");
        }
    }

    #[test]
    fn reclamation_is_bounded_w_plus_batch() {
        // Paper: nodes reclaimed within ≤ W dequeue cycles + GC delay.
        let w = 64;
        let q: CmpQueue<u64> = CmpQueue::with_config(
            manual_cfg(w).with_reclaim_period(1).with_trigger(ReclaimTrigger::Modulo),
        );
        for round in 0..50u64 {
            for i in 0..200 {
                q.push(round * 200 + i).unwrap();
            }
            for _ in 0..200 {
                q.pop().unwrap();
            }
            // In-use never exceeds live(0) + W + batch slack + dummy.
            assert!(
                q.nodes_in_use() <= w + 256 + 1,
                "round {round}: in_use={} exceeds bound",
                q.nodes_in_use()
            );
        }
        assert!(q.stats().nodes_reclaimed > 0);
    }

    #[test]
    fn reclaim_is_idempotent_when_empty() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(8));
        assert_eq!(q.reclaim(), 0);
        assert_eq!(q.reclaim(), 0);
    }

    #[test]
    fn min_batch_defers_small_reclaims() {
        let cfg = CmpConfig::default()
            .with_window(1)
            .with_min_batch(100)
            .with_trigger(ReclaimTrigger::Manual);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        for i in 0..50 {
            q.push(i).unwrap();
        }
        for _ in 0..50 {
            q.pop().unwrap();
        }
        assert_eq!(q.reclaim(), 0, "below min batch: defer");
        for i in 0..200 {
            q.push(i).unwrap();
        }
        for _ in 0..200 {
            q.pop().unwrap();
        }
        assert!(q.reclaim() >= 100, "batch threshold reached");
    }

    #[test]
    fn recycled_nodes_are_reused_not_regrown() {
        let q: CmpQueue<u64> = CmpQueue::with_config(
            manual_cfg(32).with_trigger(ReclaimTrigger::Modulo).with_reclaim_period(64),
        );
        for i in 0..100_000u64 {
            q.push(i).unwrap();
            q.pop().unwrap();
        }
        // Footprint stays near window size, far below 100k.
        assert!(
            q.footprint_nodes() < 4096,
            "footprint={} should be bounded by W + slack",
            q.footprint_nodes()
        );
    }

    #[test]
    fn adaptive_reclaim_p_tracks_backlog() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(64).with_adaptive());
        let base = q.config().bernoulli_p;
        assert_eq!(q.adaptive_snapshot().live_p, base, "seeded from config");
        // Drained queue: backlog 0 after the pass → eager (p above base).
        for i in 0..5000 {
            q.push(i).unwrap();
        }
        for _ in 0..5000 {
            q.pop().unwrap();
        }
        q.reclaim();
        let eager = q.adaptive_snapshot().live_p;
        assert!(eager > base, "low occupancy must raise p ({eager} vs {base})");
        // Hot queue: backlog well past the window → lazy (p below base).
        for i in 0..5000 {
            q.push(i).unwrap();
        }
        q.reclaim();
        let lazy = q.adaptive_snapshot().live_p;
        assert!(lazy < base, "high occupancy must lower p ({lazy} vs {base})");
        assert!(eager > lazy);
    }

    #[test]
    fn fixed_mode_never_touches_live_p() {
        let q: CmpQueue<u64> = CmpQueue::with_config(manual_cfg(64));
        let base = q.config().bernoulli_p;
        for i in 0..5000 {
            q.push(i).unwrap();
        }
        for _ in 0..5000 {
            q.pop().unwrap();
        }
        q.reclaim();
        assert_eq!(
            q.adaptive_snapshot().live_p,
            base,
            "adaptive off: the published p must stay the configured constant"
        );
    }

    #[test]
    fn payload_of_stalled_claimer_is_dropped_by_reclaimer() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D(#[allow(dead_code)] u64);
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);

        let q: CmpQueue<D> = CmpQueue::with_config(manual_cfg(4));
        // Simulate a claim that never finishes: claim the state manually
        // by popping *nothing* — instead we enqueue, pop normally for
        // most, and use the public API only. To create a stalled CLAIMED
        // node we dequeue via pop() but the simplest faithful stand-in
        // is: payloads left in CLAIMED nodes only occur via internal
        // races, so here we just verify reclaimed nodes drop payloads
        // when the queue itself is dropped mid-flight.
        for i in 0..100 {
            q.push(D(i)).unwrap();
        }
        for _ in 0..100 {
            drop(q.pop());
        }
        q.reclaim();
        drop(q);
        assert_eq!(DROPS.load(Ordering::Relaxed), 100, "every payload dropped once");
    }
}
