//! CMP tuning parameters (§3.1, §3.3 Phase 3, §3.6).

/// Reclamation trigger policy (§3.3 Phase 3: "the algorithm is agnostic
/// to the triggering policy — deterministic modulo, randomized
/// (Bernoulli p = 1/N), or hybrid").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReclaimTrigger {
    /// `cycle % N == 0` — the variant shown in Algorithm 1.
    Modulo,
    /// Bernoulli trial with `p = 1/N` per enqueue (per-thread PRNG).
    Bernoulli,
    /// Never trigger from enqueue; reclamation only via explicit
    /// [`super::CmpQueue::reclaim`] calls (useful in tests/ablations).
    Manual,
}

/// Configuration for a [`super::CmpQueue`] instance. The paper sizes the
/// window per queue instance (§3.1): `W = max(MIN_WINDOW, OPS × R)`.
#[derive(Debug, Clone)]
pub struct CmpConfig {
    /// Protection window size `W` in dequeue cycles. Nodes are reclaimed
    /// only when `cycle < deque_cycle − W`. Bounds retained memory by
    /// `W × node_size` and must exceed the worst-case dequeue-progress
    /// delay (§3.1) and the producer count (tail-boundary margin,
    /// DESIGN.md §6).
    pub window: u64,
    /// Reclamation period `N`: enqueue triggers a reclamation pass every
    /// `N` cycles (Algorithm 1 Phase 3).
    pub reclaim_period: u64,
    /// Trigger policy for the period above.
    pub trigger: ReclaimTrigger,
    /// Minimum batch size before a reclamation pass commits a head
    /// advance (Algorithm 4 "Enforce minimum batch size").
    pub min_reclaim_batch: usize,
    /// Optional cap on pool nodes (None = unbounded growth). When the
    /// cap is hit, enqueue triggers reclamation and retries (§3.3
    /// Phase 1 "automatic memory pressure relief").
    pub max_nodes: Option<usize>,
    /// Enable the scan-cursor optimization (§3.5 Phase 1). Disabled only
    /// by the ABL-CURSOR ablation; dequeues then scan from `head.next`.
    pub use_scan_cursor: bool,
    /// Use the original M&S helping mechanism instead of the paper's
    /// retry-with-fresh-state (§3.4 ablation ABL-HELP).
    pub helping: bool,
    /// Record detailed statistics (relaxed atomic counters).
    pub track_stats: bool,
    /// Per-thread node-magazine capacity (DESIGN.md §7). Each thread
    /// keeps up to this many pool nodes in a private cache, refilled
    /// from / flushed to the global freelist in one CAS per chunk.
    /// `0` disables magazines (every alloc hits the global freelist).
    pub magazine_capacity: usize,
    /// Precomputed `1 / reclaim_period` for the Bernoulli trigger —
    /// hoisted out of the per-enqueue hot path. Derived: kept in sync
    /// by [`CmpConfig::with_reclaim_period`], and re-normalized
    /// unconditionally when a queue is constructed, so a manual field
    /// write to `reclaim_period` cannot leave it stale.
    pub bernoulli_p: f64,
    /// Enable the adaptive control plane (DESIGN.md §15): a learned
    /// per-consumer spin budget replaces the fixed spin phase on the
    /// blocking wait path, and window-occupancy feedback tunes the
    /// live Bernoulli reclamation probability. Off by default — the
    /// fixed-knob paths are byte-identical when this is `false`.
    pub adaptive: bool,
}

/// Paper's `MIN_WINDOW` floor; also comfortably exceeds any thread count
/// we run, preserving the tail-boundary margin (DESIGN.md §6).
pub const MIN_WINDOW: u64 = 1024;

/// Default per-thread magazine capacity (DESIGN.md §7): one global
/// freelist CAS per this many allocations in steady state.
pub const DEFAULT_MAGAZINE_CAPACITY: usize = 32;

impl Default for CmpConfig {
    fn default() -> Self {
        Self {
            window: 4096,
            reclaim_period: 1024,
            trigger: ReclaimTrigger::Modulo,
            min_reclaim_batch: 32,
            max_nodes: None,
            use_scan_cursor: true,
            helping: false,
            track_stats: true,
            magazine_capacity: DEFAULT_MAGAZINE_CAPACITY,
            bernoulli_p: 1.0 / 1024.0,
            adaptive: false,
        }
    }
}

impl CmpConfig {
    /// Paper's sizing rule: `W = max(MIN_WINDOW, OPS × R)` where `OPS`
    /// is the expected dequeue rate (ops/s) and `R` the resilience
    /// window in seconds (§3.1).
    pub fn window_for(ops_per_sec: u64, resilience_secs: f64) -> u64 {
        let w = (ops_per_sec as f64 * resilience_secs).ceil() as u64;
        w.max(MIN_WINDOW)
    }

    /// Builder-style window override.
    pub fn with_window(mut self, w: u64) -> Self {
        self.window = w.max(1);
        self
    }

    /// Builder-style reclamation period `N` (floored at 1); keeps the
    /// precomputed Bernoulli `1/N` in sync.
    pub fn with_reclaim_period(mut self, n: u64) -> Self {
        self.reclaim_period = n.max(1);
        self.bernoulli_p = 1.0 / self.reclaim_period as f64;
        self
    }

    /// Builder-style trigger policy override.
    pub fn with_trigger(mut self, t: ReclaimTrigger) -> Self {
        self.trigger = t;
        self
    }

    /// Builder-style minimum reclamation batch (floored at 1).
    pub fn with_min_batch(mut self, b: usize) -> Self {
        self.min_reclaim_batch = b.max(1);
        self
    }

    /// Builder-style pool cap (bounded-queue configurations).
    pub fn with_max_nodes(mut self, cap: usize) -> Self {
        self.max_nodes = Some(cap);
        self
    }

    /// Disable the scan cursor (ABL-CURSOR ablation).
    pub fn without_scan_cursor(mut self) -> Self {
        self.use_scan_cursor = false;
        self
    }

    /// Enable the original M&S helping mechanism (ABL-HELP ablation).
    pub fn with_helping(mut self) -> Self {
        self.helping = true;
        self
    }

    /// Disable statistics counters (perf configurations).
    pub fn without_stats(mut self) -> Self {
        self.track_stats = false;
        self
    }

    /// Per-thread magazine capacity; `0` disables thread-local caching
    /// (ABL-MAG ablation / debugging).
    pub fn with_magazine_capacity(mut self, cap: usize) -> Self {
        self.magazine_capacity = cap;
        self
    }

    /// Disable per-thread node magazines (ABL-MAG ablation).
    pub fn without_magazines(mut self) -> Self {
        self.magazine_capacity = 0;
        self
    }

    /// Enable the adaptive control plane (DESIGN.md §15): learned spin
    /// budget on the blocking wait path, occupancy-tuned live
    /// reclamation probability.
    pub fn with_adaptive(mut self) -> Self {
        self.adaptive = true;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = CmpConfig::default();
        assert!(c.window >= MIN_WINDOW);
        assert!(c.reclaim_period > 0);
        assert!(c.min_reclaim_batch > 0);
        assert!(c.use_scan_cursor);
        assert!(!c.helping);
        assert!(c.max_nodes.is_none());
        assert_eq!(c.magazine_capacity, DEFAULT_MAGAZINE_CAPACITY);
        assert!((c.bernoulli_p - 1.0 / c.reclaim_period as f64).abs() < 1e-15);
        assert!(!c.adaptive, "fixed knobs must stay the default");
    }

    #[test]
    fn bernoulli_p_tracks_reclaim_period() {
        let c = CmpConfig::default().with_reclaim_period(17);
        assert!((c.bernoulli_p - 1.0 / 17.0).abs() < 1e-15);
        let c = c.with_reclaim_period(0); // floors at 1
        assert_eq!(c.reclaim_period, 1);
        assert!((c.bernoulli_p - 1.0).abs() < 1e-15);
    }

    #[test]
    fn magazine_builders_apply() {
        let c = CmpConfig::default().with_magazine_capacity(7);
        assert_eq!(c.magazine_capacity, 7);
        let c = c.without_magazines();
        assert_eq!(c.magazine_capacity, 0);
    }

    #[test]
    fn window_sizing_rule() {
        // Low-rate queue floors at MIN_WINDOW.
        assert_eq!(CmpConfig::window_for(100, 0.001), MIN_WINDOW);
        // 1M ops/s with 100ms resilience → 100k cycles.
        assert_eq!(CmpConfig::window_for(1_000_000, 0.1), 100_000);
    }

    #[test]
    fn builders_apply() {
        let c = CmpConfig::default()
            .with_window(9999)
            .with_reclaim_period(17)
            .with_trigger(ReclaimTrigger::Bernoulli)
            .with_min_batch(5)
            .with_max_nodes(1 << 20)
            .without_scan_cursor()
            .with_helping()
            .without_stats();
        assert_eq!(c.window, 9999);
        assert_eq!(c.reclaim_period, 17);
        assert_eq!(c.trigger, ReclaimTrigger::Bernoulli);
        assert_eq!(c.min_reclaim_batch, 5);
        assert_eq!(c.max_nodes, Some(1 << 20));
        assert!(!c.use_scan_cursor);
        assert!(c.helping);
        assert!(!c.track_stats);
    }

    #[test]
    fn adaptive_builder_applies() {
        let c = CmpConfig::default().with_adaptive();
        assert!(c.adaptive);
    }

    #[test]
    fn window_floor_is_one() {
        let c = CmpConfig::default().with_window(0);
        assert_eq!(c.window, 1);
    }
}
