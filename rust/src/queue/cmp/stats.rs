//! Operation statistics for the CMP queue — used by the tests (to see
//! lost claims, reclamation counts, cursor behavior) and the ablation
//! benches. All counters are relaxed; recording is gated by
//! `CmpConfig::track_stats` so the perf configuration can shed them.

use std::sync::atomic::{AtomicU64, Ordering};

use crossbeam_utils::CachePadded;

/// Internal counters (cache-padded to keep stats traffic off the queue's
/// hot cache lines).
#[derive(Default)]
pub(crate) struct CmpStats {
    /// Enqueue link-CAS retries (stale tail observations).
    pub enq_retries: CachePadded<AtomicU64>,
    /// Dequeue scan steps beyond the first probed node.
    pub deq_extra_scans: CachePadded<AtomicU64>,
    /// Dequeue claim CASes lost to another consumer.
    pub deq_claim_fails: CachePadded<AtomicU64>,
    /// Successful scan-cursor advances.
    pub cursor_advances: CachePadded<AtomicU64>,
    /// Cursor advances skipped/lost (another thread already moved it).
    pub cursor_misses: CachePadded<AtomicU64>,
    /// Phase-3 aborts: claim succeeded but the payload was gone
    /// (stall-past-window semantics) or state was reincarnated.
    pub lost_claims: CachePadded<AtomicU64>,
    /// Completed reclamation passes.
    pub reclaim_passes: CachePadded<AtomicU64>,
    /// Reclamation entries skipped because another pass was running.
    pub reclaim_contended: CachePadded<AtomicU64>,
    /// Nodes recycled to the pool.
    pub nodes_reclaimed: CachePadded<AtomicU64>,
    /// Payloads dropped by the reclaimer (claimer stalled past window).
    pub payloads_reclaimed: CachePadded<AtomicU64>,
    /// `push_batch` calls (each pays one cycle RMW + one link CAS).
    pub batch_enqueues: CachePadded<AtomicU64>,
    /// Items enqueued through `push_batch`.
    pub batch_enqueued_items: CachePadded<AtomicU64>,
    /// `pop_batch` calls that claimed at least one node.
    pub batch_dequeues: CachePadded<AtomicU64>,
    /// Items dequeued through `pop_batch`.
    pub batch_dequeued_items: CachePadded<AtomicU64>,
    /// Spin iterations performed on the blocking wait path (flushed
    /// once per wait, not per iteration).
    pub wait_spins: CachePadded<AtomicU64>,
    /// Park registrations on the blocking wait path (spin phase gave
    /// up and the consumer announced itself to the eventcount).
    pub wait_parks: CachePadded<AtomicU64>,
}

impl CmpStats {
    /// Increment `counter` by one iff recording is `on`.
    #[inline]
    pub fn bump(counter: &CachePadded<AtomicU64>, on: bool) {
        if on {
            counter.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Increment `counter` by `n` iff recording is `on`.
    #[inline]
    pub fn add(counter: &CachePadded<AtomicU64>, n: u64, on: bool) {
        if on && n > 0 {
            counter.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Read every counter into a plain snapshot.
    pub fn snapshot(&self) -> CmpStatsSnapshot {
        CmpStatsSnapshot {
            enq_retries: self.enq_retries.load(Ordering::Relaxed),
            deq_extra_scans: self.deq_extra_scans.load(Ordering::Relaxed),
            deq_claim_fails: self.deq_claim_fails.load(Ordering::Relaxed),
            cursor_advances: self.cursor_advances.load(Ordering::Relaxed),
            cursor_misses: self.cursor_misses.load(Ordering::Relaxed),
            lost_claims: self.lost_claims.load(Ordering::Relaxed),
            reclaim_passes: self.reclaim_passes.load(Ordering::Relaxed),
            reclaim_contended: self.reclaim_contended.load(Ordering::Relaxed),
            nodes_reclaimed: self.nodes_reclaimed.load(Ordering::Relaxed),
            payloads_reclaimed: self.payloads_reclaimed.load(Ordering::Relaxed),
            batch_enqueues: self.batch_enqueues.load(Ordering::Relaxed),
            batch_enqueued_items: self.batch_enqueued_items.load(Ordering::Relaxed),
            batch_dequeues: self.batch_dequeues.load(Ordering::Relaxed),
            batch_dequeued_items: self.batch_dequeued_items.load(Ordering::Relaxed),
            wait_spins: self.wait_spins.load(Ordering::Relaxed),
            wait_parks: self.wait_parks.load(Ordering::Relaxed),
        }
    }
}

/// Public point-in-time view of the queue's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CmpStatsSnapshot {
    /// Enqueue link-CAS retries (stale tail observations).
    pub enq_retries: u64,
    /// Dequeue scan steps beyond the first probed node.
    pub deq_extra_scans: u64,
    /// Dequeue claim CASes lost to another consumer.
    pub deq_claim_fails: u64,
    /// Successful scan-cursor advances.
    pub cursor_advances: u64,
    /// Cursor advances skipped/lost (another thread already moved it).
    pub cursor_misses: u64,
    /// Claims whose payload was gone (stall-past-window semantics).
    pub lost_claims: u64,
    /// Completed reclamation passes.
    pub reclaim_passes: u64,
    /// Reclamation entries skipped because another pass was running.
    pub reclaim_contended: u64,
    /// Nodes recycled to the pool.
    pub nodes_reclaimed: u64,
    /// Payloads dropped by the reclaimer (claimer stalled past window).
    pub payloads_reclaimed: u64,
    /// `push_batch` calls (each pays one cycle RMW + one link CAS).
    pub batch_enqueues: u64,
    /// Items enqueued through `push_batch`.
    pub batch_enqueued_items: u64,
    /// `pop_batch` calls that claimed at least one node.
    pub batch_dequeues: u64,
    /// Items dequeued through `pop_batch`.
    pub batch_dequeued_items: u64,
    /// Spin iterations performed on the blocking wait path.
    pub wait_spins: u64,
    /// Park registrations on the blocking wait path.
    pub wait_parks: u64,
}

impl CmpStatsSnapshot {
    /// Render as `key=value` pairs (bench reports).
    pub fn summary(&self) -> String {
        format!(
            "enq_retries={} extra_scans={} claim_fails={} cursor_adv={} cursor_miss={} \
             lost_claims={} reclaims={} reclaim_contended={} nodes_reclaimed={} payloads_reclaimed={} \
             batch_enq={}/{} batch_deq={}/{} wait_spins={} wait_parks={}",
            self.enq_retries,
            self.deq_extra_scans,
            self.deq_claim_fails,
            self.cursor_advances,
            self.cursor_misses,
            self.lost_claims,
            self.reclaim_passes,
            self.reclaim_contended,
            self.nodes_reclaimed,
            self.payloads_reclaimed,
            self.batch_enqueues,
            self.batch_enqueued_items,
            self.batch_dequeues,
            self.batch_dequeued_items,
            self.wait_spins,
            self.wait_parks,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_respects_gate() {
        let s = CmpStats::default();
        CmpStats::bump(&s.enq_retries, false);
        assert_eq!(s.snapshot().enq_retries, 0);
        CmpStats::bump(&s.enq_retries, true);
        assert_eq!(s.snapshot().enq_retries, 1);
    }

    #[test]
    fn add_accumulates() {
        let s = CmpStats::default();
        CmpStats::add(&s.nodes_reclaimed, 5, true);
        CmpStats::add(&s.nodes_reclaimed, 0, true);
        CmpStats::add(&s.nodes_reclaimed, 3, false);
        assert_eq!(s.snapshot().nodes_reclaimed, 5);
    }

    #[test]
    fn summary_contains_all_fields() {
        let s = CmpStats::default().snapshot();
        let txt = s.summary();
        for key in [
            "enq_retries",
            "extra_scans",
            "claim_fails",
            "cursor_adv",
            "lost_claims",
            "reclaims",
            "nodes_reclaimed",
            "wait_spins",
            "wait_parks",
        ] {
            assert!(txt.contains(key), "missing {key} in {txt}");
        }
    }
}
