//! The CMP queue: lock-free enqueue (Algorithm 1) and dequeue
//! (Algorithm 3). Reclamation (Algorithm 4) lives in `reclaim.rs`.
//!
//! Memory-ordering convention follows the paper's footnote 1: acquire
//! loads where prior writes must be visible, release stores for
//! publication, acq-rel CAS, relaxed stats.

use std::cell::RefCell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, Ordering};

use crossbeam_utils::CachePadded;

use super::config::{CmpConfig, ReclaimTrigger};
use super::node::{Node, STATE_AVAILABLE, STATE_CLAIMED, STATE_FREE};
use super::pool::NodePool;
use super::stats::{CmpStats, CmpStatsSnapshot};
use crate::queue::ConcurrentQueue;
use crate::util::{Backoff, XorShift64};

thread_local! {
    /// Per-thread PRNG for the Bernoulli reclamation trigger.
    static TRIGGER_RNG: RefCell<XorShift64> = RefCell::new(XorShift64::new(
        // Spread by thread identity so producers don't fire in lockstep.
        {
            use std::hash::{Hash, Hasher};
            let mut h = std::collections::hash_map::DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            h.finish()
        },
    ));
}

/// Lock-free, strict-FIFO, unbounded MPMC queue with Cyclic Memory
/// Protection (the paper's contribution, §3).
///
/// ```
/// use cmpq::{CmpQueue, ConcurrentQueue};
/// let q: CmpQueue<u64> = CmpQueue::new();
/// q.enqueue(1);
/// q.enqueue(2);
/// assert_eq!(q.try_dequeue(), Some(1));
/// assert_eq!(q.try_dequeue(), Some(2));
/// assert_eq!(q.try_dequeue(), None);
/// ```
pub struct CmpQueue<T> {
    /// Always points at the permanent dummy node (§3.2.1); reclamation
    /// advances `head.next`, never `head` itself.
    pub(super) head: CachePadded<AtomicPtr<Node<T>>>,
    /// Enqueue-side hint; within one link of the physical tail (§3.4).
    pub(super) tail: CachePadded<AtomicPtr<Node<T>>>,
    /// Dequeue optimization: first likely-AVAILABLE node (§3.5 Phase 1).
    scan_cursor: CachePadded<AtomicPtr<Node<T>>>,
    /// Global enqueue cycle counter (§3.2.2).
    cycle: CachePadded<AtomicU64>,
    /// Highest cycle claimed by any dequeue — the protection frontier.
    deque_cycle: CachePadded<AtomicU64>,
    /// Single-reclaimer try-lock ("reclamation is non-blocking; if
    /// another thread is already reclaiming, enqueue proceeds", §3.3).
    pub(super) reclaim_busy: CachePadded<AtomicBool>,
    pub(super) pool: NodePool<T>,
    pub(super) config: CmpConfig,
    pub(super) stats: CmpStats,
}

unsafe impl<T: Send> Send for CmpQueue<T> {}
unsafe impl<T: Send> Sync for CmpQueue<T> {}

impl<T: Send> Default for CmpQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> CmpQueue<T> {
    /// Queue with the default configuration (`W = 4096`, `N = 1024`).
    pub fn new() -> Self {
        Self::with_config(CmpConfig::default())
    }

    /// Queue with an explicit configuration (window sizing per §3.1).
    pub fn with_config(config: CmpConfig) -> Self {
        // `track_stats` also gates the pool's freelist accounting RMW
        // (§Perf experiment 2: one fewer atomic per alloc/free pair).
        let pool = NodePool::with_accounting(config.max_nodes, config.track_stats);
        let (dummy, _) = pool
            .alloc()
            .expect("pool must fit at least the dummy node");
        // The dummy stays in `Free` state forever: claim CASes
        // (AVAILABLE → CLAIMED) can never succeed on it.
        unsafe {
            (*dummy).next.store(ptr::null_mut(), Ordering::Relaxed);
            (*dummy).cycle.store(super::node::DUMMY_CYCLE, Ordering::Relaxed);
        }
        Self {
            head: CachePadded::new(AtomicPtr::new(dummy)),
            tail: CachePadded::new(AtomicPtr::new(dummy)),
            scan_cursor: CachePadded::new(AtomicPtr::new(dummy)),
            cycle: CachePadded::new(AtomicU64::new(0)),
            deque_cycle: CachePadded::new(AtomicU64::new(0)),
            reclaim_busy: CachePadded::new(AtomicBool::new(false)),
            pool,
            config,
            stats: CmpStats::default(),
        }
    }

    /// Active configuration.
    pub fn config(&self) -> &CmpConfig {
        &self.config
    }

    /// Statistics snapshot (all zeros when `track_stats` is off).
    pub fn stats(&self) -> CmpStatsSnapshot {
        self.stats.snapshot()
    }

    /// Total nodes drawn from the OS (pool footprint; never shrinks —
    /// type stability, §3.2.1).
    pub fn footprint_nodes(&self) -> u64 {
        self.pool.fresh_allocated()
    }

    /// Nodes currently outside the pool freelist (dummy + linked list).
    pub fn nodes_in_use(&self) -> u64 {
        self.pool.in_use()
    }

    /// Current global enqueue cycle.
    pub fn enqueue_cycle(&self) -> u64 {
        self.cycle.load(Ordering::Acquire)
    }

    /// Current dequeue frontier (`deque_cycle`, §3.2.2).
    pub fn dequeue_cycle(&self) -> u64 {
        self.deque_cycle.load(Ordering::Acquire)
    }

    // ------------------------------------------------------------------
    // Algorithm 1 — Lock-Free Enqueue
    // ------------------------------------------------------------------

    /// Enqueue `item`. Fails only when a `max_nodes` cap is configured
    /// and reclamation cannot relieve the pressure (§3.3 Phase 1).
    pub fn push(&self, item: T) -> Result<(), T> {
        // Phase 1: node allocation and cycle assignment.
        let node = match self.alloc_node() {
            Some(n) => n,
            None => return Err(item),
        };
        unsafe {
            (*node).next.store(ptr::null_mut(), Ordering::Relaxed);
            (*node).put_data(item);
            let cycle = self.cycle.fetch_add(1, Ordering::AcqRel) + 1;
            (*node).cycle.store(cycle, Ordering::Relaxed);
            // Publish AVAILABLE before the link CAS releases the node.
            (*node).state.store(STATE_AVAILABLE, Ordering::Release);

            // Phase 2: lock-free insertion (M&S without helping, §3.4).
            let mut retries = 0u32;
            let mut backoff = Backoff::new();
            loop {
                let tail = self.tail.load(Ordering::Acquire);
                let next = (*tail).next.load(Ordering::Acquire);
                if !next.is_null() {
                    // Tail is stale.
                    CmpStats::bump(&self.stats.enq_retries, self.config.track_stats);
                    if self.config.helping {
                        // §3.4 ablation: original M&S helping — advance
                        // tail using the (possibly stale) next pointer.
                        let _ = self.tail.compare_exchange(
                            tail,
                            next,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        );
                    } else {
                        // Paper's design: retry with fresh state; pause
                        // when necessary (Algorithm 1 lines 15–21).
                        retries += 1;
                        if retries > 3 {
                            backoff.spin();
                        }
                    }
                    continue;
                }
                // Attempt to link the new node.
                if (*tail)
                    .next
                    .compare_exchange(
                        ptr::null_mut(),
                        node,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    // Optional tail advancement (failure is benign: the
                    // next enqueuer observes next ≠ null and waits for
                    // us — see DESIGN.md §6 tail-lag argument).
                    let _ = self.tail.compare_exchange(
                        tail,
                        node,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                    break;
                }
                CmpStats::bump(&self.stats.enq_retries, self.config.track_stats);
                retries += 1;
                if retries > 3 {
                    backoff.spin();
                }
            }

            // Phase 3: conditional reclamation.
            if self.should_trigger_reclaim(cycle) {
                self.reclaim();
            }
        }
        Ok(())
    }

    /// Allocate a node, applying the §3.3 pressure-relief loop: on pool
    /// exhaustion trigger reclamation and retry a bounded number of
    /// times before reporting failure.
    fn alloc_node(&self) -> Option<*mut Node<T>> {
        for attempt in 0..8 {
            if let Some((node, _reused)) = self.pool.alloc() {
                debug_assert_eq!(
                    unsafe { (*node).state.load(Ordering::Relaxed) },
                    STATE_FREE
                );
                return Some(node);
            }
            // Memory pressure: reclaim immediately and retry.
            let freed = self.reclaim();
            if freed == 0 && attempt > 2 {
                // Nothing reclaimable; let other threads progress.
                std::thread::yield_now();
            }
        }
        None
    }

    #[inline]
    fn should_trigger_reclaim(&self, cycle: u64) -> bool {
        match self.config.trigger {
            ReclaimTrigger::Modulo => cycle % self.config.reclaim_period == 0,
            ReclaimTrigger::Bernoulli => {
                let p = 1.0 / self.config.reclaim_period as f64;
                TRIGGER_RNG.with(|r| r.borrow_mut().chance(p))
            }
            ReclaimTrigger::Manual => false,
        }
    }

    /// Fault injection (FAULT experiment, §3.6): perform dequeue
    /// Phases 1–2 — claim the earliest AVAILABLE node — then *abandon*
    /// it, simulating a consumer that crashed immediately after its
    /// claim CAS. The abandoned payload is recovered (dropped) by
    /// reclamation once the node leaves the protection window; no other
    /// thread is blocked. Returns whether a node was claimed.
    pub fn inject_stalled_claim(&self) -> bool {
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            while !cur.is_null() {
                if (*cur)
                    .state
                    .compare_exchange(
                        STATE_AVAILABLE,
                        STATE_CLAIMED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                cur = (*cur).next.load(Ordering::Acquire);
            }
        }
        false
    }

    // ------------------------------------------------------------------
    // Algorithm 3 — Lock-Free Dequeue
    // ------------------------------------------------------------------

    /// Dequeue the earliest available item, or `None` when the queue is
    /// empty at the linearization point.
    pub fn pop(&self) -> Option<T> {
        unsafe {
            let mut current = self.head.load(Ordering::Acquire); // dummy, non-null
            let mut last_deque_cycle = 0u64;
            let mut last_cursor: *mut Node<T> = ptr::null_mut();
            let mut cursor_cycle = 0u64;
            let mut first_probe = true;

            // Phases 1–2: cursor-guided scan and atomic claim.
            loop {
                if current.is_null() {
                    return None; // reached the end: empty at this point
                }
                if self.config.use_scan_cursor {
                    let deque_cycle = self.deque_cycle.load(Ordering::Acquire);
                    if deque_cycle != last_deque_cycle {
                        // Other threads progressed: restart from the
                        // advertised cursor (§3.5 Phase 1).
                        last_deque_cycle = deque_cycle;
                        current = self.scan_cursor.load(Ordering::Acquire);
                        last_cursor = current;
                        cursor_cycle = (*current).cycle.load(Ordering::Acquire);
                    }
                }
                // Phase 2: atomic node claiming (single winner).
                if (*current)
                    .state
                    .compare_exchange(
                        STATE_AVAILABLE,
                        STATE_CLAIMED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    break;
                }
                if !first_probe {
                    CmpStats::bump(&self.stats.deq_extra_scans, self.config.track_stats);
                }
                first_probe = false;
                current = (*current).next.load(Ordering::Acquire);
            }

            // Phase 3: claim the payload (detect reincarnation / stall
            // -past-window reclamation, §3.5 Phase 3).
            if (*current).state.load(Ordering::Acquire) == STATE_AVAILABLE {
                CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                return None;
            }
            let data = match (*current).take_data() {
                Some(d) => d,
                None => {
                    CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                    return None;
                }
            };

            // Phase 4: opportunistic scan-cursor advance. The dual
            // (pointer, cycle) condition is the mathematical ABA guard:
            // a recycled cursor node carries a different cycle.
            let mut advance_boundary = true;
            if self.config.use_scan_cursor && !last_cursor.is_null() {
                let sc = self.scan_cursor.load(Ordering::Acquire);
                if sc == last_cursor
                    && (*sc).cycle.load(Ordering::Acquire) == cursor_cycle
                {
                    let next = (*current).next.load(Ordering::Acquire);
                    advance_boundary = false;
                    if next.is_null() {
                        // We claimed the last linked node. Algorithm 3 as
                        // printed leaves the cursor untouched here, but
                        // that lets it stagnate arbitrarily far behind
                        // `deque_cycle` under alternating push/pop —
                        // breaking the §3.5/§3.6 invariant
                        // `scan_cursor.cycle ≥ deque_cycle` the reclaimer
                        // depends on (a stagnant cursor node can then be
                        // recycled and a claim on its new incarnation
                        // violates FIFO). Advance to the claimed node
                        // itself, which restores the invariant
                        // (DESIGN.md §6).
                        if current != last_cursor {
                            let _ = self.scan_cursor.compare_exchange(
                                last_cursor,
                                current,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            );
                        }
                        advance_boundary = true;
                    } else if self
                        .scan_cursor
                        .compare_exchange(
                            last_cursor,
                            next,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        CmpStats::bump(&self.stats.cursor_advances, self.config.track_stats);
                        advance_boundary = true;
                    } else {
                        CmpStats::bump(&self.stats.cursor_misses, self.config.track_stats);
                    }
                }
            }

            // Phase 5: protection boundary update — publish the highest
            // claimed cycle (monotonic max via CAS loop).
            if advance_boundary {
                let my_cycle = (*current).cycle.load(Ordering::Acquire);
                let mut cur = self.deque_cycle.load(Ordering::Acquire);
                while cur < my_cycle {
                    match self.deque_cycle.compare_exchange_weak(
                        cur,
                        my_cycle,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }

            Some(data)
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for CmpQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item)
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn name(&self) -> &'static str {
        "cmp"
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        true
    }
}

impl<T> Drop for CmpQueue<T> {
    fn drop(&mut self) {
        // Drop any live payloads; segment memory is released by the
        // pool's Drop afterwards.
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            while !cur.is_null() {
                (*cur).drop_data_if_present();
                cur = (*cur).next.load(Ordering::Acquire);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::ReclaimTrigger;
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spsc_fifo_order() {
        let q: CmpQueue<u32> = CmpQueue::new();
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        for i in 0..1000 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn empty_queue_pops_none() {
        let q: CmpQueue<u8> = CmpQueue::new();
        assert_eq!(q.pop(), None);
        q.push(1).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop() {
        let q: CmpQueue<u64> = CmpQueue::new();
        let mut expect = 0u64;
        let mut next = 0u64;
        for round in 0..500 {
            for _ in 0..(round % 5 + 1) {
                q.push(next).unwrap();
                next += 1;
            }
            for _ in 0..(round % 3 + 1) {
                if let Some(v) = q.pop() {
                    assert_eq!(v, expect);
                    expect += 1;
                }
            }
        }
        while let Some(v) = q.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, next, "all items dequeued in order");
    }

    #[test]
    fn cycles_are_monotonic() {
        let q: CmpQueue<u32> = CmpQueue::new();
        assert_eq!(q.enqueue_cycle(), 0);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.enqueue_cycle(), 2);
        q.pop();
        assert!(q.dequeue_cycle() >= 1);
        q.pop();
        assert_eq!(q.dequeue_cycle(), 2);
    }

    #[test]
    fn drop_releases_payloads() {
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);
        {
            let q: CmpQueue<D> = CmpQueue::new();
            for _ in 0..10 {
                q.push(D).unwrap();
            }
            drop(q.pop()); // one dequeued and dropped here
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 10, "9 in queue + 1 popped");
    }

    #[test]
    fn bounded_pool_relieves_pressure_via_reclaim() {
        // Cap small; with Manual trigger + explicit reclaim, push/pop
        // cycles must keep working because nodes recycle.
        let cfg = CmpConfig::default()
            .with_max_nodes(4096)
            .with_window(16)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Modulo)
            .with_reclaim_period(64);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        for i in 0..20_000u64 {
            q.push(i).unwrap();
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.footprint_nodes() <= 4096, "stayed within cap");
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let producers = 4;
        let consumers = 4;
        let per = 5_000u64;
        let total = producers as u64 * per;
        let done = Arc::new(AtomicBool::new(false));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p as u64 * per + i).unwrap();
                }
            }));
        }
        let mut consumers_h = Vec::new();
        for _ in 0..consumers {
            let q = q.clone();
            let done = done.clone();
            consumers_h.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.pop() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.pop().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = Vec::new();
        for h in consumers_h {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len() as u64, total, "no loss");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "no duplicates");
    }

    #[test]
    fn per_producer_order_is_preserved() {
        let q: Arc<CmpQueue<(u8, u64)>> = Arc::new(CmpQueue::new());
        let per = 4_000u64;
        let producers: Vec<_> = (0..3u8)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push((p, i)).unwrap();
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let mut last = [-1i64; 3];
        while let Some((p, i)) = q.pop() {
            assert!(last[p as usize] < i as i64, "producer {p} out of order");
            last[p as usize] = i as i64;
        }
        for p in 0..3 {
            assert_eq!(last[p], per as i64 - 1);
        }
    }

    #[test]
    fn scan_cursor_disabled_still_correct() {
        let q: CmpQueue<u32> =
            CmpQueue::with_config(CmpConfig::default().without_scan_cursor());
        for i in 0..500 {
            q.push(i).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.stats().cursor_advances, 0, "cursor disabled");
    }

    #[test]
    fn helping_variant_still_correct() {
        let q: CmpQueue<u32> = CmpQueue::with_config(CmpConfig::default().with_helping());
        for i in 0..500 {
            q.push(i).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn bernoulli_trigger_reclaims_eventually() {
        let cfg = CmpConfig::default()
            .with_window(8)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Bernoulli)
            .with_reclaim_period(16);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        for i in 0..20_000u64 {
            q.push(i).unwrap();
            q.pop();
        }
        assert!(
            q.stats().reclaim_passes > 0,
            "Bernoulli trigger should fire over 20k enqueues"
        );
    }

    #[test]
    fn stats_disabled_stays_zero() {
        let q: CmpQueue<u32> =
            CmpQueue::with_config(CmpConfig::default().without_stats());
        for i in 0..100 {
            q.push(i).unwrap();
            q.pop();
        }
        assert_eq!(q.stats(), CmpStatsSnapshot::default());
    }
}
