//! The CMP queue: lock-free enqueue (Algorithm 1) and dequeue
//! (Algorithm 3). Reclamation (Algorithm 4) lives in `reclaim.rs`.
//!
//! Memory-ordering convention follows the paper's footnote 1: acquire
//! loads where prior writes must be visible, release stores for
//! publication, acq-rel CAS, relaxed stats.

use std::cell::RefCell;
use std::ptr;
use std::sync::atomic::Ordering;
use std::time::Instant;

// Real std atomics normally; model-checker shims under the
// `model-check` feature, so the claim CAS / frontier / parking core
// runs unmodified under the schedule enumerator (DESIGN.md §9).
use crate::model::shim::{AtomicBool, AtomicPtr, AtomicU64};

use crossbeam_utils::CachePadded;

use super::config::{CmpConfig, ReclaimTrigger};
use super::node::{Node, STATE_AVAILABLE, STATE_CLAIMED, STATE_FREE};
use super::pool::NodePool;
use super::stats::{CmpStats, CmpStatsSnapshot};
use crate::queue::{ConcurrentQueue, ControlReport};
use crate::runtime::adaptive::{AdaptiveSnapshot, GapTracker, QueueAdaptive};
use crate::util::{Backoff, WaitStrategy, XorShift64};

thread_local! {
    /// Per-thread PRNG for the Bernoulli reclamation trigger.
    static TRIGGER_RNG: RefCell<XorShift64> = RefCell::new(XorShift64::new(
        // Spread by thread identity so producers don't fire in lockstep.
        // `| 1` only guarantees a nonzero *seed* (skipping the zero-seed
        // remap path); the real all-zero-state hazard — a hash equal to
        // splitmix64's unique (odd!) preimage of 0 would have wedged the
        // Bernoulli trigger on that thread — is fixed at the source, in
        // `XorShift64::new`'s nonzero-state fallback (util/rng.rs).
        {
            use std::hash::{Hash, Hasher};
            let mut h = std::collections::hash_map::DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            h.finish() | 1
        },
    ));

    /// Per-thread inter-arrival tracker for the adaptive wait path
    /// (DESIGN.md §15), tagged with the owning queue's adaptive id so
    /// a thread that moves between queues re-learns the new regime
    /// instead of dragging a stale gap estimate across.
    static GAP_TRACKER: RefCell<(u64, GapTracker)> = RefCell::new((0, GapTracker::new()));
}

/// Outcome of the dequeue Phase 1–2 scan ([`CmpQueue::claim_first`]):
/// the claimed node plus the cursor observation the later
/// cursor-advance phase needs for its ABA-guarded CAS.
struct ClaimedStart<T> {
    node: *mut Node<T>,
    last_cursor: *mut Node<T>,
    cursor_cycle: u64,
}

/// Lock-free, strict-FIFO, unbounded MPMC queue with Cyclic Memory
/// Protection (the paper's contribution, §3).
///
/// ```
/// use cmpq::{CmpQueue, ConcurrentQueue};
/// let q: CmpQueue<u64> = CmpQueue::new();
/// q.enqueue(1);
/// q.enqueue(2);
/// assert_eq!(q.try_dequeue(), Some(1));
/// assert_eq!(q.try_dequeue(), Some(2));
/// assert_eq!(q.try_dequeue(), None);
/// ```
pub struct CmpQueue<T> {
    /// Always points at the permanent dummy node (§3.2.1); reclamation
    /// advances `head.next`, never `head` itself.
    pub(super) head: CachePadded<AtomicPtr<Node<T>>>,
    /// Enqueue-side hint; within one link of the physical tail (§3.4).
    pub(super) tail: CachePadded<AtomicPtr<Node<T>>>,
    /// Dequeue optimization: first likely-AVAILABLE node (§3.5 Phase 1).
    scan_cursor: CachePadded<AtomicPtr<Node<T>>>,
    /// Global enqueue cycle counter (§3.2.2).
    cycle: CachePadded<AtomicU64>,
    /// Highest cycle claimed by any dequeue — the protection frontier.
    deque_cycle: CachePadded<AtomicU64>,
    /// Single-reclaimer try-lock ("reclamation is non-blocking; if
    /// another thread is already reclaiming, enqueue proceeds", §3.3).
    pub(super) reclaim_busy: CachePadded<AtomicBool>,
    pub(super) pool: NodePool<T>,
    pub(super) config: CmpConfig,
    pub(super) stats: CmpStats,
    /// Eventcount for consumers blocked on an empty queue (DESIGN.md
    /// §8). Touched by the lock-free fast paths only as one fence +
    /// relaxed load per enqueue; parking happens exclusively on the
    /// empty slow path.
    waiters: WaitStrategy,
    /// Published adaptive decisions (DESIGN.md §15): spin budget, gap
    /// EWMA, live reclamation probability. Plain relaxed std atomics,
    /// read by waiters once per wait and written only off the
    /// lock-free fast path; inert unless `config.adaptive`.
    pub(super) adaptive: QueueAdaptive,
}

unsafe impl<T: Send> Send for CmpQueue<T> {}
unsafe impl<T: Send> Sync for CmpQueue<T> {}

impl<T: Send + 'static> Default for CmpQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send + 'static> CmpQueue<T> {
    /// Queue with the default configuration (`W = 4096`, `N = 1024`).
    pub fn new() -> Self {
        Self::with_config(CmpConfig::default())
    }

    /// Queue with an explicit configuration (window sizing per §3.1).
    pub fn with_config(mut config: CmpConfig) -> Self {
        // Normalize here, where the config freezes: a caller that set
        // `reclaim_period` by field access (bypassing the builders) can
        // neither leave a stale `bernoulli_p` on the hot path nor a
        // zero period for the Modulo trigger to divide by.
        config.reclaim_period = config.reclaim_period.max(1);
        config.bernoulli_p = 1.0 / config.reclaim_period as f64;
        // Bounded pools: disable the per-thread magazines. With a
        // `max_nodes` cap, idle threads' caches could strand the whole
        // budget where no other allocator (nor reclamation's pressure
        // relief) can reach it, breaking push's "fails only when
        // reclamation cannot relieve the pressure" contract. Unbounded
        // pools — the production default — keep the amortization
        // (DESIGN.md §7).
        if config.max_nodes.is_some() {
            config.magazine_capacity = 0;
        }
        // `track_stats` also gates the pool's freelist accounting RMW
        // (§Perf experiment 2: one fewer atomic per alloc/free pair).
        let pool = NodePool::with_magazines(
            config.max_nodes,
            config.track_stats,
            config.magazine_capacity,
        );
        let (dummy, _) = pool
            .alloc()
            .expect("pool must fit at least the dummy node");
        // The dummy stays in `Free` state forever: claim CASes
        // (AVAILABLE → CLAIMED) can never succeed on it.
        unsafe {
            (*dummy).next.store(ptr::null_mut(), Ordering::Relaxed);
            (*dummy).cycle.store(super::node::DUMMY_CYCLE, Ordering::Relaxed);
        }
        let adaptive = QueueAdaptive::new(config.bernoulli_p);
        Self {
            head: CachePadded::new(AtomicPtr::new(dummy)),
            tail: CachePadded::new(AtomicPtr::new(dummy)),
            scan_cursor: CachePadded::new(AtomicPtr::new(dummy)),
            cycle: CachePadded::new(AtomicU64::new(0)),
            deque_cycle: CachePadded::new(AtomicU64::new(0)),
            reclaim_busy: CachePadded::new(AtomicBool::new(false)),
            pool,
            config,
            stats: CmpStats::default(),
            waiters: WaitStrategy::new(),
            adaptive,
        }
    }

    /// Active configuration.
    pub fn config(&self) -> &CmpConfig {
        &self.config
    }

    /// Statistics snapshot (all zeros when `track_stats` is off).
    pub fn stats(&self) -> CmpStatsSnapshot {
        self.stats.snapshot()
    }

    /// Published adaptive-control decisions (DESIGN.md §15). With
    /// `adaptive` off the snapshot stays at its optimistic initial
    /// values (full spin budget, `live_p == bernoulli_p`) — nothing
    /// writes it.
    pub fn adaptive_snapshot(&self) -> AdaptiveSnapshot {
        self.adaptive.snapshot()
    }

    /// Eventcount sleeps: wait calls on this queue that reached the
    /// kernel-sleep loop (exported by the `/metrics` endpoint;
    /// unconditional — not gated by `track_stats`).
    pub fn wait_sleeps(&self) -> u64 {
        self.waiters.sleeps()
    }

    /// Total nodes drawn from the OS (pool footprint; never shrinks —
    /// type stability, §3.2.1).
    pub fn footprint_nodes(&self) -> u64 {
        self.pool.fresh_allocated()
    }

    /// Nodes currently outside the pool freelist (dummy + linked list).
    pub fn nodes_in_use(&self) -> u64 {
        self.pool.in_use()
    }

    /// Current global enqueue cycle.
    pub fn enqueue_cycle(&self) -> u64 {
        self.cycle.load(Ordering::Acquire)
    }

    /// Current dequeue frontier (`deque_cycle`, §3.2.2).
    pub fn dequeue_cycle(&self) -> u64 {
        self.deque_cycle.load(Ordering::Acquire)
    }

    // ------------------------------------------------------------------
    // Algorithm 1 — Lock-Free Enqueue
    // ------------------------------------------------------------------

    /// Enqueue `item`. Fails only when a `max_nodes` cap is configured
    /// and reclamation cannot relieve the pressure (§3.3 Phase 1).
    pub fn push(&self, item: T) -> Result<(), T> {
        // Phase 1: node allocation and cycle assignment.
        let node = match self.alloc_node() {
            Some(n) => n,
            None => return Err(item),
        };
        unsafe {
            (*node).next.store(ptr::null_mut(), Ordering::Relaxed);
            (*node).put_data(item);
            let cycle = self.cycle.fetch_add(1, Ordering::AcqRel) + 1;
            (*node).cycle.store(cycle, Ordering::Relaxed);
            // Publish AVAILABLE before the link CAS releases the node.
            (*node).state.store(STATE_AVAILABLE, Ordering::Release);

            // Phase 2: lock-free insertion (M&S without helping, §3.4).
            self.link_chain(node, node);

            // Wake parked consumers: with none registered this is one
            // fence + one relaxed load (DESIGN.md §8).
            self.waiters.notify_if_waiting();

            // Phase 3: conditional reclamation.
            if self.should_trigger_reclaim(cycle) {
                self.reclaim();
            }
        }
        Ok(())
    }

    /// Phase-2 insertion shared by `push` (a 1-node chain) and
    /// `push_batch`: link the private chain `first..=last` after the
    /// physical tail with one CAS, then opportunistically advance the
    /// tail hint to `last` (M&S without helping by default, §3.4).
    ///
    /// # Safety
    /// `first..=last` must be a valid, fully initialized chain that no
    /// other thread can reach yet, with `(*last).next == null`.
    unsafe fn link_chain(&self, first: *mut Node<T>, last: *mut Node<T>) {
        let mut retries = 0u32;
        let mut backoff = Backoff::new();
        loop {
            let tail = self.tail.load(Ordering::Acquire);
            let next = (*tail).next.load(Ordering::Acquire);
            if !next.is_null() {
                // Tail is stale.
                CmpStats::bump(&self.stats.enq_retries, self.config.track_stats);
                if self.config.helping {
                    // §3.4 ablation: original M&S helping — advance
                    // tail using the (possibly stale) next pointer.
                    let _ = self.tail.compare_exchange(
                        tail,
                        next,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                } else {
                    // Paper's design: retry with fresh state; pause
                    // when necessary (Algorithm 1 lines 15–21).
                    retries += 1;
                    if retries > 3 {
                        backoff.spin();
                    }
                }
                continue;
            }
            // Attempt to link the new chain.
            if (*tail)
                .next
                .compare_exchange(
                    ptr::null_mut(),
                    first,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                // Optional tail advancement (failure is benign: the
                // next enqueuer observes next ≠ null and waits for
                // us — see DESIGN.md §6 tail-lag argument).
                let _ = self.tail.compare_exchange(
                    tail,
                    last,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                return;
            }
            CmpStats::bump(&self.stats.enq_retries, self.config.track_stats);
            retries += 1;
            if retries > 3 {
                backoff.spin();
            }
        }
    }

    // ------------------------------------------------------------------
    // Batch enqueue (DESIGN.md §7) — amortized Algorithm 1
    // ------------------------------------------------------------------

    /// Enqueue `items` as one atomic batch: K nodes are pre-linked into
    /// a private chain, K contiguous cycles are claimed with a single
    /// `fetch_add(K)`, and the chain is published with a single
    /// tail-link CAS — so the two global RMWs of the enqueue hot path
    /// are paid once per batch instead of once per item. Because the
    /// chain is linked before publication, the batch occupies
    /// consecutive positions in the FIFO (no other enqueue can
    /// interleave inside it).
    ///
    /// All-or-nothing: on pool exhaustion (bounded `max_nodes` that
    /// reclamation cannot relieve) every item is handed back untouched.
    /// An empty batch is a no-op.
    pub fn push_batch(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        if items.is_empty() {
            return Ok(());
        }
        let k = items.len();
        // Phase 1: allocate all K nodes up front (§3.3 pressure relief
        // applies per node). Nodes are still FREE; on failure they go
        // straight back with one spliced push.
        let mut nodes: Vec<*mut Node<T>> = Vec::with_capacity(k);
        for _ in 0..k {
            match self.alloc_node() {
                Some(n) => nodes.push(n),
                None => {
                    // SAFETY: every node came from this pool's alloc
                    // moments ago and is still in its reset (FREE)
                    // state — nothing was linked or published.
                    unsafe { self.pool.free_chain(&nodes) };
                    return Err(items);
                }
            }
        }
        unsafe {
            // Phase 2: claim K contiguous cycles with one global RMW.
            let base = self.cycle.fetch_add(k as u64, Ordering::AcqRel);
            let last_cycle = base + k as u64;

            // Phase 3: build the private chain in FIFO order. Nothing is
            // visible to other threads until the link CAS below.
            for (i, item) in items.into_iter().enumerate() {
                let node = nodes[i];
                let next = if i + 1 < k {
                    nodes[i + 1]
                } else {
                    ptr::null_mut()
                };
                (*node).next.store(next, Ordering::Relaxed);
                (*node).put_data(item);
                (*node).cycle.store(base + 1 + i as u64, Ordering::Relaxed);
                // Publish AVAILABLE before the link CAS releases the node.
                (*node).state.store(STATE_AVAILABLE, Ordering::Release);
            }
            // Phase 4: single lock-free insertion of the whole chain
            // (exactly `push`'s Phase 2 — shared in `link_chain`).
            self.link_chain(nodes[0], nodes[k - 1]);

            // Wake parked consumers, once for the whole batch.
            self.waiters.notify_if_waiting();

            CmpStats::bump(&self.stats.batch_enqueues, self.config.track_stats);
            CmpStats::add(
                &self.stats.batch_enqueued_items,
                k as u64,
                self.config.track_stats,
            );

            // Phase 5: conditional reclamation, once per batch.
            if self.should_trigger_reclaim_span(last_cycle, k as u64) {
                self.reclaim();
            }
        }
        Ok(())
    }

    /// Allocate a node, applying the §3.3 pressure-relief loop: on pool
    /// exhaustion trigger reclamation and retry a bounded number of
    /// times before reporting failure.
    fn alloc_node(&self) -> Option<*mut Node<T>> {
        for attempt in 0..8 {
            if let Some((node, _reused)) = self.pool.alloc() {
                debug_assert_eq!(
                    unsafe { (*node).state.load(Ordering::Relaxed) },
                    STATE_FREE
                );
                return Some(node);
            }
            // Memory pressure: reclaim immediately and retry.
            let freed = self.reclaim();
            if freed == 0 && attempt > 2 {
                // Nothing reclaimable; let other threads progress.
                std::thread::yield_now();
            }
        }
        None
    }

    #[inline]
    fn should_trigger_reclaim(&self, cycle: u64) -> bool {
        self.should_trigger_reclaim_span(cycle, 1)
    }

    /// Trigger decision for an operation that claimed the cycle span
    /// `(last_cycle − span, last_cycle]` (span = 1 for single enqueues,
    /// K for `push_batch`). Modulo fires iff the span crossed a multiple
    /// of the period; Bernoulli runs one trial with probability scaled
    /// by the span, using the precomputed `1/N` from [`CmpConfig`].
    #[inline]
    fn should_trigger_reclaim_span(&self, last_cycle: u64, span: u64) -> bool {
        match self.config.trigger {
            ReclaimTrigger::Modulo => {
                let n = self.config.reclaim_period;
                last_cycle / n != (last_cycle - span) / n
            }
            ReclaimTrigger::Bernoulli => {
                // Adaptive mode reads the live, occupancy-tuned
                // probability published by the last reclamation pass
                // (DESIGN.md §15); one relaxed load, no extra traffic
                // on the fixed path.
                let base = if self.config.adaptive {
                    self.adaptive.live_p()
                } else {
                    self.config.bernoulli_p
                };
                let p = (base * span as f64).min(1.0);
                TRIGGER_RNG.with(|r| r.borrow_mut().chance(p))
            }
            ReclaimTrigger::Manual => false,
        }
    }

    /// Fault injection (FAULT experiment, §3.6): perform dequeue
    /// Phases 1–2 — claim the earliest AVAILABLE node — then *abandon*
    /// it, simulating a consumer that crashed immediately after its
    /// claim CAS. The abandoned payload is recovered (dropped) by
    /// reclamation once the node leaves the protection window; no other
    /// thread is blocked. Returns whether a node was claimed.
    pub fn inject_stalled_claim(&self) -> bool {
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            while !cur.is_null() {
                if (*cur)
                    .state
                    .compare_exchange(
                        STATE_AVAILABLE,
                        STATE_CLAIMED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                cur = (*cur).next.load(Ordering::Acquire);
            }
        }
        false
    }

    // ------------------------------------------------------------------
    // Algorithm 3 — Lock-Free Dequeue
    // ------------------------------------------------------------------

    /// Dequeue the earliest available item, or `None` when the queue is
    /// empty at the linearization point.
    pub fn pop(&self) -> Option<T> {
        unsafe {
            // Phases 1–2: cursor-guided scan and atomic claim.
            let start = self.claim_first()?;
            let current = start.node;

            // Phase 3: claim the payload (detect reincarnation / stall
            // -past-window reclamation, §3.5 Phase 3).
            if (*current).state.load(Ordering::Acquire) == STATE_AVAILABLE {
                CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                return None;
            }
            let data = match (*current).take_data() {
                Some(d) => d,
                None => {
                    CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                    return None;
                }
            };

            // Phases 4–5: cursor advance + frontier publication.
            let my_cycle = (*current).cycle.load(Ordering::Acquire);
            self.finish_claim(current, &start, my_cycle);

            Some(data)
        }
    }

    /// Phases 1–2 of Algorithm 3, shared by `pop` and `pop_batch_into`:
    /// cursor-guided scan from head, claim the first AVAILABLE node
    /// (single winner). `None` means the scan reached the end — empty
    /// at that linearization point.
    ///
    /// # Safety
    /// Standard CMP traversal: every pointer walked stays dereferenceable
    /// because nodes are type-stable for the queue's lifetime.
    unsafe fn claim_first(&self) -> Option<ClaimedStart<T>> {
        let mut current = self.head.load(Ordering::Acquire); // dummy, non-null
        let mut last_deque_cycle = 0u64;
        let mut last_cursor: *mut Node<T> = ptr::null_mut();
        let mut cursor_cycle = 0u64;
        let mut first_probe = true;

        loop {
            if current.is_null() {
                return None; // reached the end: empty at this point
            }
            if self.config.use_scan_cursor {
                let deque_cycle = self.deque_cycle.load(Ordering::Acquire);
                if deque_cycle != last_deque_cycle {
                    // Other threads progressed: restart from the
                    // advertised cursor (§3.5 Phase 1).
                    last_deque_cycle = deque_cycle;
                    current = self.scan_cursor.load(Ordering::Acquire);
                    last_cursor = current;
                    cursor_cycle = (*current).cycle.load(Ordering::Acquire);
                }
            }
            // Phase 2: atomic node claiming (single winner).
            if (*current)
                .state
                .compare_exchange(
                    STATE_AVAILABLE,
                    STATE_CLAIMED,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return Some(ClaimedStart {
                    node: current,
                    last_cursor,
                    cursor_cycle,
                });
            }
            if !first_probe {
                CmpStats::bump(&self.stats.deq_extra_scans, self.config.track_stats);
            }
            first_probe = false;
            current = (*current).next.load(Ordering::Acquire);
        }
    }

    /// Phases 4–5 of Algorithm 3, shared by `pop` (run of one) and
    /// `pop_batch_into` (run of many): one opportunistic scan-cursor
    /// advance past `current` (the run's last claimed node) and, if the
    /// cursor protocol permits, one monotonic CAS-max publication of
    /// `claimed_cycle` (the run's highest claimed cycle) to the
    /// protection frontier.
    ///
    /// The dual (pointer, cycle) cursor condition is the mathematical
    /// ABA guard: a recycled cursor node carries a different cycle.
    ///
    /// # Safety
    /// `current` must be a node this caller claimed in this operation;
    /// `start` must come from the same [`Self::claim_first`] call.
    unsafe fn finish_claim(
        &self,
        current: *mut Node<T>,
        start: &ClaimedStart<T>,
        claimed_cycle: u64,
    ) {
        // Phase 4: opportunistic scan-cursor advance.
        let mut advance_boundary = true;
        if self.config.use_scan_cursor && !start.last_cursor.is_null() {
            let sc = self.scan_cursor.load(Ordering::Acquire);
            if sc == start.last_cursor
                && (*sc).cycle.load(Ordering::Acquire) == start.cursor_cycle
            {
                let next = (*current).next.load(Ordering::Acquire);
                advance_boundary = false;
                if next.is_null() {
                    // We claimed the last linked node. Algorithm 3 as
                    // printed leaves the cursor untouched here, but
                    // that lets it stagnate arbitrarily far behind
                    // `deque_cycle` under alternating push/pop —
                    // breaking the §3.5/§3.6 invariant
                    // `scan_cursor.cycle ≥ deque_cycle` the reclaimer
                    // depends on (a stagnant cursor node can then be
                    // recycled and a claim on its new incarnation
                    // violates FIFO). Advance to the claimed node
                    // itself, which restores the invariant
                    // (DESIGN.md §6).
                    if current != start.last_cursor {
                        let _ = self.scan_cursor.compare_exchange(
                            start.last_cursor,
                            current,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        );
                    }
                    advance_boundary = true;
                } else if self
                    .scan_cursor
                    .compare_exchange(
                        start.last_cursor,
                        next,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    CmpStats::bump(&self.stats.cursor_advances, self.config.track_stats);
                    advance_boundary = true;
                } else {
                    CmpStats::bump(&self.stats.cursor_misses, self.config.track_stats);
                }
            }
        }

        // Phase 5: protection boundary update — publish the highest
        // claimed cycle (monotonic max via CAS loop).
        if advance_boundary {
            let mut cur = self.deque_cycle.load(Ordering::Acquire);
            while cur < claimed_cycle {
                match self.deque_cycle.compare_exchange_weak(
                    cur,
                    claimed_cycle,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => break,
                    Err(now) => cur = now,
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Batch dequeue (DESIGN.md §7) — amortized Algorithm 3
    // ------------------------------------------------------------------

    /// Dequeue up to `max` items, appending them to `out` in FIFO
    /// order; returns the number claimed. A run of consecutive
    /// AVAILABLE nodes is claimed node-by-node (the per-node claim CAS
    /// is unavoidable — it is the single-winner point), but the two
    /// *global* RMWs of the dequeue path — the scan-cursor CAS and the
    /// `deque_cycle` frontier CAS — are paid once per run instead of
    /// once per item.
    pub fn pop_batch_into(&self, max: usize, out: &mut Vec<T>) -> usize {
        if max == 0 {
            return 0;
        }
        let before = out.len();
        unsafe {
            // Phases 1–2 (shared with `pop`): cursor-guided scan, claim
            // the first AVAILABLE node.
            let start = match self.claim_first() {
                Some(s) => s,
                None => return 0, // reached the end: empty at this point
            };
            let mut current = start.node;

            // Phase 3, per node: extend the claimed run along the list,
            // taking each payload (reincarnation guard as in `pop`).
            // `last_taken` tracks the last node whose payload we
            // actually took: a lost-claim break leaves `current` on a
            // possibly *reincarnated* node, and advancing the cursor
            // through its new `next` would skip live items — only
            // nodes we verifiably own may steer Phase 4.
            let mut last_taken: *mut Node<T> = ptr::null_mut();
            let mut max_cycle = 0u64;
            loop {
                if (*current).state.load(Ordering::Acquire) == STATE_AVAILABLE {
                    // Recycled + republished between claim and read.
                    CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                    break;
                }
                match (*current).take_data() {
                    Some(d) => {
                        out.push(d);
                        last_taken = current;
                        let c = (*current).cycle.load(Ordering::Acquire);
                        if c > max_cycle {
                            max_cycle = c;
                        }
                    }
                    None => {
                        CmpStats::bump(&self.stats.lost_claims, self.config.track_stats);
                        break;
                    }
                }
                if out.len() - before >= max {
                    break;
                }
                let next = (*current).next.load(Ordering::Acquire);
                if next.is_null() {
                    break; // claimed through the linked tail
                }
                if (*next)
                    .state
                    .compare_exchange(
                        STATE_AVAILABLE,
                        STATE_CLAIMED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
                {
                    break; // another consumer owns the next node
                }
                current = next;
            }

            let got = out.len() - before;
            if got > 0 {
                // Phases 4–5 (shared with `pop`), once for the whole
                // run: cursor advance past the run's last *taken* node,
                // frontier CAS-max with the run's highest cycle. A run
                // that yielded nothing (first claim lost to a
                // reclamation race) skips both, exactly like `pop`'s
                // early return.
                self.finish_claim(last_taken, &start, max_cycle);
                CmpStats::bump(&self.stats.batch_dequeues, self.config.track_stats);
                CmpStats::add(
                    &self.stats.batch_dequeued_items,
                    got as u64,
                    self.config.track_stats,
                );
            }
            got
        }
    }

    /// Convenience wrapper over [`Self::pop_batch_into`].
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(max.min(64));
        self.pop_batch_into(max, &mut out);
        out
    }

    // ------------------------------------------------------------------
    // Blocking dequeues (DESIGN.md §8) — spin → yield → park
    // ------------------------------------------------------------------

    /// Dequeue, blocking until an item is available.
    ///
    /// Escalates spin → yield ([`Backoff::is_yielding`]) → epoch-guarded
    /// park on the queue's eventcount, so an idle consumer sleeps in the
    /// kernel instead of burning a core, and every `push`/`push_batch`
    /// wakes it immediately. The lock-free `pop` fast path is untouched:
    /// parking is reached only after repeated empty polls.
    ///
    /// There is no cancellation: this returns only when an item is
    /// claimed. A [`Self::wake_consumers`] kick onto a still-empty
    /// queue re-parks the caller — shutdown paths that must not block
    /// indefinitely should use [`Self::pop_deadline`] /
    /// [`Self::pop_deadline_batch`] instead.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use cmpq::CmpQueue;
    ///
    /// let q: Arc<CmpQueue<u32>> = Arc::new(CmpQueue::new());
    /// let q2 = q.clone();
    /// let consumer = std::thread::spawn(move || q2.pop_blocking());
    /// q.push(7).unwrap();
    /// assert_eq!(consumer.join().unwrap(), 7);
    /// ```
    pub fn pop_blocking(&self) -> T {
        self.pop_wait(None)
            .expect("pop_wait without a deadline cannot time out")
    }

    /// Dequeue, blocking until an item is available or `deadline`
    /// passes; `None` means the queue stayed empty through the deadline.
    ///
    /// ```
    /// use std::time::{Duration, Instant};
    /// use cmpq::CmpQueue;
    ///
    /// let q: CmpQueue<u32> = CmpQueue::new();
    /// assert_eq!(q.pop_deadline(Instant::now() + Duration::from_millis(5)), None);
    /// q.push(1).unwrap();
    /// assert_eq!(q.pop_deadline(Instant::now() + Duration::from_millis(5)), Some(1));
    /// ```
    pub fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        self.pop_wait(Some(deadline))
    }

    /// Blocking batch dequeue: block until at least one item is claimed,
    /// then claim a run of up to `max` (appending to `out`, FIFO order).
    /// Returns the number claimed (≥ 1).
    pub fn pop_blocking_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        self.pop_wait_batch(max, out, None)
    }

    /// Deadline batch dequeue: claim a run of up to `max` items
    /// (appending to `out`), blocking until at least one is available or
    /// `deadline` passes. Returns the number claimed (0 = empty through
    /// the deadline; `max == 0` returns 0 immediately).
    pub fn pop_deadline_batch(&self, max: usize, out: &mut Vec<T>, deadline: Instant) -> usize {
        self.pop_wait_batch(max, out, Some(deadline))
    }

    /// Shared wait loop of the blocking dequeues: run `attempt` (a
    /// single or batch claim) until it yields, escalating spin → yield
    /// → epoch-guarded park. `None` deadline means wait forever. The
    /// eventcount protocol (register → re-attempt → sleep) makes a push
    /// between "decide to sleep" and "sleep" wake us — the re-attempt
    /// after [`WaitStrategy::register`] is the lost-wakeup guard
    /// (DESIGN.md §8). On deadline expiry one final attempt runs, so a
    /// push racing the expiry is not left behind.
    fn park_wait<R>(
        &self,
        mut attempt: impl FnMut() -> Option<R>,
        deadline: Option<Instant>,
    ) -> Option<R> {
        let mut backoff = Backoff::new();
        // Under the model checker (constant `false` in normal builds):
        // skip the spin phase — perf-only noise that bloats the
        // schedule space (it is just repeated `attempt()`s) — and skip
        // wall-clock deadline expiry, which would inject machine-load
        // nondeterminism into otherwise identical schedules (virtual
        // time does not advance; deadline paths are checked by their
        // wakeup edges).
        let model = crate::model::shims_active();
        // Adaptive spin budget (DESIGN.md §15): sampled once per wait
        // from the queue's published decisions, so one wait follows
        // one consistent policy. Forced off under the model checker —
        // the spin phase is skipped there anyway, and reading wall
        // clocks would perturb schedule determinism. A budget of
        // MAX_SPIN_STEPS reproduces the fixed `is_yielding` schedule
        // exactly; smaller budgets only park *sooner*, so the
        // register → re-attempt → sleep protocol below (the
        // lost-wakeup guard) is unchanged in either mode.
        let adaptive = self.config.adaptive && !model;
        let budget = if adaptive {
            self.adaptive.spin_budget()
        } else {
            0
        };
        let mut spins = 0u64;
        let result = loop {
            if let Some(r) = attempt() {
                break Some(r);
            }
            if let Some(d) = deadline {
                if !model && Instant::now() >= d {
                    break None;
                }
            }
            let keep_spinning = if adaptive {
                backoff.step() < budget
            } else {
                !backoff.is_yielding()
            };
            if !model && keep_spinning {
                backoff.spin();
                spins += 1;
                continue;
            }
            // RAII registration: if `attempt` (a queue re-poll running
            // arbitrary payload Drops) unwinds, the waiter count is
            // still decremented — a leak here would permanently force
            // every producer onto the notify lock path.
            CmpStats::bump(&self.stats.wait_parks, self.config.track_stats);
            let registration = self.waiters.registration();
            if let Some(r) = attempt() {
                break Some(r); // registration drops → cancel
            }
            match deadline {
                Some(d) => {
                    if !registration.wait_deadline(d) {
                        // Deadline expired while parked: one final
                        // attempt so a push racing the expiry is not
                        // left behind.
                        break attempt();
                    }
                }
                None => registration.wait(),
            }
        };
        CmpStats::add(&self.stats.wait_spins, spins, self.config.track_stats);
        if adaptive && result.is_some() {
            self.observe_arrival();
        }
        result
    }

    /// Record an arrival observed by the blocking wait path (adaptive
    /// mode only): fold the gap since this thread's previous arrival
    /// into its EWMA and publish the updated estimate and spin budget.
    /// Runs strictly after the claim — never inside the lock-free
    /// scan/claim path (DESIGN.md §15).
    fn observe_arrival(&self) {
        GAP_TRACKER.with(|t| {
            let mut t = t.borrow_mut();
            if t.0 != self.adaptive.id() {
                *t = (self.adaptive.id(), GapTracker::new());
            }
            if let Some(ewma_ns) = t.1.observe(Instant::now()) {
                self.adaptive.record_gap(ewma_ns);
            }
        });
    }

    /// [`Self::park_wait`] over [`Self::pop`].
    fn pop_wait(&self, deadline: Option<Instant>) -> Option<T> {
        self.park_wait(|| self.pop(), deadline)
    }

    /// [`Self::park_wait`] over [`Self::pop_batch_into`].
    fn pop_wait_batch(&self, max: usize, out: &mut Vec<T>, deadline: Option<Instant>) -> usize {
        if max == 0 {
            return 0;
        }
        self.park_wait(
            || match self.pop_batch_into(max, out) {
                0 => None,
                n => Some(n),
            },
            deadline,
        )
        .unwrap_or(0)
    }

    // ------------------------------------------------------------------
    // Async dequeues (DESIGN.md §10) — waker registration, no threads
    // ------------------------------------------------------------------

    /// Dequeue asynchronously: the returned future resolves once an
    /// item is claimed, woken directly by the publishing
    /// [`CmpQueue::push`] / [`CmpQueue::push_batch`] through a waker
    /// slot on the queue's eventcount — no dedicated waiter thread, no
    /// executor dependency (any runtime's [`std::task::Waker`] works),
    /// and the enqueue fast path is untouched while no waiter is
    /// registered.
    ///
    /// Dropping a pending future cancels it: its waker slot is
    /// deregistered and no element is stranded (claims happen only
    /// inside `poll` and resolve immediately). Like
    /// [`CmpQueue::pop_blocking`], a resolved value is the only exit —
    /// shutdown paths should prefer [`CmpQueue::pop_deadline_async`],
    /// since [`CmpQueue::wake_consumers`] is a wake, not a
    /// cancellation: a woken future that still finds the queue empty
    /// re-registers and keeps waiting.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use cmpq::util::executor::block_on;
    /// use cmpq::CmpQueue;
    ///
    /// let q: Arc<CmpQueue<u32>> = Arc::new(CmpQueue::new());
    /// let q2 = q.clone();
    /// let consumer = std::thread::spawn(move || block_on(q2.pop_async()));
    /// q.push(7).unwrap();
    /// assert_eq!(consumer.join().unwrap(), 7);
    /// ```
    pub fn pop_async(&self) -> super::futures::PopFuture<'_, T> {
        super::futures::PopFuture::new(self)
    }

    /// Async batch dequeue: resolves to a run of 1..=`max` items
    /// claimed through the amortized [`CmpQueue::pop_batch_into`] path
    /// (`max == 0` resolves immediately with an empty vector). Same
    /// wakeup and cancellation semantics as [`CmpQueue::pop_async`].
    pub fn pop_async_batch(&self, max: usize) -> super::futures::PopBatchFuture<'_, T> {
        super::futures::PopBatchFuture::new(self, max)
    }

    /// Async dequeue with a deadline: resolves to `Some(item)` on a
    /// claim or `None` once `deadline` passes with the queue observed
    /// empty. Push-side wakeups work as in [`CmpQueue::pop_async`];
    /// expiry is delivered by the shared timer thread
    /// ([`crate::util::executor::wake_at`]), so a pending future burns
    /// no CPU while it waits.
    ///
    /// Timer entries are not cancellable: a future resolved (or
    /// dropped) early leaves its armed entry in the shared heap until
    /// `deadline`, when it fires one spurious wake. On high-churn
    /// paths prefer bounded deadline slices in a loop (as the
    /// coordinator's workers do) over one long far-future deadline.
    pub fn pop_deadline_async(
        &self,
        deadline: Instant,
    ) -> super::futures::PopDeadlineFuture<'_, T> {
        super::futures::PopDeadlineFuture::new(self, deadline)
    }

    /// Wake every consumer parked in a blocking dequeue (shutdown and
    /// drain paths), and every task pending in an async dequeue. Safe
    /// to call at any time; a consumer woken onto a still-empty queue
    /// simply re-parks — and a woken future re-registers — so this is
    /// a wake, not a cancellation (use the deadline variants on paths
    /// that must not wait forever).
    pub fn wake_consumers(&self) {
        self.waiters.notify_all();
    }

    /// Consumers currently registered on the parking layer — parked
    /// (or about-to-park) threads plus pending async waker slots
    /// (telemetry; racy by nature).
    pub fn parked_consumers(&self) -> u64 {
        self.waiters.waiters()
    }

    /// The queue's eventcount (waker registration surface for the
    /// async futures in `super::futures`; the sharded fabric parks its
    /// consumers on their home shard's eventcount through this too).
    pub(crate) fn wait_strategy(&self) -> &WaitStrategy {
        &self.waiters
    }

    // ------------------------------------------------------------------
    // Thread-cache management (DESIGN.md §7)
    // ------------------------------------------------------------------

    /// Return the calling thread's node-magazine contents to the global
    /// freelist. Exiting threads flush automatically; long-lived
    /// threads that stop using the queue can call this for exact
    /// accounting (`nodes_in_use` counts magazine-cached nodes as in
    /// use).
    pub fn flush_thread_cache(&self) {
        self.pool.flush_local();
    }

    /// Nodes currently cached in the calling thread's magazine.
    pub fn thread_cached_nodes(&self) -> usize {
        self.pool.local_cached()
    }

    /// Count nodes reachable from `head` (the dummy included). Only
    /// meaningful while the queue is quiescent; used by leak tests to
    /// prove `nodes_in_use() == linked nodes` (nothing stranded in a
    /// magazine).
    #[doc(hidden)]
    pub fn debug_linked_nodes(&self) -> u64 {
        let mut n = 0u64;
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            while !cur.is_null() {
                n += 1;
                cur = (*cur).next.load(Ordering::Acquire);
            }
        }
        n
    }
}

impl<T: Send + 'static> ConcurrentQueue<T> for CmpQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item)
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn try_enqueue_batch(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        self.push_batch(items)
    }

    fn try_dequeue_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        self.pop_batch_into(max, out)
    }

    fn pop_blocking(&self) -> T {
        CmpQueue::pop_blocking(self)
    }

    fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        CmpQueue::pop_deadline(self, deadline)
    }

    fn pop_blocking_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        CmpQueue::pop_blocking_batch(self, max, out)
    }

    fn pop_deadline_batch(&self, max: usize, out: &mut Vec<T>, deadline: Instant) -> usize {
        CmpQueue::pop_deadline_batch(self, max, out, deadline)
    }

    fn pop_async(&self) -> crate::queue::BoxFuture<'_, T> {
        Box::pin(CmpQueue::pop_async(self))
    }

    fn pop_deadline_async(&self, deadline: Instant) -> crate::queue::BoxFuture<'_, Option<T>> {
        Box::pin(CmpQueue::pop_deadline_async(self, deadline))
    }

    fn pop_async_batch(&self, max: usize) -> crate::queue::BoxFuture<'_, Vec<T>> {
        Box::pin(CmpQueue::pop_async_batch(self, max))
    }

    fn wake_all(&self) {
        self.wake_consumers();
    }

    fn name(&self) -> &'static str {
        if self.config.adaptive {
            "cmp-adaptive"
        } else {
            "cmp"
        }
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        true
    }

    fn control_report(&self) -> Option<ControlReport> {
        let s = self.stats.snapshot();
        let waits = s.wait_spins + s.wait_parks;
        ControlReport {
            // Fraction of blocking-wait effort that ended in a park
            // registration; needs `track_stats` for the inputs.
            park_ratio: (self.config.track_stats && waits > 0)
                .then(|| s.wait_parks as f64 / waits as f64),
            reclaim_p: Some(if self.config.adaptive {
                self.adaptive.live_p()
            } else {
                self.config.bernoulli_p
            }),
            spin_budget: Some(self.adaptive.spin_budget()),
        }
        .into()
    }
}

impl<T> Drop for CmpQueue<T> {
    fn drop(&mut self) {
        // Drop any live payloads; segment memory is released by the
        // pool's Drop afterwards.
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            while !cur.is_null() {
                (*cur).drop_data_if_present();
                cur = (*cur).next.load(Ordering::Acquire);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::ReclaimTrigger;
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spsc_fifo_order() {
        let q: CmpQueue<u32> = CmpQueue::new();
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        for i in 0..1000 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn empty_queue_pops_none() {
        let q: CmpQueue<u8> = CmpQueue::new();
        assert_eq!(q.pop(), None);
        q.push(1).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop() {
        let q: CmpQueue<u64> = CmpQueue::new();
        let mut expect = 0u64;
        let mut next = 0u64;
        for round in 0..500 {
            for _ in 0..(round % 5 + 1) {
                q.push(next).unwrap();
                next += 1;
            }
            for _ in 0..(round % 3 + 1) {
                if let Some(v) = q.pop() {
                    assert_eq!(v, expect);
                    expect += 1;
                }
            }
        }
        while let Some(v) = q.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, next, "all items dequeued in order");
    }

    #[test]
    fn cycles_are_monotonic() {
        let q: CmpQueue<u32> = CmpQueue::new();
        assert_eq!(q.enqueue_cycle(), 0);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.enqueue_cycle(), 2);
        q.pop();
        assert!(q.dequeue_cycle() >= 1);
        q.pop();
        assert_eq!(q.dequeue_cycle(), 2);
    }

    #[test]
    fn drop_releases_payloads() {
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);
        {
            let q: CmpQueue<D> = CmpQueue::new();
            for _ in 0..10 {
                q.push(D).unwrap();
            }
            drop(q.pop()); // one dequeued and dropped here
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 10, "9 in queue + 1 popped");
    }

    #[test]
    fn bounded_pool_relieves_pressure_via_reclaim() {
        // Cap small; with Manual trigger + explicit reclaim, push/pop
        // cycles must keep working because nodes recycle.
        let cfg = CmpConfig::default()
            .with_max_nodes(4096)
            .with_window(16)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Modulo)
            .with_reclaim_period(64);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        for i in 0..20_000u64 {
            q.push(i).unwrap();
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.footprint_nodes() <= 4096, "stayed within cap");
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let producers = 4;
        let consumers = 4;
        let per = 5_000u64;
        let total = producers as u64 * per;
        let done = Arc::new(AtomicBool::new(false));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p as u64 * per + i).unwrap();
                }
            }));
        }
        let mut consumers_h = Vec::new();
        for _ in 0..consumers {
            let q = q.clone();
            let done = done.clone();
            consumers_h.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.pop() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.pop().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = Vec::new();
        for h in consumers_h {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len() as u64, total, "no loss");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "no duplicates");
    }

    #[test]
    fn per_producer_order_is_preserved() {
        let q: Arc<CmpQueue<(u8, u64)>> = Arc::new(CmpQueue::new());
        let per = 4_000u64;
        let producers: Vec<_> = (0..3u8)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push((p, i)).unwrap();
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let mut last = [-1i64; 3];
        while let Some((p, i)) = q.pop() {
            assert!(last[p as usize] < i as i64, "producer {p} out of order");
            last[p as usize] = i as i64;
        }
        for p in 0..3 {
            assert_eq!(last[p], per as i64 - 1);
        }
    }

    #[test]
    fn scan_cursor_disabled_still_correct() {
        let q: CmpQueue<u32> =
            CmpQueue::with_config(CmpConfig::default().without_scan_cursor());
        for i in 0..500 {
            q.push(i).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.stats().cursor_advances, 0, "cursor disabled");
    }

    #[test]
    fn helping_variant_still_correct() {
        let q: CmpQueue<u32> = CmpQueue::with_config(CmpConfig::default().with_helping());
        for i in 0..500 {
            q.push(i).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn bernoulli_trigger_reclaims_eventually() {
        let cfg = CmpConfig::default()
            .with_window(8)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Bernoulli)
            .with_reclaim_period(16);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        for i in 0..20_000u64 {
            q.push(i).unwrap();
            q.pop();
        }
        assert!(
            q.stats().reclaim_passes > 0,
            "Bernoulli trigger should fire over 20k enqueues"
        );
    }

    #[test]
    fn stats_disabled_stays_zero() {
        let q: CmpQueue<u32> =
            CmpQueue::with_config(CmpConfig::default().without_stats());
        for i in 0..100 {
            q.push(i).unwrap();
            q.pop();
        }
        q.push_batch((0..8).collect::<Vec<_>>()).unwrap();
        q.pop_batch(8);
        assert_eq!(q.stats(), CmpStatsSnapshot::default());
    }

    #[test]
    fn push_batch_claims_contiguous_cycles_in_fifo_order() {
        let q: CmpQueue<u64> = CmpQueue::new();
        q.push_batch((0..8).collect::<Vec<_>>()).unwrap();
        assert_eq!(q.enqueue_cycle(), 8, "one fetch_add(8)");
        q.push(8).unwrap();
        q.push_batch(vec![9, 10]).unwrap();
        assert_eq!(q.enqueue_cycle(), 11);
        for i in 0..11 {
            assert_eq!(q.pop(), Some(i), "strict FIFO across batch/single mix");
        }
        assert_eq!(q.pop(), None);
        assert_eq!(q.stats().batch_enqueues, 2);
        assert_eq!(q.stats().batch_enqueued_items, 10);
    }

    #[test]
    fn push_batch_empty_is_noop() {
        let q: CmpQueue<u64> = CmpQueue::new();
        q.push_batch(Vec::new()).unwrap();
        assert_eq!(q.enqueue_cycle(), 0);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_batch_respects_max_and_order() {
        let q: CmpQueue<u64> = CmpQueue::new();
        q.push_batch((0..10).collect::<Vec<_>>()).unwrap();
        assert_eq!(q.pop_batch(4), vec![0, 1, 2, 3]);
        assert_eq!(q.pop_batch(0), Vec::<u64>::new());
        let mut out = vec![99]; // appends, never clears
        assert_eq!(q.pop_batch_into(100, &mut out), 6);
        assert_eq!(out, vec![99, 4, 5, 6, 7, 8, 9]);
        assert_eq!(q.pop_batch(4), Vec::<u64>::new());
        assert!(q.stats().batch_dequeues >= 2);
        assert_eq!(q.stats().batch_dequeued_items, 10);
    }

    #[test]
    fn pop_batch_advances_frontier_once() {
        let q: CmpQueue<u64> = CmpQueue::new();
        q.push_batch((0..16).collect::<Vec<_>>()).unwrap();
        assert_eq!(q.pop_batch(16).len(), 16);
        assert_eq!(q.dequeue_cycle(), 16, "frontier covers the whole run");
    }

    #[test]
    fn push_batch_all_or_nothing_on_exhausted_pool() {
        // Cap of 4 (dummy + 3): a batch of 8 cannot fit even after
        // reclamation, so every item must come back.
        let cfg = CmpConfig::default()
            .with_max_nodes(4)
            .with_trigger(ReclaimTrigger::Manual);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        let items: Vec<u64> = (0..8).collect();
        let back = q.push_batch(items).unwrap_err();
        assert_eq!(back, (0..8).collect::<Vec<_>>(), "items returned intact");
        assert_eq!(q.pop(), None, "nothing was published");
        // The pool can still serve batches that fit.
        q.push_batch(vec![1, 2, 3]).unwrap();
        assert_eq!(q.pop_batch(8), vec![1, 2, 3]);
    }

    #[test]
    fn batch_ops_with_tiny_window_and_reclaim() {
        let cfg = CmpConfig::default()
            .with_window(4)
            .with_min_batch(1)
            .with_reclaim_period(8);
        let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
        let mut next = 0u64;
        let mut expect = 0u64;
        for round in 0..2_000u64 {
            let k = round % 7 + 1;
            q.push_batch((next..next + k).collect::<Vec<_>>()).unwrap();
            next += k;
            for v in q.pop_batch(k as usize) {
                assert_eq!(v, expect, "FIFO under batch churn + reclaim");
                expect += 1;
            }
        }
        while let Some(v) = q.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, next);
    }

    #[test]
    fn pop_blocking_wakes_on_push() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_blocking());
        // Give the consumer time to escalate to a real park.
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(42).unwrap();
        assert_eq!(h.join().unwrap(), 42);
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn pop_deadline_semantics() {
        let q: CmpQueue<u64> = CmpQueue::new();
        let t0 = Instant::now();
        let dl = t0 + std::time::Duration::from_millis(40);
        assert_eq!(q.pop_deadline(dl), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(40));
        q.push(9).unwrap();
        assert_eq!(
            q.pop_deadline(Instant::now() + std::time::Duration::from_millis(40)),
            Some(9),
            "non-empty queue returns without waiting out the deadline"
        );
    }

    #[test]
    fn pop_deadline_batch_claims_run_pushed_while_parked() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            let dl = Instant::now() + std::time::Duration::from_secs(20);
            let n = q2.pop_deadline_batch(8, &mut out, dl);
            (n, out)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push_batch(vec![1, 2, 3]).unwrap();
        let (n, out) = h.join().unwrap();
        assert!(n >= 1, "woken by the batch publish");
        assert_eq!(out[0], 1, "FIFO from the parked claim");
    }

    #[test]
    fn wake_consumers_unblocks_parked_thread() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            // Woken by wake_consumers onto a still-empty queue, then the
            // deadline expires → None.
            q2.pop_deadline(Instant::now() + std::time::Duration::from_millis(200))
        });
        // Bounded observation: on a loaded box the consumer may time out
        // before we catch it parked — the join assertion holds anyway.
        let until = Instant::now() + std::time::Duration::from_secs(5);
        while q.parked_consumers() == 0 && Instant::now() < until {
            std::thread::yield_now();
        }
        q.wake_consumers();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn mixed_batch_and_single_mpmc_no_loss_no_dup() {
        let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
        let producers = 4usize;
        let per = 4_000u64; // must be divisible by the batch cadence below
        let total = producers as u64 * per;
        let done = Arc::new(AtomicBool::new(false));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let base = p as u64 * per;
                let mut i = 0u64;
                while i < per {
                    if i % 3 == 0 {
                        // Batch of 8.
                        let k = 8.min(per - i);
                        q.push_batch((base + i..base + i + k).collect::<Vec<_>>())
                            .unwrap();
                        i += k;
                    } else {
                        q.push(base + i).unwrap();
                        i += 1;
                    }
                }
            }));
        }
        let consumers: Vec<_> = (0..4)
            .map(|c| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut buf = Vec::new();
                    loop {
                        let n = if c % 2 == 0 {
                            q.pop_batch_into(16, &mut buf)
                        } else {
                            match q.pop() {
                                Some(v) => {
                                    buf.push(v);
                                    1
                                }
                                None => 0,
                            }
                        };
                        if n > 0 {
                            got.append(&mut buf);
                        } else if done.load(Ordering::Acquire) {
                            // Exit probe must not drop a claimed item.
                            match q.pop() {
                                Some(v) => got.push(v),
                                None => break,
                            }
                        } else {
                            std::thread::yield_now();
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = Vec::new();
        for h in consumers {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len() as u64, total, "no loss");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "no duplicates");
    }
}
