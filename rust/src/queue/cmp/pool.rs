//! Type-stable node pool (§3.2.1).
//!
//! "All linked-list nodes are allocated and recycled from a type-stable
//! memory pool — nodes reside in a persistent pool, recycled exclusively
//! as Node objects, and never freed to the OS." Segments are installed
//! on demand into a fixed directory and released only when the whole
//! pool (i.e. the owning queue) is dropped, so any pointer obtained from
//! this pool stays dereferenceable for the queue's lifetime.
//!
//! The internal freelist is a Treiber stack over node *indices* with a
//! 32-bit ABA tag packed beside the index in one `AtomicU64`. (This tag
//! protects only the pool-internal freelist; the queue-level ABA defense
//! is the paper's cycle window.)

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};

use super::node::{Node, STATE_FREE};

/// log2 of nodes per segment.
pub const SEG_SHIFT: usize = 10;
/// Nodes per segment.
pub const SEG_SIZE: usize = 1 << SEG_SHIFT;
/// Maximum installable segments (directory capacity). 16 Ki segments ×
/// 1 Ki nodes = 16.7M nodes per queue — far beyond any experiment here.
pub const MAX_SEGS: usize = 1 << 14;

/// Pack a freelist head: low 32 bits = node index + 1 (0 = empty list),
/// high 32 bits = ABA tag.
#[inline]
fn pack(tag: u32, idx_plus1: u32) -> u64 {
    ((tag as u64) << 32) | idx_plus1 as u64
}

#[inline]
fn unpack(word: u64) -> (u32, u32) {
    ((word >> 32) as u32, word as u32)
}

/// Type-stable segmented node pool.
pub struct NodePool<T> {
    /// Segment directory: fixed capacity, entries installed by CAS.
    segments: Box<[AtomicPtr<Node<T>>]>,
    /// Next never-used node index.
    next_fresh: AtomicU64,
    /// Packed freelist head (tag | idx+1).
    free_head: AtomicU64,
    /// Approximate freelist length (relaxed counter, for accounting).
    free_len: AtomicU64,
    /// Maintain `free_len` (one extra RMW per alloc/free). Disabled by
    /// perf configurations (`CmpConfig::without_stats`); accounting
    /// methods then report 0 recycled.
    count_free: bool,
    /// Optional cap on total fresh allocations.
    max_nodes: Option<usize>,
}

unsafe impl<T: Send> Send for NodePool<T> {}
unsafe impl<T: Send> Sync for NodePool<T> {}

impl<T> NodePool<T> {
    pub fn new(max_nodes: Option<usize>) -> Self {
        Self::with_accounting(max_nodes, true)
    }

    pub fn with_accounting(max_nodes: Option<usize>, count_free: bool) -> Self {
        let mut dir = Vec::with_capacity(MAX_SEGS);
        dir.resize_with(MAX_SEGS, || AtomicPtr::new(std::ptr::null_mut()));
        Self {
            segments: dir.into_boxed_slice(),
            next_fresh: AtomicU64::new(0),
            free_head: AtomicU64::new(pack(0, 0)),
            free_len: AtomicU64::new(0),
            count_free,
            max_nodes,
        }
    }

    /// Resolve a node index to its (stable) address. The segment must
    /// already be installed — guaranteed for any index handed out by
    /// [`Self::alloc`].
    #[inline]
    pub fn node_at(&self, idx: u32) -> *mut Node<T> {
        let seg = (idx as usize) >> SEG_SHIFT;
        let off = (idx as usize) & (SEG_SIZE - 1);
        let base = self.segments[seg].load(Ordering::Acquire);
        debug_assert!(!base.is_null(), "index {idx} resolved before segment install");
        unsafe { base.add(off) }
    }

    /// Allocate a node: freelist first (recycle), fresh segment space
    /// otherwise. `None` when the configured cap is exhausted — the
    /// caller (enqueue) then triggers reclamation and retries (§3.3).
    /// Returns `(ptr, reused)`.
    pub fn alloc(&self) -> Option<(*mut Node<T>, bool)> {
        // Freelist pop (tagged to defeat pool-internal ABA).
        let mut head = self.free_head.load(Ordering::Acquire);
        loop {
            let (tag, idx_plus1) = unpack(head);
            if idx_plus1 == 0 {
                break;
            }
            let node = self.node_at(idx_plus1 - 1);
            let next = unsafe { (*node).free_next.load(Ordering::Acquire) };
            let new = pack(tag.wrapping_add(1), next);
            match self.free_head.compare_exchange_weak(
                head,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if self.count_free {
                        self.free_len.fetch_sub(1, Ordering::Relaxed);
                    }
                    debug_assert_eq!(
                        unsafe { (*node).state.load(Ordering::Relaxed) },
                        STATE_FREE
                    );
                    return Some((node, true));
                }
                Err(cur) => head = cur,
            }
        }

        // Fresh allocation.
        loop {
            let idx = self.next_fresh.load(Ordering::Relaxed);
            if let Some(cap) = self.max_nodes {
                if idx as usize >= cap {
                    return None;
                }
            }
            assert!(
                (idx as usize) < MAX_SEGS * SEG_SIZE,
                "node pool directory exhausted ({} nodes)",
                MAX_SEGS * SEG_SIZE
            );
            if self
                .next_fresh
                .compare_exchange_weak(idx, idx + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let idx = idx as u32;
            self.ensure_segment((idx as usize) >> SEG_SHIFT);
            return Some((self.node_at(idx), false));
        }
    }

    /// Push a node back on the freelist. Caller must already have reset
    /// the node (state = FREE, next = null, payload dropped) — the
    /// reclaimer does this (Algorithm 4 Phase 5).
    pub fn free(&self, node: *mut Node<T>) {
        let idx = unsafe { (*node).pool_idx };
        let mut head = self.free_head.load(Ordering::Acquire);
        loop {
            let (tag, idx_plus1) = unpack(head);
            unsafe { (*node).free_next.store(idx_plus1, Ordering::Release) };
            let new = pack(tag.wrapping_add(1), idx + 1);
            match self.free_head.compare_exchange_weak(
                head,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if self.count_free {
                        self.free_len.fetch_add(1, Ordering::Relaxed);
                    }
                    return;
                }
                Err(cur) => head = cur,
            }
        }
    }

    /// Install segment `seg` if absent (idempotent, lock-free).
    fn ensure_segment(&self, seg: usize) {
        if !self.segments[seg].load(Ordering::Acquire).is_null() {
            return;
        }
        let base_idx = (seg << SEG_SHIFT) as u32;
        let mut nodes: Vec<Node<T>> = Vec::with_capacity(SEG_SIZE);
        for i in 0..SEG_SIZE {
            nodes.push(Node::blank(base_idx + i as u32));
        }
        let boxed: Box<[Node<T>]> = nodes.into_boxed_slice();
        let ptr = Box::into_raw(boxed) as *mut Node<T>;
        if self.segments[seg]
            .compare_exchange(
                std::ptr::null_mut(),
                ptr,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_err()
        {
            // Another thread installed first; drop our unpublished copy.
            unsafe {
                drop(Box::from_raw(std::slice::from_raw_parts_mut(ptr, SEG_SIZE)));
            }
        }
    }

    /// Total nodes ever drawn from fresh segment space — the pool's OS
    /// memory footprint in nodes (never shrinks: type stability).
    pub fn fresh_allocated(&self) -> u64 {
        self.next_fresh.load(Ordering::Relaxed)
    }

    /// Approximate current freelist length.
    pub fn freelist_len(&self) -> u64 {
        self.free_len.load(Ordering::Relaxed)
    }

    /// Nodes currently outside the freelist (live in the queue or held
    /// by the dummy): footprint − recycled.
    pub fn in_use(&self) -> u64 {
        self.fresh_allocated().saturating_sub(self.freelist_len())
    }
}

impl<T> Drop for NodePool<T> {
    fn drop(&mut self) {
        // The owning queue has already dropped any live payloads. Here we
        // only release segment memory (the one place nodes return to the
        // OS — after the data structure itself is gone).
        for slot in self.segments.iter() {
            let ptr = slot.load(Ordering::Acquire);
            if !ptr.is_null() {
                unsafe {
                    drop(Box::from_raw(std::slice::from_raw_parts_mut(ptr, SEG_SIZE)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn pack_unpack_roundtrip() {
        for (tag, idx) in [(0u32, 0u32), (1, 1), (u32::MAX, u32::MAX), (7, 1 << 20)] {
            assert_eq!(unpack(pack(tag, idx)), (tag, idx));
        }
    }

    #[test]
    fn fresh_alloc_assigns_sequential_indices() {
        let pool: NodePool<u32> = NodePool::new(None);
        for expect in 0..2500u32 {
            // crosses a segment boundary
            let (n, reused) = pool.alloc().unwrap();
            assert!(!reused);
            assert_eq!(unsafe { (*n).pool_idx }, expect);
        }
        assert_eq!(pool.fresh_allocated(), 2500);
    }

    #[test]
    fn free_then_alloc_recycles() {
        let pool: NodePool<u32> = NodePool::new(None);
        let (a, _) = pool.alloc().unwrap();
        let idx_a = unsafe { (*a).pool_idx };
        pool.free(a);
        assert_eq!(pool.freelist_len(), 1);
        let (b, reused) = pool.alloc().unwrap();
        assert!(reused);
        assert_eq!(unsafe { (*b).pool_idx }, idx_a, "LIFO recycle of same node");
        assert_eq!(pool.freelist_len(), 0);
    }

    #[test]
    fn cap_limits_fresh_allocations() {
        let pool: NodePool<u32> = NodePool::new(Some(3));
        let n1 = pool.alloc().unwrap().0;
        let _n2 = pool.alloc().unwrap().0;
        let _n3 = pool.alloc().unwrap().0;
        assert!(pool.alloc().is_none(), "cap reached");
        pool.free(n1);
        assert!(pool.alloc().is_some(), "recycle still works past cap");
    }

    #[test]
    fn node_at_is_stable_across_growth() {
        let pool: NodePool<u64> = NodePool::new(None);
        let (first, _) = pool.alloc().unwrap();
        let addr = first as usize;
        // Force several segment installs.
        for _ in 0..(3 * SEG_SIZE) {
            pool.alloc().unwrap();
        }
        assert_eq!(pool.node_at(0) as usize, addr, "type stability");
    }

    #[test]
    fn in_use_accounting() {
        let pool: NodePool<u8> = NodePool::new(None);
        let (a, _) = pool.alloc().unwrap();
        let (_b, _) = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.free(a);
        assert_eq!(pool.in_use(), 1);
    }

    #[test]
    fn concurrent_alloc_free_is_consistent() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(None));
        let threads = 8;
        let per = 2000;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for i in 0..per {
                        let (n, _) = p.alloc().unwrap();
                        held.push(n as usize);
                        if i % 3 == 0 {
                            let ptr = held.pop().unwrap() as *mut Node<u64>;
                            p.free(ptr);
                        }
                    }
                    // Distinctness of concurrently held nodes.
                    let mut sorted = held.clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    assert_eq!(sorted.len(), held.len(), "no double allocation");
                    for ptr in held {
                        p.free(ptr as *mut Node<u64>);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.in_use(), 0, "everything returned");
    }

    #[test]
    fn freelist_survives_tag_wraparound_pressure() {
        // Hammer a single slot to move the tag; correctness = no dup.
        let pool: NodePool<u32> = NodePool::new(Some(1));
        for _ in 0..10_000 {
            let (n, _) = pool.alloc().unwrap();
            assert!(pool.alloc().is_none());
            pool.free(n);
        }
        assert_eq!(pool.in_use(), 0);
    }
}
