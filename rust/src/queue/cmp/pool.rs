//! Type-stable node pool (§3.2.1) with per-thread magazines (DESIGN.md
//! §7).
//!
//! "All linked-list nodes are allocated and recycled from a type-stable
//! memory pool — nodes reside in a persistent pool, recycled exclusively
//! as Node objects, and never freed to the OS." Segments are installed
//! on demand into a fixed directory and released only when the whole
//! pool (i.e. the owning queue) is dropped, so any pointer obtained from
//! this pool stays dereferenceable for the queue's lifetime.
//!
//! The internal freelist is a Treiber stack over node *indices* with a
//! 32-bit ABA tag packed beside the index in one `AtomicU64`. (This tag
//! protects only the pool-internal freelist; the queue-level ABA defense
//! is the paper's cycle window.)
//!
//! On top of the shared freelist sits a **magazine layer**: each thread
//! keeps a small private stack of free-node indices per pool. Allocation
//! pops the magazine; an empty magazine refills with one chunked pop
//! (single CAS for up to `magazine_capacity` nodes), so the contended
//! `free_head` RMW is paid once per chunk instead of once per alloc.
//! The reclaimer returns whole batches with one spliced-chain push
//! ([`NodePool::free_chain`]). Magazines are flushed back to the global
//! freelist when their thread exits (a thread-local destructor holds a
//! `Weak` reference to the pool, so a dead pool simply skips the flush)
//! or explicitly via [`NodePool::flush_local`].

use std::cell::RefCell;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Weak};

// Real std atomics normally; model-checker shims under the
// `model-check` feature — the tagged freelist's ABA defense is one of
// the exhaustively checked properties (DESIGN.md §9).
use crate::model::shim::{AtomicPtr, AtomicU64};

use super::node::{Node, STATE_FREE};

/// log2 of nodes per segment.
pub const SEG_SHIFT: usize = 10;
/// Nodes per segment.
pub const SEG_SIZE: usize = 1 << SEG_SHIFT;
/// Maximum installable segments (directory capacity). 16 Ki segments ×
/// 1 Ki nodes = 16.7M nodes per queue — far beyond any experiment here.
pub const MAX_SEGS: usize = 1 << 14;

/// Pack a freelist head: low 32 bits = node index + 1 (0 = empty list),
/// high 32 bits = ABA tag.
#[inline]
fn pack(tag: u32, idx_plus1: u32) -> u64 {
    ((tag as u64) << 32) | idx_plus1 as u64
}

#[inline]
fn unpack(word: u64) -> (u32, u32) {
    ((word >> 32) as u32, word as u32)
}

/// Erased flush target for thread-exit magazine draining. Implemented by
/// [`PoolInner`]; object-safe so the thread-local registry can hold
/// magazines for pools of different `T`.
trait MagazineSink {
    /// Splice `indices` back onto the global freelist (one CAS).
    fn flush_indices(&self, indices: &[u32]);
}

/// One thread's private node cache for one pool.
struct MagazineEntry {
    pool_id: u64,
    /// Weak so a magazine never keeps a dropped queue's pool alive by
    /// itself; if the pool died first the indices die with its segments.
    sink: Weak<dyn MagazineSink>,
    slots: Vec<u32>,
}

/// Per-thread registry of magazines. The `Drop` impl is the
/// flush-on-thread-exit guarantee (no nodes stranded in dead threads).
struct LocalMagazines {
    entries: Vec<MagazineEntry>,
}

impl Drop for LocalMagazines {
    fn drop(&mut self) {
        for e in &mut self.entries {
            if e.slots.is_empty() {
                continue;
            }
            if let Some(sink) = e.sink.upgrade() {
                sink.flush_indices(&e.slots);
            }
            e.slots.clear();
        }
    }
}

thread_local! {
    static MAGAZINES: RefCell<LocalMagazines> =
        RefCell::new(LocalMagazines { entries: Vec::new() });
}

/// Pool identity for magazine routing (never reused).
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

/// Shared pool state. Lives behind an `Arc` so thread-exit flushes can
/// race a queue drop safely: an in-flight flush holds a temporary strong
/// reference and segment memory is released only after it completes.
struct PoolInner<T> {
    id: u64,
    /// Segment directory: fixed capacity, entries installed by CAS.
    segments: Box<[AtomicPtr<Node<T>>]>,
    /// Next never-used node index.
    next_fresh: AtomicU64,
    /// Packed freelist head (tag | idx+1).
    free_head: AtomicU64,
    /// Approximate freelist length (relaxed counter, for accounting).
    /// Excludes magazine-cached nodes, which count as "in use".
    free_len: AtomicU64,
    /// Maintain `free_len` (one extra RMW per alloc/free). Disabled by
    /// perf configurations (`CmpConfig::without_stats`); accounting
    /// methods then report 0 recycled.
    count_free: bool,
    /// Optional cap on total fresh allocations.
    max_nodes: Option<usize>,
    /// Per-thread magazine capacity; 0 disables the magazine layer.
    magazine_capacity: usize,
}

unsafe impl<T: Send> Send for PoolInner<T> {}
unsafe impl<T: Send> Sync for PoolInner<T> {}

/// Type-stable segmented node pool with per-thread magazines.
pub struct NodePool<T> {
    inner: Arc<PoolInner<T>>,
}

unsafe impl<T: Send> Send for NodePool<T> {}
unsafe impl<T: Send> Sync for NodePool<T> {}

impl<T> NodePool<T> {
    /// Pool with freelist accounting on and default magazine capacity.
    pub fn new(max_nodes: Option<usize>) -> Self {
        Self::with_accounting(max_nodes, true)
    }

    /// Pool with explicit freelist-accounting choice (perf configs
    /// disable the extra RMW) and default magazine capacity.
    pub fn with_accounting(max_nodes: Option<usize>, count_free: bool) -> Self {
        Self::with_magazines(
            max_nodes,
            count_free,
            super::config::DEFAULT_MAGAZINE_CAPACITY,
        )
    }

    /// Fully explicit constructor (`magazine_capacity == 0` disables
    /// the per-thread magazine layer).
    pub fn with_magazines(
        max_nodes: Option<usize>,
        count_free: bool,
        magazine_capacity: usize,
    ) -> Self {
        let mut dir = Vec::with_capacity(MAX_SEGS);
        dir.resize_with(MAX_SEGS, || AtomicPtr::new(std::ptr::null_mut()));
        Self {
            inner: Arc::new(PoolInner {
                id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
                segments: dir.into_boxed_slice(),
                next_fresh: AtomicU64::new(0),
                free_head: AtomicU64::new(pack(0, 0)),
                free_len: AtomicU64::new(0),
                count_free,
                max_nodes,
                magazine_capacity,
            }),
        }
    }

    /// Resolve a node index to its (stable) address. The segment must
    /// already be installed — guaranteed for any index handed out by
    /// [`Self::alloc`].
    #[inline]
    pub fn node_at(&self, idx: u32) -> *mut Node<T> {
        self.inner.node_at(idx)
    }

    /// Push a node back on the freelist.
    ///
    /// # Safety
    /// `node` must be a live node of **this** pool (obtained from
    /// [`Self::alloc`] and not since freed), already reset for
    /// recycling: state = FREE, `next` = null, payload dropped — the
    /// reclaimer does this (Algorithm 4 Phase 5). A foreign, dangling,
    /// or double-freed pointer corrupts the freelist.
    pub unsafe fn free(&self, node: *mut Node<T>) {
        let idx = (*node).pool_idx;
        self.inner.flush_indices(std::slice::from_ref(&idx));
    }

    /// Push an already-reset batch of nodes back on the freelist as one
    /// spliced chain: a single `free_head` CAS regardless of batch size
    /// (the reclamation release path, DESIGN.md §7).
    ///
    /// # Safety
    /// Same contract as [`Self::free`], for every node in the slice.
    pub unsafe fn free_chain(&self, nodes: &[*mut Node<T>]) {
        if nodes.is_empty() {
            return;
        }
        // Reuse the index-based splice; a reclamation batch is small and
        // short-lived, so the temporary index vector is cheap.
        let indices: Vec<u32> = nodes.iter().map(|&n| unsafe { (*n).pool_idx }).collect();
        self.inner.flush_indices(&indices);
    }

    /// Total nodes ever drawn from fresh segment space — the pool's OS
    /// memory footprint in nodes (never shrinks: type stability).
    pub fn fresh_allocated(&self) -> u64 {
        self.inner.next_fresh.load(Ordering::Relaxed)
    }

    /// Approximate current *global* freelist length. Nodes cached in
    /// per-thread magazines are not counted here.
    pub fn freelist_len(&self) -> u64 {
        self.inner.free_len.load(Ordering::Relaxed)
    }

    /// Nodes currently outside the global freelist — live in the queue,
    /// held by the dummy, or cached in a thread magazine:
    /// footprint − recycled.
    pub fn in_use(&self) -> u64 {
        self.fresh_allocated().saturating_sub(self.freelist_len())
    }

    /// Configured per-thread magazine capacity.
    pub fn magazine_capacity(&self) -> usize {
        self.inner.magazine_capacity
    }
}

impl<T: Send + 'static> NodePool<T> {
    /// Allocate a node: this thread's magazine first, then a chunked
    /// refill from the global freelist (one CAS per chunk), then fresh
    /// segment space. `None` when the configured cap is exhausted — the
    /// caller (enqueue) then triggers reclamation and retries (§3.3).
    /// Returns `(ptr, reused)`.
    pub fn alloc(&self) -> Option<(*mut Node<T>, bool)> {
        // Fault injection: simulate pool exhaustion (`None` is exactly
        // what a capped pool returns), exercising the caller's
        // reclaim-and-retry path. Compiles out without `failpoints`.
        crate::fail_point!("pool/alloc", None);
        // Under the model checker the magazine layer is bypassed: its
        // thread-exit flush (`LocalMagazines::Drop`) runs after the
        // virtual thread deregisters, i.e. *outside* the schedule —
        // a wall-clock-timed freelist CAS that would make identical
        // schedule prefixes diverge and break the enumerator's
        // determinism guarantee. `shims_active()` is a constant
        // `false` without the `model-check` feature.
        if self.inner.magazine_capacity > 0 && !crate::model::shims_active() {
            if let Ok(hit) = MAGAZINES.try_with(|m| self.alloc_cached(&mut m.borrow_mut())) {
                return hit;
            }
            // TLS already torn down (thread-exit path): fall through to
            // the uncached slow path below.
        }
        if let Some(node) = self.inner.pop_one() {
            return Some((node, true));
        }
        self.inner.alloc_fresh()
    }

    fn alloc_cached(&self, local: &mut LocalMagazines) -> Option<(*mut Node<T>, bool)> {
        let cap = self.inner.magazine_capacity;
        let id = self.inner.id;
        let i = match local.entries.iter().position(|e| e.pool_id == id) {
            Some(i) => i,
            None => {
                // First touch of this pool from this thread (rare path):
                // prune entries whose pool has died so the registry — and
                // the linear scan above — stays bounded by the number of
                // *live* pools, then register a weak flush handle.
                local.entries.retain(|e| e.sink.strong_count() > 0);
                let sink: Arc<dyn MagazineSink> = self.inner.clone();
                local.entries.push(MagazineEntry {
                    pool_id: id,
                    sink: Arc::downgrade(&sink),
                    slots: Vec::with_capacity(cap),
                });
                local.entries.len() - 1
            }
        };
        let slots = &mut local.entries[i].slots;
        if let Some(idx) = slots.pop() {
            let node = self.inner.node_at(idx);
            debug_assert_eq!(unsafe { (*node).state.load(Ordering::Relaxed) }, STATE_FREE);
            return Some((node, true));
        }
        // Refill: one CAS moves up to `cap` nodes into the magazine.
        if self.inner.pop_chunk(cap, slots) > 0 {
            let idx = slots.pop().expect("pop_chunk > 0 implies non-empty");
            let node = self.inner.node_at(idx);
            debug_assert_eq!(unsafe { (*node).state.load(Ordering::Relaxed) }, STATE_FREE);
            return Some((node, true));
        }
        self.inner.alloc_fresh()
    }

    /// Return this thread's magazine contents (for this pool) to the
    /// global freelist. Used by tests and by callers that want exact
    /// accounting from a long-lived thread; exiting threads flush
    /// automatically.
    pub fn flush_local(&self) {
        let _ = MAGAZINES.try_with(|m| {
            let mut m = m.borrow_mut();
            if let Some(e) = m.entries.iter_mut().find(|e| e.pool_id == self.inner.id) {
                if !e.slots.is_empty() {
                    self.inner.flush_indices(&e.slots);
                    e.slots.clear();
                }
            }
        });
    }

    /// Number of nodes currently cached in this thread's magazine for
    /// this pool (diagnostics / leak tests).
    pub fn local_cached(&self) -> usize {
        MAGAZINES
            .try_with(|m| {
                m.borrow()
                    .entries
                    .iter()
                    .find(|e| e.pool_id == self.inner.id)
                    .map(|e| e.slots.len())
                    .unwrap_or(0)
            })
            .unwrap_or(0)
    }
}

impl<T> PoolInner<T> {
    #[inline]
    fn node_at(&self, idx: u32) -> *mut Node<T> {
        let seg = (idx as usize) >> SEG_SHIFT;
        let off = (idx as usize) & (SEG_SIZE - 1);
        let base = self.segments[seg].load(Ordering::Acquire);
        debug_assert!(!base.is_null(), "index {idx} resolved before segment install");
        unsafe { base.add(off) }
    }

    /// Pop a single node from the global freelist (tagged to defeat
    /// pool-internal ABA). The magazine-less slow path.
    fn pop_one(&self) -> Option<*mut Node<T>> {
        let mut head = self.free_head.load(Ordering::Acquire);
        loop {
            let (tag, idx_plus1) = unpack(head);
            if idx_plus1 == 0 {
                return None;
            }
            let node = self.node_at(idx_plus1 - 1);
            let next = unsafe { (*node).free_next.load(Ordering::Acquire) };
            let new = pack(tag.wrapping_add(1), next);
            match self.free_head.compare_exchange_weak(
                head,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if self.count_free {
                        self.free_len.fetch_sub(1, Ordering::Relaxed);
                    }
                    debug_assert_eq!(
                        unsafe { (*node).state.load(Ordering::Relaxed) },
                        STATE_FREE
                    );
                    return Some(node);
                }
                Err(cur) => head = cur,
            }
        }
    }

    /// Pop up to `max` nodes from the global freelist with one CAS,
    /// **replacing** the contents of `out` with their indices (the
    /// vector is cleared on every CAS attempt — callers must pass an
    /// empty or disposable buffer). Returns the count (0 = empty).
    ///
    /// The walk reads `free_next` links of nodes still on the shared
    /// stack; that is safe because nodes are type-stable and a link can
    /// only change via a successful `free_head` CAS, which bumps the tag
    /// and fails ours — any chain observed under an unchanged tag is
    /// consistent.
    fn pop_chunk(&self, max: usize, out: &mut Vec<u32>) -> usize {
        debug_assert!(max > 0);
        let mut head = self.free_head.load(Ordering::Acquire);
        loop {
            let (tag, first) = unpack(head);
            if first == 0 {
                return 0;
            }
            out.clear();
            let mut cur = first;
            let mut rest = 0u32;
            for _ in 0..max {
                let node = self.node_at(cur - 1);
                out.push(cur - 1);
                rest = unsafe { (*node).free_next.load(Ordering::Acquire) };
                if rest == 0 {
                    break;
                }
                cur = rest;
            }
            let new = pack(tag.wrapping_add(1), rest);
            match self.free_head.compare_exchange_weak(
                head,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if self.count_free {
                        self.free_len.fetch_sub(out.len() as u64, Ordering::Relaxed);
                    }
                    return out.len();
                }
                Err(cur_head) => head = cur_head,
            }
        }
    }

    /// Fresh allocation from never-used segment space.
    fn alloc_fresh(&self) -> Option<(*mut Node<T>, bool)> {
        loop {
            let idx = self.next_fresh.load(Ordering::Relaxed);
            if let Some(cap) = self.max_nodes {
                if idx as usize >= cap {
                    return None;
                }
            }
            assert!(
                (idx as usize) < MAX_SEGS * SEG_SIZE,
                "node pool directory exhausted ({} nodes)",
                MAX_SEGS * SEG_SIZE
            );
            if self
                .next_fresh
                .compare_exchange_weak(idx, idx + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let idx = idx as u32;
            self.ensure_segment((idx as usize) >> SEG_SHIFT);
            return Some((self.node_at(idx), false));
        }
    }

    /// Install segment `seg` if absent (idempotent, lock-free).
    fn ensure_segment(&self, seg: usize) {
        if !self.segments[seg].load(Ordering::Acquire).is_null() {
            return;
        }
        let base_idx = (seg << SEG_SHIFT) as u32;
        let mut nodes: Vec<Node<T>> = Vec::with_capacity(SEG_SIZE);
        for i in 0..SEG_SIZE {
            nodes.push(Node::blank(base_idx + i as u32));
        }
        let boxed: Box<[Node<T>]> = nodes.into_boxed_slice();
        let ptr = Box::into_raw(boxed) as *mut Node<T>;
        if self.segments[seg]
            .compare_exchange(
                std::ptr::null_mut(),
                ptr,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_err()
        {
            // Another thread installed first; drop our unpublished copy.
            unsafe {
                drop(Box::from_raw(std::slice::from_raw_parts_mut(ptr, SEG_SIZE)));
            }
        }
    }
}

impl<T> MagazineSink for PoolInner<T> {
    /// Splice `indices` onto the freelist as one pre-linked chain:
    /// `indices[0] → indices[1] → … → old head`, published with a
    /// single CAS.
    fn flush_indices(&self, indices: &[u32]) {
        if indices.is_empty() {
            return;
        }
        for w in indices.windows(2) {
            let node = self.node_at(w[0]);
            unsafe { (*node).free_next.store(w[1] + 1, Ordering::Relaxed) };
        }
        let first = indices[0];
        let last = self.node_at(*indices.last().expect("non-empty"));
        let mut head = self.free_head.load(Ordering::Acquire);
        loop {
            let (tag, old_first) = unpack(head);
            unsafe { (*last).free_next.store(old_first, Ordering::Release) };
            let new = pack(tag.wrapping_add(1), first + 1);
            match self.free_head.compare_exchange_weak(
                head,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if self.count_free {
                        self.free_len.fetch_add(indices.len() as u64, Ordering::Relaxed);
                    }
                    return;
                }
                Err(cur) => head = cur,
            }
        }
    }
}

impl<T> Drop for PoolInner<T> {
    fn drop(&mut self) {
        // The owning queue has already dropped any live payloads. Here we
        // only release segment memory (the one place nodes return to the
        // OS — after the data structure itself is gone, and after any
        // in-flight thread-exit flush has dropped its temporary Arc).
        for slot in self.segments.iter() {
            let ptr = slot.load(Ordering::Acquire);
            if !ptr.is_null() {
                unsafe {
                    drop(Box::from_raw(std::slice::from_raw_parts_mut(ptr, SEG_SIZE)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn pack_unpack_roundtrip() {
        for (tag, idx) in [(0u32, 0u32), (1, 1), (u32::MAX, u32::MAX), (7, 1 << 20)] {
            assert_eq!(unpack(pack(tag, idx)), (tag, idx));
        }
    }

    #[test]
    fn fresh_alloc_assigns_sequential_indices() {
        let pool: NodePool<u32> = NodePool::new(None);
        for expect in 0..2500u32 {
            // crosses a segment boundary
            let (n, reused) = pool.alloc().unwrap();
            assert!(!reused);
            assert_eq!(unsafe { (*n).pool_idx }, expect);
        }
        assert_eq!(pool.fresh_allocated(), 2500);
    }

    #[test]
    fn free_then_alloc_recycles() {
        let pool: NodePool<u32> = NodePool::new(None);
        let (a, _) = pool.alloc().unwrap();
        let idx_a = unsafe { (*a).pool_idx };
        unsafe { pool.free(a) };
        assert_eq!(pool.freelist_len(), 1);
        let (b, reused) = pool.alloc().unwrap();
        assert!(reused);
        assert_eq!(unsafe { (*b).pool_idx }, idx_a, "LIFO recycle of same node");
        assert_eq!(pool.freelist_len(), 0);
    }

    #[test]
    fn cap_limits_fresh_allocations() {
        let pool: NodePool<u32> = NodePool::new(Some(3));
        let n1 = pool.alloc().unwrap().0;
        let _n2 = pool.alloc().unwrap().0;
        let _n3 = pool.alloc().unwrap().0;
        assert!(pool.alloc().is_none(), "cap reached");
        unsafe { pool.free(n1) };
        assert!(pool.alloc().is_some(), "recycle still works past cap");
    }

    #[test]
    fn node_at_is_stable_across_growth() {
        let pool: NodePool<u64> = NodePool::new(None);
        let (first, _) = pool.alloc().unwrap();
        let addr = first as usize;
        // Force several segment installs.
        for _ in 0..(3 * SEG_SIZE) {
            pool.alloc().unwrap();
        }
        assert_eq!(pool.node_at(0) as usize, addr, "type stability");
    }

    #[test]
    fn in_use_accounting() {
        let pool: NodePool<u8> = NodePool::new(None);
        let (a, _) = pool.alloc().unwrap();
        let (_b, _) = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        unsafe { pool.free(a) };
        assert_eq!(pool.in_use(), 1);
    }

    #[test]
    fn concurrent_alloc_free_is_consistent() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(None));
        let threads = 8;
        let per = 2000;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for i in 0..per {
                        let (n, _) = p.alloc().unwrap();
                        held.push(n as usize);
                        if i % 3 == 0 {
                            let ptr = held.pop().unwrap() as *mut Node<u64>;
                            unsafe { p.free(ptr) };
                        }
                    }
                    // Distinctness of concurrently held nodes.
                    let mut sorted = held.clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    assert_eq!(sorted.len(), held.len(), "no double allocation");
                    for ptr in held {
                        unsafe { p.free(ptr as *mut Node<u64>) };
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Worker magazines were flushed on thread exit; everything is
        // back on the global freelist.
        assert_eq!(pool.in_use(), 0, "everything returned");
    }

    #[test]
    fn freelist_survives_tag_wraparound_pressure() {
        // Hammer a single slot to move the tag; correctness = no dup.
        let pool: NodePool<u32> = NodePool::new(Some(1));
        for _ in 0..10_000 {
            let (n, _) = pool.alloc().unwrap();
            assert!(pool.alloc().is_none());
            unsafe { pool.free(n) };
        }
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn chunked_refill_fills_magazine() {
        let pool: NodePool<u32> = NodePool::with_magazines(None, true, 8);
        // Seed the global freelist with 20 recycled nodes.
        let nodes: Vec<_> = (0..20).map(|_| pool.alloc().unwrap().0).collect();
        pool.flush_local();
        unsafe { pool.free_chain(&nodes) };
        assert_eq!(pool.freelist_len(), 20);
        // One alloc pulls a whole chunk: 1 returned + 7 cached.
        let (_n, reused) = pool.alloc().unwrap();
        assert!(reused);
        assert_eq!(pool.local_cached(), 7);
        assert_eq!(pool.freelist_len(), 12);
        // Subsequent allocs drain the magazine without touching the
        // global freelist.
        for _ in 0..7 {
            assert!(pool.alloc().unwrap().1);
        }
        assert_eq!(pool.local_cached(), 0);
        assert_eq!(pool.freelist_len(), 12);
    }

    #[test]
    fn flush_local_returns_cached_nodes() {
        let pool: NodePool<u32> = NodePool::with_magazines(None, true, 8);
        let nodes: Vec<_> = (0..8).map(|_| pool.alloc().unwrap().0).collect();
        unsafe { pool.free_chain(&nodes) };
        let _ = pool.alloc().unwrap(); // refill: 1 out, 7 cached
        assert_eq!(pool.local_cached(), 7);
        let held = pool.in_use();
        pool.flush_local();
        assert_eq!(pool.local_cached(), 0);
        assert_eq!(pool.in_use(), held - 7, "cached nodes returned");
    }

    #[test]
    fn magazine_flushes_on_thread_exit() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::with_magazines(None, true, 16));
        // Seed recycled nodes so the worker's allocs go through refill.
        let nodes: Vec<_> = (0..32).map(|_| pool.alloc().unwrap().0).collect();
        pool.flush_local();
        unsafe { pool.free_chain(&nodes) };
        let before = pool.in_use();
        assert_eq!(before, 0);
        let p = pool.clone();
        std::thread::spawn(move || {
            let (n, reused) = p.alloc().unwrap();
            assert!(reused);
            assert!(p.local_cached() > 0, "refill cached extra nodes");
            unsafe { p.free(n) };
            // Exit with a non-empty magazine: the TLS destructor must
            // flush it.
        })
        .join()
        .unwrap();
        assert_eq!(pool.in_use(), 0, "no nodes stranded in the dead thread");
    }

    #[test]
    fn free_chain_is_one_splice() {
        let pool: NodePool<u32> = NodePool::with_magazines(None, true, 0);
        let nodes: Vec<_> = (0..10).map(|_| pool.alloc().unwrap().0).collect();
        unsafe { pool.free_chain(&nodes) };
        assert_eq!(pool.freelist_len(), 10);
        // All ten come back out, each exactly once.
        let mut seen: Vec<u32> = (0..10)
            .map(|_| unsafe { (*pool.alloc().unwrap().0).pool_idx })
            .collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 10, "no duplicates from the spliced chain");
        assert!(!pool.alloc().unwrap().1, "11th alloc is fresh again");
    }

    #[test]
    fn zero_capacity_disables_magazines() {
        let pool: NodePool<u32> = NodePool::with_magazines(None, true, 0);
        let (a, _) = pool.alloc().unwrap();
        unsafe { pool.free(a) };
        assert_eq!(pool.freelist_len(), 1);
        let (_b, reused) = pool.alloc().unwrap();
        assert!(reused);
        assert_eq!(pool.local_cached(), 0, "nothing cached when disabled");
    }
}
