//! Cyclic Memory Protection (CMP) — the paper's contribution (§3).
//!
//! A lock-free, strict-FIFO, unbounded MPMC queue whose memory safety
//! comes from two coordination-free mechanisms instead of hazard
//! pointers or epochs:
//!
//! 1. **State protection** — nodes transition `AVAILABLE → CLAIMED`; an
//!    `AVAILABLE` node is never reclaimed.
//! 2. **Cycle-based sliding window** — every node carries an immutable
//!    monotonically increasing *cycle*; dequeues publish the highest
//!    claimed cycle (`deque_cycle`) and reclamation only frees `CLAIMED`
//!    nodes with `cycle < deque_cycle − W`.
//!
//! Nodes live in a type-stable pool ([`pool`]) and are recycled, never
//! freed to the OS while the queue lives, so stale pointers always
//! reference a valid `Node` — the property §3.2.1 relies on.

mod config;
mod node;
mod pool;
mod queue;
mod reclaim;
mod stats;

pub use config::{CmpConfig, ReclaimTrigger};
pub use node::{NodeState, DUMMY_CYCLE};
pub use queue::CmpQueue;
pub use stats::CmpStatsSnapshot;
