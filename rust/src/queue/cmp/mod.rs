//! Cyclic Memory Protection (CMP) — the paper's contribution (§3).
//!
//! A lock-free, strict-FIFO, unbounded MPMC queue whose memory safety
//! comes from two coordination-free mechanisms instead of hazard
//! pointers or epochs:
//!
//! 1. **State protection** — nodes transition `AVAILABLE → CLAIMED`; an
//!    `AVAILABLE` node is never reclaimed.
//! 2. **Cycle-based sliding window** — every node carries an immutable
//!    monotonically increasing *cycle*; dequeues publish the highest
//!    claimed cycle (`deque_cycle`) and reclamation only frees `CLAIMED`
//!    nodes with `cycle < deque_cycle − W`.
//!
//! Nodes live in a type-stable pool ([`pool`]) and are recycled, never
//! freed to the OS while the queue lives, so stale pointers always
//! reference a valid `Node` — the property §3.2.1 relies on.
//!
//! On top of the paper's algorithms sits a **batch/amortization layer**
//! (DESIGN.md §7): [`CmpQueue::push_batch`] claims K contiguous cycles
//! with one RMW and publishes a pre-linked K-node chain with one tail
//! CAS; [`CmpQueue::pop_batch_into`] claims a run of consecutive nodes
//! and pays the scan-cursor and `deque_cycle` RMWs once per run; and
//! the pool keeps per-thread node *magazines* so the global freelist
//! CAS is paid once per refill/flush chunk instead of once per
//! operation. None of this relaxes strict FIFO — a batch occupies
//! consecutive FIFO positions by construction.

//! A third layer is the **async bridge** (DESIGN.md §10):
//! [`CmpQueue::pop_async`], [`CmpQueue::pop_async_batch`] and
//! [`CmpQueue::pop_deadline_async`] resolve through push-side waker
//! wakeups on the §8 eventcount — no parked thread per consumer, no
//! executor dependency, and the enqueue fast path still pays one fence
//! plus one relaxed load when nobody waits.

mod config;
mod futures;
mod node;
mod pool;
mod queue;
mod reclaim;
mod stats;

pub use config::{CmpConfig, ReclaimTrigger};
pub use futures::{PopBatchFuture, PopDeadlineFuture, PopFuture};
pub use node::{NodeState, DUMMY_CYCLE};
pub use queue::CmpQueue;
pub use stats::CmpStatsSnapshot;

// Exported only for the model-checking harness (tests/model_wait.rs
// drives the pool's tagged freelist directly). Not part of the stable
// API: `NodePool::free`/`free_chain` trust caller-supplied raw
// pointers (safe-fn UB if misused), which is fine for the reclaimer
// and the checker but must not be a generally public surface.
#[cfg(feature = "model-check")]
#[doc(hidden)]
pub use node::Node;
#[cfg(feature = "model-check")]
#[doc(hidden)]
pub use pool::NodePool;
