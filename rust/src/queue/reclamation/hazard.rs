//! Hazard-pointer reclamation domain (Michael 2004) — the coordination
//! scheme behind the paper's "Boost" comparator (§2.2, §4).
//!
//! Threads publish the pointers they are about to dereference into
//! shared per-thread slots; before freeing a retired object, the
//! reclaimer scans *all* slots of *all* registered threads
//! (`O(P × K)` comparisons — the coordination cost the paper measures
//! against). A slot that is never cleared (stalled/crashed thread)
//! blocks reclamation of whatever it protects forever — the fragility
//! the FAULT experiment demonstrates.

use std::cell::RefCell;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Hazard slots per thread. The M&S queue needs 2 (head/next).
pub const SLOTS_PER_THREAD: usize = 2;
/// Maximum registered threads per domain.
pub const MAX_THREADS: usize = 512;
/// Retired-list length that triggers a scan pass.
pub const SCAN_THRESHOLD: usize = 64;

/// A retired allocation awaiting a safe free.
struct Retired {
    ptr: *mut u8,
    drop_fn: unsafe fn(*mut u8),
}

unsafe impl Send for Retired {}

/// One thread's published hazard slots.
struct Record {
    active: AtomicBool,
    slots: [AtomicPtr<u8>; SLOTS_PER_THREAD],
}

impl Record {
    fn new() -> Self {
        Record {
            active: AtomicBool::new(false),
            slots: std::array::from_fn(|_| AtomicPtr::new(std::ptr::null_mut())),
        }
    }
}

/// Shared domain state.
pub struct DomainInner {
    records: Box<[Record]>,
    /// High-water mark of ever-activated records (bounds scan range).
    high: AtomicUsize,
    /// Retired objects orphaned by exited threads (freed on domain drop
    /// or adopted by later scans).
    orphans: Mutex<Vec<Retired>>,
    /// Diagnostic: objects freed so far.
    freed: AtomicUsize,
    /// Diagnostic: currently retired-but-not-freed (approximate).
    pending: AtomicUsize,
}

/// A hazard-pointer domain. Clone-able handle (`Arc` inside).
#[derive(Clone)]
pub struct HazardDomain {
    inner: Arc<DomainInner>,
}

impl Default for HazardDomain {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    /// This thread's record registrations: (domain key, registration).
    /// Holding the `Arc` keeps key addresses stable and unique.
    static TLS: RefCell<Vec<(usize, ThreadReg)>> = const { RefCell::new(Vec::new()) };
}

/// A thread's registration in one domain.
struct ThreadReg {
    domain: Arc<DomainInner>,
    idx: usize,
    retired: Vec<Retired>,
}

impl Drop for ThreadReg {
    fn drop(&mut self) {
        // Release the record and orphan any still-retired objects so the
        // domain can free them later (thread exit must not leak).
        let rec = &self.domain.records[self.idx];
        for s in rec.slots.iter() {
            s.store(std::ptr::null_mut(), Ordering::Release);
        }
        rec.active.store(false, Ordering::Release);
        if !self.retired.is_empty() {
            let mut orphans = self.domain.orphans.lock().unwrap();
            orphans.extend(self.retired.drain(..));
        }
    }
}

impl HazardDomain {
    /// A fresh domain with all hazard records unclaimed.
    pub fn new() -> Self {
        let records: Vec<Record> = (0..MAX_THREADS).map(|_| Record::new()).collect();
        HazardDomain {
            inner: Arc::new(DomainInner {
                records: records.into_boxed_slice(),
                high: AtomicUsize::new(0),
                orphans: Mutex::new(Vec::new()),
                freed: AtomicUsize::new(0),
                pending: AtomicUsize::new(0),
            }),
        }
    }

    fn key(&self) -> usize {
        Arc::as_ptr(&self.inner) as usize
    }

    /// Run `f` with this thread's registration (registering on first
    /// use — the coordination setup cost hazard pointers impose).
    fn with_reg<R>(&self, f: impl FnOnce(&mut ThreadReg) -> R) -> R {
        let key = self.key();
        TLS.with(|tls| {
            let mut tls = tls.borrow_mut();
            if let Some(pos) = tls.iter().position(|(k, _)| *k == key) {
                return f(&mut tls[pos].1);
            }
            let idx = self.acquire_record();
            tls.push((
                key,
                ThreadReg {
                    domain: self.inner.clone(),
                    idx,
                    retired: Vec::new(),
                },
            ));
            let last = tls.len() - 1;
            f(&mut tls[last].1)
        })
    }

    fn acquire_record(&self) -> usize {
        for i in 0..MAX_THREADS {
            let rec = &self.inner.records[i];
            if !rec.active.load(Ordering::Acquire)
                && rec
                    .active
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                self.inner.high.fetch_max(i + 1, Ordering::AcqRel);
                return i;
            }
        }
        panic!("hazard domain: more than {MAX_THREADS} concurrent threads");
    }

    /// Publish `src`'s current value in hazard slot `slot` and return it
    /// once the publication is validated (the classic load/publish/
    /// revalidate loop — *reactive* protection, §3.1).
    pub fn protect<T>(&self, slot: usize, src: &AtomicPtr<T>) -> *mut T {
        debug_assert!(slot < SLOTS_PER_THREAD);
        self.with_reg(|reg| {
            let rec = &reg.domain.records[reg.idx];
            let mut p = src.load(Ordering::Acquire);
            loop {
                rec.slots[slot].store(p as *mut u8, Ordering::Release);
                // Full fence semantics come from the SeqCst pair below in
                // scan(); on x86 the store above is already visible.
                std::sync::atomic::fence(Ordering::SeqCst);
                let q = src.load(Ordering::Acquire);
                if q == p {
                    return p;
                }
                p = q;
            }
        })
    }

    /// Clear one hazard slot.
    pub fn clear(&self, slot: usize) {
        self.with_reg(|reg| {
            reg.domain.records[reg.idx].slots[slot]
                .store(std::ptr::null_mut(), Ordering::Release);
        });
    }

    /// Clear all of this thread's slots.
    pub fn clear_all(&self) {
        self.with_reg(|reg| {
            for s in reg.domain.records[reg.idx].slots.iter() {
                s.store(std::ptr::null_mut(), Ordering::Release);
            }
        });
    }

    /// Retire an allocation; it is freed by a later scan once no hazard
    /// slot references it.
    ///
    /// # Safety
    /// `ptr` must be a valid allocation matching `drop_fn`, and must be
    /// unreachable to new readers (already unlinked).
    pub unsafe fn retire<T>(&self, ptr: *mut T, drop_fn: unsafe fn(*mut u8)) {
        self.inner.pending.fetch_add(1, Ordering::Relaxed);
        let should_scan = self.with_reg(|reg| {
            reg.retired.push(Retired {
                ptr: ptr as *mut u8,
                drop_fn,
            });
            reg.retired.len() >= SCAN_THRESHOLD
        });
        if should_scan {
            self.scan();
        }
    }

    /// Scan pass: gather all published hazards (O(P × K)), free every
    /// retired object not in the set.
    pub fn scan(&self) {
        std::sync::atomic::fence(Ordering::SeqCst);
        let high = self.inner.high.load(Ordering::Acquire);
        let mut hazards: HashSet<usize> = HashSet::with_capacity(high * SLOTS_PER_THREAD);
        for rec in self.inner.records[..high].iter() {
            // Scan even inactive records: a slot may be mid-release.
            for s in rec.slots.iter() {
                let p = s.load(Ordering::Acquire) as usize;
                if p != 0 {
                    hazards.insert(p);
                }
            }
        }
        // Adopt orphans from exited threads.
        let mut adopted: Vec<Retired> = {
            let mut o = self.inner.orphans.lock().unwrap();
            std::mem::take(&mut *o)
        };
        self.with_reg(|reg| {
            adopted.extend(reg.retired.drain(..));
            let mut kept = Vec::new();
            for r in adopted.drain(..) {
                if hazards.contains(&(r.ptr as usize)) {
                    kept.push(r);
                } else {
                    unsafe { (r.drop_fn)(r.ptr) };
                    self.inner.freed.fetch_add(1, Ordering::Relaxed);
                    self.inner.pending.fetch_sub(1, Ordering::Relaxed);
                }
            }
            reg.retired.extend(kept);
        });
    }

    /// Approximate count of retired-but-unfreed objects (FAULT metric).
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::Relaxed)
    }

    /// Objects freed so far.
    pub fn freed(&self) -> usize {
        self.inner.freed.load(Ordering::Relaxed)
    }
}

impl Drop for DomainInner {
    fn drop(&mut self) {
        // Last reference: no thread can touch protected objects anymore;
        // free all orphans.
        for r in self.orphans.lock().unwrap().drain(..) {
            unsafe { (r.drop_fn)(r.ptr) };
        }
    }
}

/// Typed drop shim for retiring `Box<T>` allocations.
///
/// # Safety
/// `p` must have come from `Box::<T>::into_raw`.
pub unsafe fn drop_box<T>(p: *mut u8) {
    drop(Box::from_raw(p as *mut T));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protect_returns_current_value() {
        let d = HazardDomain::new();
        let target = AtomicPtr::new(Box::into_raw(Box::new(42u32)));
        let p = d.protect(0, &target);
        assert_eq!(unsafe { *p }, 42);
        d.clear(0);
        unsafe { drop(Box::from_raw(target.load(Ordering::Relaxed))) };
    }

    #[test]
    fn protected_object_survives_scan() {
        let d = HazardDomain::new();
        let obj = Box::into_raw(Box::new(7u64));
        let slot = AtomicPtr::new(obj);
        let p = d.protect(0, &slot);
        assert_eq!(p, obj);
        unsafe { d.retire(obj, drop_box::<u64>) };
        d.scan();
        assert_eq!(d.freed(), 0, "hazard-protected object must not be freed");
        assert_eq!(d.pending(), 1);
        // Release protection → next scan frees it.
        d.clear(0);
        d.scan();
        assert_eq!(d.freed(), 1);
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn unprotected_objects_are_freed_on_scan() {
        let d = HazardDomain::new();
        for _ in 0..10 {
            let obj = Box::into_raw(Box::new(1u32));
            unsafe { d.retire(obj, drop_box::<u32>) };
        }
        d.scan();
        assert_eq!(d.freed(), 10);
    }

    #[test]
    fn threshold_triggers_automatic_scan() {
        let d = HazardDomain::new();
        for _ in 0..SCAN_THRESHOLD {
            let obj = Box::into_raw(Box::new(0u8));
            unsafe { d.retire(obj, drop_box::<u8>) };
        }
        assert!(d.freed() > 0, "threshold retire should have scanned");
    }

    #[test]
    fn thread_exit_orphans_are_recovered() {
        let d = HazardDomain::new();
        let d2 = d.clone();
        std::thread::spawn(move || {
            // Retire a handful below the scan threshold, then exit.
            for _ in 0..5 {
                let obj = Box::into_raw(Box::new(0u64));
                unsafe { d2.retire(obj, drop_box::<u64>) };
            }
        })
        .join()
        .unwrap();
        assert_eq!(d.pending(), 5);
        d.scan(); // adopting scan frees the orphans
        assert_eq!(d.freed(), 5);
    }

    #[test]
    fn stalled_hazard_blocks_reclamation_indefinitely() {
        // The §2.3.1 fragility: a slot that is never cleared pins its
        // object through any number of scans.
        let d = HazardDomain::new();
        let obj = Box::into_raw(Box::new(3u32));
        let slot = AtomicPtr::new(obj);
        let _ = d.protect(0, &slot); // never cleared — "stalled thread"
        unsafe { d.retire(obj, drop_box::<u32>) };
        for _ in 0..100 {
            d.scan();
        }
        assert_eq!(d.freed(), 0);
        assert_eq!(d.pending(), 1, "pinned forever");
        d.clear_all();
        d.scan();
        assert_eq!(d.freed(), 1);
    }

    #[test]
    fn multithreaded_protect_retire_is_safe() {
        let d = HazardDomain::new();
        let shared = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(0u64))));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let d = d.clone();
                let shared = shared.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let p = d.protect(0, &shared);
                        // Read through the protected pointer.
                        let _v = unsafe { *p };
                        // Occasionally swap in a new object and retire
                        // the old one.
                        if i % 7 == t {
                            let fresh = Box::into_raw(Box::new(i));
                            let old = shared.swap(fresh, Ordering::AcqRel);
                            unsafe { d.retire(old, drop_box::<u64>) };
                        }
                        d.clear(0);
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        d.scan();
        // Final object still installed; free it manually.
        unsafe { drop(Box::from_raw(shared.load(Ordering::Relaxed))) };
    }
}
