//! Coordination-*ful* memory reclamation substrates (§2.2) — the
//! schemes CMP is evaluated against. Built from scratch (no external
//! comparator libraries are usable offline):
//!
//! * [`hazard`] — Michael's hazard pointers (2004): per-thread published
//!   pointer slots, `O(P × K)` scans before any free.
//! * [`ebr`] — epoch-based reclamation: global epoch, per-thread pinned
//!   epochs, frees lag two epochs; a stalled pinned thread blocks
//!   reclamation (the fragility §2.3.1 describes — demonstrated by the
//!   FAULT experiment).

pub mod ebr;
pub mod hazard;
