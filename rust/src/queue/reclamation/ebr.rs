//! Epoch-based reclamation (EBR) domain (§2.2).
//!
//! A global epoch advances only when every *pinned* thread has observed
//! it; retired objects are freed two epochs later. Coordination is
//! amortized to O(P) per advance attempt, but reclamation progress
//! depends on the slowest pinned thread — a stalled participant blocks
//! frees forever ("unbounded retention", §2.2), which the FAULT
//! experiment demonstrates against CMP's bounded window.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Maximum registered threads per domain.
pub const MAX_THREADS: usize = 512;
/// Retired-list length per thread that triggers an advance attempt.
pub const ADVANCE_THRESHOLD: usize = 64;
/// Sentinel: thread not currently pinned.
const QUIESCENT: u64 = u64::MAX;

struct Retired {
    ptr: *mut u8,
    drop_fn: unsafe fn(*mut u8),
    epoch: u64,
}

unsafe impl Send for Retired {}

struct Record {
    active: AtomicBool,
    /// Epoch this thread is pinned at, or [`QUIESCENT`].
    epoch: AtomicU64,
}

/// Shared state behind an [`EbrDomain`] handle (thread records, global
/// epoch, orphaned retirees).
pub struct DomainInner {
    records: Box<[Record]>,
    high: AtomicUsize,
    global_epoch: AtomicU64,
    orphans: Mutex<Vec<Retired>>,
    freed: AtomicUsize,
    pending: AtomicUsize,
}

/// An EBR domain handle (`Arc` inside; clone freely).
#[derive(Clone)]
pub struct EbrDomain {
    inner: Arc<DomainInner>,
}

impl Default for EbrDomain {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static TLS: RefCell<Vec<(usize, ThreadReg)>> = const { RefCell::new(Vec::new()) };
}

struct ThreadReg {
    domain: Arc<DomainInner>,
    idx: usize,
    retired: Vec<Retired>,
    /// Pin nesting depth (guards may nest).
    depth: usize,
}

impl Drop for ThreadReg {
    fn drop(&mut self) {
        let rec = &self.domain.records[self.idx];
        rec.epoch.store(QUIESCENT, Ordering::Release);
        rec.active.store(false, Ordering::Release);
        if !self.retired.is_empty() {
            self.domain
                .orphans
                .lock()
                .unwrap()
                .extend(self.retired.drain(..));
        }
    }
}

/// RAII pin guard: the thread participates in the epoch protocol while
/// this is alive. Dropping unpins.
pub struct EbrGuard {
    domain: EbrDomain,
}

impl Drop for EbrGuard {
    fn drop(&mut self) {
        self.domain.unpin();
    }
}

impl EbrDomain {
    /// A fresh domain with no registered threads.
    pub fn new() -> Self {
        let records: Vec<Record> = (0..MAX_THREADS)
            .map(|_| Record {
                active: AtomicBool::new(false),
                epoch: AtomicU64::new(QUIESCENT),
            })
            .collect();
        EbrDomain {
            inner: Arc::new(DomainInner {
                records: records.into_boxed_slice(),
                high: AtomicUsize::new(0),
                global_epoch: AtomicU64::new(2), // frees need epoch ≥ 2 lag
                orphans: Mutex::new(Vec::new()),
                freed: AtomicUsize::new(0),
                pending: AtomicUsize::new(0),
            }),
        }
    }

    fn key(&self) -> usize {
        Arc::as_ptr(&self.inner) as usize
    }

    fn with_reg<R>(&self, f: impl FnOnce(&mut ThreadReg) -> R) -> R {
        let key = self.key();
        TLS.with(|tls| {
            let mut tls = tls.borrow_mut();
            if let Some(pos) = tls.iter().position(|(k, _)| *k == key) {
                return f(&mut tls[pos].1);
            }
            let idx = self.acquire_record();
            tls.push((
                key,
                ThreadReg {
                    domain: self.inner.clone(),
                    idx,
                    retired: Vec::new(),
                    depth: 0,
                },
            ));
            let last = tls.len() - 1;
            f(&mut tls[last].1)
        })
    }

    fn acquire_record(&self) -> usize {
        for i in 0..MAX_THREADS {
            let rec = &self.inner.records[i];
            if !rec.active.load(Ordering::Acquire)
                && rec
                    .active
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                self.inner.high.fetch_max(i + 1, Ordering::AcqRel);
                return i;
            }
        }
        panic!("ebr domain: more than {MAX_THREADS} concurrent threads");
    }

    /// Pin this thread at the current global epoch. Objects retired by
    /// other threads at this epoch or later cannot be freed while the
    /// guard lives.
    pub fn pin(&self) -> EbrGuard {
        self.with_reg(|reg| {
            if reg.depth == 0 {
                let g = reg.domain.global_epoch.load(Ordering::Acquire);
                reg.domain.records[reg.idx].epoch.store(g, Ordering::Release);
                std::sync::atomic::fence(Ordering::SeqCst);
            }
            reg.depth += 1;
        });
        EbrGuard {
            domain: self.clone(),
        }
    }

    fn unpin(&self) {
        self.with_reg(|reg| {
            reg.depth -= 1;
            if reg.depth == 0 {
                reg.domain.records[reg.idx]
                    .epoch
                    .store(QUIESCENT, Ordering::Release);
            }
        });
    }

    /// Retire an allocation at the current epoch (caller should be
    /// pinned). Freed once the global epoch has advanced ≥ 2 past it.
    ///
    /// # Safety
    /// `ptr` must be a valid allocation matching `drop_fn`, already
    /// unlinked from shared structures.
    pub unsafe fn retire<T>(&self, ptr: *mut T, drop_fn: unsafe fn(*mut u8)) {
        self.inner.pending.fetch_add(1, Ordering::Relaxed);
        let should_collect = self.with_reg(|reg| {
            let e = reg.domain.global_epoch.load(Ordering::Acquire);
            reg.retired.push(Retired {
                ptr: ptr as *mut u8,
                drop_fn,
                epoch: e,
            });
            reg.retired.len() >= ADVANCE_THRESHOLD
        });
        if should_collect {
            self.try_advance();
            self.collect();
        }
    }

    /// Attempt to advance the global epoch: succeeds only if every
    /// pinned thread has observed the current epoch — the all-threads-
    /// must-participate requirement that makes EBR fragile.
    pub fn try_advance(&self) -> bool {
        let g = self.inner.global_epoch.load(Ordering::Acquire);
        let high = self.inner.high.load(Ordering::Acquire);
        for rec in self.inner.records[..high].iter() {
            let e = rec.epoch.load(Ordering::Acquire);
            if e != QUIESCENT && e != g {
                return false; // a pinned thread lags — cannot advance
            }
        }
        self.inner
            .global_epoch
            .compare_exchange(g, g + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Free this thread's retired objects that are ≥ 2 epochs old, plus
    /// any orphans that qualify.
    pub fn collect(&self) {
        let g = self.inner.global_epoch.load(Ordering::Acquire);
        let safe = g.saturating_sub(2);
        let inner = self.inner.clone();
        self.with_reg(|reg| {
            let mut adopted: Vec<Retired> = {
                let mut o = inner.orphans.lock().unwrap();
                std::mem::take(&mut *o)
            };
            adopted.extend(reg.retired.drain(..));
            for r in adopted.drain(..) {
                if r.epoch <= safe {
                    unsafe { (r.drop_fn)(r.ptr) };
                    inner.freed.fetch_add(1, Ordering::Relaxed);
                    inner.pending.fetch_sub(1, Ordering::Relaxed);
                } else {
                    reg.retired.push(r);
                }
            }
        });
    }

    /// Retired-but-unfreed count (FAULT experiment metric).
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::Relaxed)
    }

    /// Objects actually freed so far (FAULT experiment metric).
    pub fn freed(&self) -> usize {
        self.inner.freed.load(Ordering::Relaxed)
    }

    /// Current global epoch (diagnostics).
    pub fn global_epoch(&self) -> u64 {
        self.inner.global_epoch.load(Ordering::Acquire)
    }
}

impl Drop for DomainInner {
    fn drop(&mut self) {
        for r in self.orphans.lock().unwrap().drain(..) {
            unsafe { (r.drop_fn)(r.ptr) };
        }
    }
}

/// Typed drop shim for `Box<T>` retirees.
///
/// # Safety
/// `p` must have come from `Box::<T>::into_raw`.
pub unsafe fn drop_box<T>(p: *mut u8) {
    drop(Box::from_raw(p as *mut T));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpinned_retires_free_after_advances() {
        let d = EbrDomain::new();
        {
            let _g = d.pin();
            let obj = Box::into_raw(Box::new(5u32));
            unsafe { d.retire(obj, drop_box::<u32>) };
        }
        // Advance twice, then collect.
        assert!(d.try_advance());
        assert!(d.try_advance());
        d.collect();
        assert_eq!(d.freed(), 1);
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn pinned_thread_blocks_epoch_advance() {
        let d = EbrDomain::new();
        let d2 = d.clone();
        let hold = Arc::new(AtomicBool::new(true));
        let h2 = hold.clone();
        let stalled = std::thread::spawn(move || {
            let _g = d2.pin(); // pin and stall
            while h2.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
        });
        // Give the stalled thread time to pin.
        while d.inner.high.load(Ordering::Acquire) == 0 {
            std::thread::yield_now();
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        let e0 = d.global_epoch();
        assert!(d.try_advance(), "first advance can still succeed");
        assert!(
            !d.try_advance(),
            "second advance must fail: stalled thread pinned at {e0}"
        );
        // Retired objects cannot be freed.
        let obj = Box::into_raw(Box::new(1u64));
        unsafe { d.retire(obj, drop_box::<u64>) };
        d.collect();
        assert_eq!(d.freed(), 0, "stall blocks reclamation (§2.3.1)");
        hold.store(false, Ordering::Release);
        stalled.join().unwrap();
        // Stall resolved → reclamation resumes.
        d.try_advance();
        d.try_advance();
        d.collect();
        assert_eq!(d.freed(), 1);
    }

    #[test]
    fn nested_pins_unpin_once() {
        let d = EbrDomain::new();
        let g1 = d.pin();
        let g2 = d.pin();
        drop(g1);
        // Still pinned: advance should stall after one bump.
        d.try_advance();
        assert!(!d.try_advance());
        drop(g2);
        assert!(d.try_advance());
    }

    #[test]
    fn thread_exit_orphans_recovered() {
        let d = EbrDomain::new();
        let d2 = d.clone();
        std::thread::spawn(move || {
            let _g = d2.pin();
            let obj = Box::into_raw(Box::new(0u8));
            unsafe { d2.retire(obj, drop_box::<u8>) };
        })
        .join()
        .unwrap();
        assert_eq!(d.pending(), 1);
        d.try_advance();
        d.try_advance();
        d.collect();
        assert_eq!(d.freed(), 1);
    }

    #[test]
    fn threshold_triggers_collection() {
        let d = EbrDomain::new();
        for _ in 0..(ADVANCE_THRESHOLD * 3) {
            let _g = d.pin();
            let obj = Box::into_raw(Box::new(0u32));
            unsafe { d.retire(obj, drop_box::<u32>) };
        }
        assert!(d.freed() > 0, "epochs advanced and frees happened");
    }
}
