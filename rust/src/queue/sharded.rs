//! Sharded CMP fabric: N independent [`CmpQueue`] shards behind one
//! [`ConcurrentQueue`] facade (DESIGN.md §13).
//!
//! A single CMP queue serializes every enqueue on one cycle-counter
//! RMW and every dequeue on one claim CAS; the fabric is the road past
//! that — to the "hundreds of threads" scale the paper claims —
//! at the price of a *relaxation knob* the caller chooses explicitly:
//!
//! - [`ShardMode::Strict`] routes **all** producers through one
//!   designated head shard (shard 0), whose enqueue cycle counter is
//!   the global ordering ticket. The facade stays a strict FIFO — and
//!   still pays exactly one globally contended RMW per push, which is
//!   why strict mode cannot scale producers past a single shard's
//!   ceiling. That RMW *is* the price of strictness; see DESIGN.md §13.
//! - [`ShardMode::Relaxed`] spreads producers round-robin over all
//!   shards via a producer ticket, so the contended RMW is split N
//!   ways. Order is relaxed: only per-shard FIFO holds. The
//!   `max_rank_error` bound is the declared quality target — batch
//!   chunking and the rotating dequeue sweep keep the *measured* p99
//!   rank error (see `bench::workload::rank_error_stats`) under it.
//!
//! # Consumer affinity and steal-on-empty
//!
//! Each consumer thread registers once per fabric (a registration
//! counter hands out affinity slots; slot `s` homes on shard
//! `s % N`, optionally pinning the thread to core `s` via
//! [`crate::util::cpu::pin_current_thread`]). A dequeue scans
//! `(home+k) % N` for `k = 0..N` — home first, then stealing from
//! victims in ring order. Blocking dequeues run a bounded number of
//! steal sweeps, then park on the **home shard's eventcount**.
//!
//! # Why a parked stealer never misses a cross-shard push
//!
//! Parking on the home shard's eventcount alone would lose wakeups:
//! a push to shard B notifies only shard B's eventcount, while the
//! stealer sleeps on shard A's. The facade closes the race with one
//! shared `parked` counter in the SC total order (the same 4-access
//! argument as `util/wait.rs`, with `parked` as the pivot):
//!
//! - consumer: register on home eventcount → `parked += 1` (SeqCst) →
//!   re-sweep every shard → sleep;
//! - producer: publish item → SC fence → load `parked` (SeqCst); if
//!   nonzero, notify **every** shard's eventcount.
//!
//! If the producer's load reads 0, the consumer's increment is later
//! in the SC order, so the consumer's re-sweep (program-order after
//! its increment) observes the published item and cancels the sleep.
//! If the load reads > 0, the notification bumps every eventcount
//! epoch *after* the consumer's registration snapshot, so the sleep
//! returns immediately or is woken. Either way: no lost wakeup, and
//! the producer fast path stays one fence + one load when nobody
//! parks. The whole protocol runs under the §9 model checker
//! (`tests/model_sharded.rs`).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

// The `parked` pivot is the one facade atomic with a protocol role
// (the lost-wakeup race above), so it routes through the model-check
// shims like the wait/claim layers do. The ticket and registration
// counters are plain std atomics: they only distribute indices, and
// keeping them off the shim keeps the model state space small.
use crate::model::shim::{fence, AtomicU64};

use super::cmp::{CmpConfig, CmpQueue};
use super::ConcurrentQueue;
use crate::util::{cpu, Backoff};

/// Ordering contract of a [`ShardedCmp`] fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Global strict FIFO: every producer routes through the head
    /// shard's ordering ticket (one contended RMW per push — the
    /// measurable price of strictness, DESIGN.md §13).
    Strict,
    /// Round-robin producers over all shards; only per-shard FIFO
    /// holds. `max_rank_error` is the declared p99 rank-error target
    /// the fabric's chunking and rotating sweep are tuned to hold
    /// (verified by `tests/sharded_fabric.rs`).
    Relaxed {
        /// Target bound on the p99 rank error (|dequeue position −
        /// enqueue ticket| under the charitable linearization).
        max_rank_error: u64,
    },
}

impl ShardMode {
    /// Whether this mode guarantees global FIFO order.
    pub fn is_strict(&self) -> bool {
        matches!(self, ShardMode::Strict)
    }

    /// The declared rank-error target; `None` in strict mode (where
    /// the rank error is exactly 0 by construction).
    pub fn max_rank_error(&self) -> Option<u64> {
        match self {
            ShardMode::Strict => None,
            ShardMode::Relaxed { max_rank_error } => Some(*max_rank_error),
        }
    }
}

/// Construction parameters for [`ShardedCmp`].
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    /// Number of CMP shards (clamped to ≥ 1).
    pub shards: usize,
    /// Ordering contract (see [`ShardMode`]).
    pub mode: ShardMode,
    /// Per-shard CMP configuration (window, reclamation trigger, …).
    pub shard_config: CmpConfig,
    /// Pin each registering consumer to core `slot % online_cpus()`
    /// (best-effort; Linux only). Off by default — CI runners and
    /// oversubscribed hosts are hurt, not helped, by pinning.
    pub pin_cores: bool,
    /// Extra full steal sweeps a blocking dequeue runs before parking.
    pub steal_sweeps: u32,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            shards: 4,
            mode: ShardMode::Strict,
            shard_config: CmpConfig::default(),
            pin_cores: false,
            steal_sweeps: 2,
        }
    }
}

impl ShardedConfig {
    /// Set the shard count.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Set the ordering mode.
    pub fn with_mode(mut self, mode: ShardMode) -> Self {
        self.mode = mode;
        self
    }

    /// Set the per-shard CMP configuration.
    pub fn with_shard_config(mut self, cfg: CmpConfig) -> Self {
        self.shard_config = cfg;
        self
    }

    /// Enable best-effort consumer→core pinning.
    pub fn with_pinning(mut self, pin: bool) -> Self {
        self.pin_cores = pin;
        self
    }

    /// Set the number of pre-park steal sweeps.
    pub fn with_steal_sweeps(mut self, sweeps: u32) -> Self {
        self.steal_sweeps = sweeps;
        self
    }

    /// Size each shard's protection window from an *observed* fabric
    /// dequeue rate: the per-shard rate is `ops_per_sec / shards`, and
    /// [`CmpConfig::window_for`] turns it into a window that survives
    /// `resilience_secs` of a stalled consumer (wCQ's motivation:
    /// shard windows must track diverging shard rates, not the
    /// aggregate). The bench measures a warmup rate and rebuilds the
    /// fabric through this.
    pub fn sized_for_rate(mut self, ops_per_sec: u64, resilience_secs: f64) -> Self {
        let per_shard = ops_per_sec / self.shards.max(1) as u64;
        let window = CmpConfig::window_for(per_shard, resilience_secs);
        self.shard_config = self.shard_config.with_window(window);
        self
    }
}

/// Per-thread affinity slot for one fabric (keyed by fabric id).
struct TlsSlot {
    facade: u64,
    slot: u64,
    /// Rotating sweep origin (relaxed mode): advanced past the last
    /// shard that yielded, so consumers collectively drain shards
    /// round-robin — the dequeue-side half of the rank-error bound.
    rot: u64,
}

thread_local! {
    /// Affinity registrations of this thread, most recent last. Capped
    /// so model-checker runs (thousands of short-lived fabrics on a
    /// few virtual threads) cannot grow it without bound; eviction
    /// merely re-registers on next use.
    static CONSUMER_TLS: RefCell<Vec<TlsSlot>> = const { RefCell::new(Vec::new()) };
}

/// Max fabrics tracked per thread before the oldest slot is evicted.
const TLS_FACADE_CAP: usize = 16;

/// Fabric identity source for the thread-local affinity table.
static FACADE_IDS: StdAtomicU64 = StdAtomicU64::new(1);

/// RAII decrement for the facade `parked` pivot: every exit from the
/// park window (item found, woken, deadline, unwind) must retract the
/// announcement or producers would pay the notify slow path forever.
struct ParkGuard<'a>(&'a AtomicU64);

impl Drop for ParkGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A facade over N [`CmpQueue`] shards with per-consumer affinity,
/// steal-on-empty, and a strict/relaxed ordering knob. See the module
/// docs for the protocol and DESIGN.md §13 for the argument.
///
/// ```
/// use cmpq::{ConcurrentQueue, ShardedCmp};
/// let q: ShardedCmp<u64> = ShardedCmp::new(4); // strict mode
/// q.enqueue(1);
/// q.enqueue(2);
/// assert_eq!(q.try_dequeue(), Some(1));
/// assert_eq!(q.try_dequeue(), Some(2));
/// assert_eq!(q.try_dequeue(), None);
/// ```
pub struct ShardedCmp<T: Send> {
    id: u64,
    shards: Vec<Arc<CmpQueue<T>>>,
    mode: ShardMode,
    pin_cores: bool,
    steal_sweeps: u32,
    /// Relaxed-mode producer round-robin ticket (one fetch_add per
    /// push/chunk, spread over N shard RMWs instead of one).
    ticket: StdAtomicU64,
    /// Consumer affinity registrations handed out so far.
    consumer_reg: StdAtomicU64,
    /// Parked-consumer pivot of the cross-shard wakeup protocol
    /// (module docs); shimmed so the model checker explores it.
    parked: AtomicU64,
}

impl<T: Send> ShardedCmp<T> {
    /// A strict-FIFO fabric with `shards` default-configured shards.
    pub fn new(shards: usize) -> Self {
        Self::with_config(ShardedConfig::default().with_shards(shards))
    }

    /// Build a fabric from a full [`ShardedConfig`].
    pub fn with_config(cfg: ShardedConfig) -> Self {
        let n = cfg.shards.max(1);
        let shards = (0..n)
            .map(|_| Arc::new(CmpQueue::with_config(cfg.shard_config.clone())))
            .collect();
        ShardedCmp {
            id: FACADE_IDS.fetch_add(1, Ordering::Relaxed),
            shards,
            mode: cfg.mode,
            pin_cores: cfg.pin_cores,
            steal_sweeps: cfg.steal_sweeps,
            ticket: StdAtomicU64::new(0),
            consumer_reg: StdAtomicU64::new(0),
            parked: AtomicU64::new(0),
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The ordering mode this fabric was built with.
    pub fn mode(&self) -> ShardMode {
        self.mode
    }

    /// Borrow shard `i` (telemetry, reclamation driving, tests).
    ///
    /// # Panics
    /// If `i >= shard_count()`.
    pub fn shard(&self, i: usize) -> &CmpQueue<T> {
        &self.shards[i]
    }

    /// Clone shard `i`'s handle (the router shares shards with its
    /// per-shard worker drains this way).
    ///
    /// # Panics
    /// If `i >= shard_count()`.
    pub fn shard_arc(&self, i: usize) -> Arc<CmpQueue<T>> {
        Arc::clone(&self.shards[i])
    }

    /// Consumer affinity slots handed out so far.
    pub fn registered_consumers(&self) -> u64 {
        self.consumer_reg.load(Ordering::Relaxed)
    }

    /// Consumers currently inside the park window (announced via the
    /// `parked` pivot; 0 once every blocking dequeue has returned).
    pub fn parked_consumers(&self) -> u64 {
        self.parked.load(Ordering::Relaxed)
    }

    /// Run this thread's affinity slot through `f`, registering (and
    /// optionally pinning) on first use per fabric.
    fn with_slot<R>(&self, f: impl FnOnce(&mut TlsSlot) -> R) -> R {
        CONSUMER_TLS.with(|cell| {
            let mut v = cell.borrow_mut();
            if let Some(pos) = v.iter().position(|s| s.facade == self.id) {
                return f(&mut v[pos]);
            }
            if v.len() >= TLS_FACADE_CAP {
                v.remove(0);
            }
            let slot = self.consumer_reg.fetch_add(1, Ordering::Relaxed);
            if self.pin_cores {
                let online = cpu::online_cpus();
                cpu::pin_current_thread(slot as usize % online.max(1));
            }
            let rot = slot % self.shards.len() as u64;
            v.push(TlsSlot {
                facade: self.id,
                slot,
                rot,
            });
            let last = v.len() - 1;
            f(&mut v[last])
        })
    }

    /// This thread's home shard (affinity slot mod N).
    fn home_shard(&self) -> usize {
        let n = self.shards.len();
        self.with_slot(|ts| ts.slot as usize % n)
    }

    /// Producer routing: strict → the head shard; relaxed → ticket
    /// round-robin.
    fn route_push(&self) -> usize {
        match self.mode {
            ShardMode::Strict => 0,
            ShardMode::Relaxed { .. } => {
                (self.ticket.fetch_add(1, Ordering::Relaxed) % self.shards.len() as u64) as usize
            }
        }
    }

    /// Producer half of the cross-shard wakeup protocol (module docs):
    /// SC fence, then the `parked` pivot load; only when a consumer is
    /// inside its park window does the push pay the per-shard notifies.
    fn notify_waiters(&self) {
        fence(Ordering::SeqCst);
        if self.parked.load(Ordering::SeqCst) == 0 {
            return;
        }
        for s in &self.shards {
            s.wake_consumers();
        }
    }

    /// Run the producer half of the cross-shard wakeup protocol after
    /// publishing *directly* into a shard obtained from
    /// [`ShardedCmp::shard`] / [`ShardedCmp::shard_arc`] (the
    /// coordinator router does this). A raw `CmpQueue::push` only
    /// notifies that shard's own eventcount; a fabric consumer parked
    /// on a *different* home shard would sleep through it. This is the
    /// conditional fence + `parked`-pivot check every fabric enqueue
    /// performs — free (one load) when nobody is parked.
    pub fn notify_stealers(&self) {
        self.notify_waiters();
    }

    /// One full `(start+k) % N` sweep; relaxed mode rotates the origin
    /// past the yielding shard so successive pops drain shards
    /// round-robin (matching the producer round-robin is what keeps
    /// the rank error near N, not near the queue length).
    fn pop_once(&self) -> Option<T> {
        let n = self.shards.len();
        let strict = self.mode.is_strict();
        self.with_slot(|ts| {
            let start = if strict {
                ts.slot as usize % n
            } else {
                ts.rot as usize % n
            };
            for k in 0..n {
                let i = (start + k) % n;
                if let Some(v) = self.shards[i].pop() {
                    if !strict {
                        ts.rot = ((i + 1) % n) as u64;
                    }
                    return Some(v);
                }
            }
            None
        })
    }

    /// Relaxed-mode cap on contiguous same-shard transfers: both the
    /// enqueue chunking and the per-shard batch take are held to
    /// `max_rank_error / N`, so a batch contributes at most
    /// ~`max_rank_error` of ticket spread.
    fn per_shard_chunk(&self, max: usize) -> usize {
        match self.mode {
            ShardMode::Strict => max,
            ShardMode::Relaxed { max_rank_error } => {
                let c = (max_rank_error / self.shards.len() as u64).clamp(1, 4096) as usize;
                c.min(max.max(1))
            }
        }
    }

    /// One batch sweep: visit shards from the origin, taking up to the
    /// relaxed chunk cap from each, until `max` items or a full lap.
    fn pop_batch_once(&self, max: usize, out: &mut Vec<T>) -> usize {
        if max == 0 {
            return 0;
        }
        let n = self.shards.len();
        let strict = self.mode.is_strict();
        let cap = self.per_shard_chunk(max);
        self.with_slot(|ts| {
            let start = if strict {
                ts.slot as usize % n
            } else {
                ts.rot as usize % n
            };
            let mut got = 0;
            for k in 0..n {
                if got >= max {
                    break;
                }
                let i = (start + k) % n;
                let want = (max - got).min(cap);
                let took = self.shards[i].pop_batch_into(want, out);
                if took > 0 && !strict {
                    ts.rot = ((i + 1) % n) as u64;
                }
                got += took;
            }
            got
        })
    }

    /// Blocking dequeue core: bounded steal sweeps, spin/yield
    /// escalation, then the park window (consumer half of the
    /// cross-shard wakeup protocol — register on the home shard's
    /// eventcount, announce on the `parked` pivot, re-sweep, sleep).
    fn pop_wait(&self, deadline: Option<Instant>) -> Option<T> {
        let mut backoff = Backoff::new();
        loop {
            for _ in 0..=self.steal_sweeps {
                if let Some(v) = self.pop_once() {
                    return Some(v);
                }
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return self.pop_once();
                }
            }
            // The spin phase is perf-only; under the model checker it
            // would just multiply schedules, so it is skipped there
            // (same convention as CmpQueue::park_wait).
            if !crate::model::shims_active() && !backoff.is_yielding() {
                backoff.spin();
                continue;
            }
            let home = self.home_shard();
            let reg = self.shards[home].wait_strategy().registration();
            self.parked.fetch_add(1, Ordering::SeqCst);
            let _parked = ParkGuard(&self.parked);
            if let Some(v) = self.pop_once() {
                return Some(v);
            }
            match deadline {
                Some(d) => {
                    reg.wait_deadline(d);
                }
                None => reg.wait(),
            }
        }
    }

    /// Batch variant of [`ShardedCmp::pop_wait`]: returns on the first
    /// sweep that claims anything (≥ 1 unless the deadline passes).
    fn pop_wait_batch(&self, max: usize, out: &mut Vec<T>, deadline: Option<Instant>) -> usize {
        if max == 0 {
            return 0;
        }
        let mut backoff = Backoff::new();
        loop {
            for _ in 0..=self.steal_sweeps {
                let got = self.pop_batch_once(max, out);
                if got > 0 {
                    return got;
                }
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return self.pop_batch_once(max, out);
                }
            }
            if !crate::model::shims_active() && !backoff.is_yielding() {
                backoff.spin();
                continue;
            }
            let home = self.home_shard();
            let reg = self.shards[home].wait_strategy().registration();
            self.parked.fetch_add(1, Ordering::SeqCst);
            let _parked = ParkGuard(&self.parked);
            let got = self.pop_batch_once(max, out);
            if got > 0 {
                return got;
            }
            match deadline {
                Some(d) => {
                    reg.wait_deadline(d);
                }
                None => reg.wait(),
            }
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for ShardedCmp<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        let r = self.shards[self.route_push()].push(item);
        if r.is_ok() {
            self.notify_waiters();
        }
        r
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop_once()
    }

    fn try_enqueue_batch(&self, mut items: Vec<T>) -> Result<(), Vec<T>> {
        if items.is_empty() {
            return Ok(());
        }
        let r = match self.mode {
            // Strict: the head shard's native all-or-nothing batch
            // insert (one amortized ticket RMW for the whole chain).
            ShardMode::Strict => self.shards[0].push_batch(items),
            // Relaxed: split into rank-bounded chunks, one routing
            // ticket per chunk.
            ShardMode::Relaxed { .. } => {
                let chunk = self.per_shard_chunk(usize::MAX);
                loop {
                    let rest = if items.len() > chunk {
                        items.split_off(chunk)
                    } else {
                        Vec::new()
                    };
                    match self.shards[self.route_push()].push_batch(items) {
                        Ok(()) => {
                            if rest.is_empty() {
                                break Ok(());
                            }
                            items = rest;
                            // Accepted chunks are visible now; wake
                            // stealers before working on the rest.
                            self.notify_waiters();
                        }
                        Err(mut rejected) => {
                            rejected.extend(rest);
                            break Err(rejected);
                        }
                    }
                }
            }
        };
        if r.is_ok() {
            self.notify_waiters();
        }
        r
    }

    fn try_dequeue_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        self.pop_batch_once(max, out)
    }

    fn pop_blocking(&self) -> T {
        self.pop_wait(None)
            .expect("pop_wait without a deadline cannot time out")
    }

    fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        self.pop_wait(Some(deadline))
    }

    fn pop_blocking_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        self.pop_wait_batch(max, out, None)
    }

    fn pop_deadline_batch(&self, max: usize, out: &mut Vec<T>, deadline: Instant) -> usize {
        self.pop_wait_batch(max, out, Some(deadline))
    }

    fn wake_all(&self) {
        for s in &self.shards {
            s.wake_consumers();
        }
    }

    fn name(&self) -> &'static str {
        "sharded"
    }

    fn is_strict_fifo(&self) -> bool {
        self.mode.is_strict()
    }

    fn is_lock_free(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestAtomicU64;
    use std::time::Duration;

    #[test]
    fn strict_roundtrip_exact_order() {
        let q: ShardedCmp<u64> = ShardedCmp::new(4);
        assert!(q.is_strict_fifo());
        assert_eq!(q.mode().max_rank_error(), None);
        for i in 0..64 {
            q.enqueue(i);
        }
        for i in 0..64 {
            assert_eq!(q.try_dequeue(), Some(i));
        }
        assert_eq!(q.try_dequeue(), None);
    }

    #[test]
    fn relaxed_single_thread_rank_error_is_tiny() {
        let cfg = ShardedConfig::default()
            .with_shards(4)
            .with_mode(ShardMode::Relaxed {
                max_rank_error: 4096,
            });
        let q: ShardedCmp<u64> = ShardedCmp::with_config(cfg);
        assert!(!q.is_strict_fifo());
        for i in 0..100u64 {
            q.enqueue(i);
        }
        let mut popped = Vec::new();
        while let Some(v) = q.try_dequeue() {
            popped.push(v);
        }
        assert_eq!(popped.len(), 100);
        // Producer round-robin + rotating sweep: single-threaded, the
        // merge is off by at most one lap of the shard ring.
        for (pos, v) in popped.iter().enumerate() {
            let err = (pos as i64 - *v as i64).unsigned_abs();
            assert!(err <= 4, "rank error {err} at position {pos}");
        }
        let mut sorted = popped;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn relaxed_batches_are_chunked_and_conserved() {
        let cfg = ShardedConfig::default()
            .with_shards(4)
            .with_mode(ShardMode::Relaxed { max_rank_error: 8 });
        let q: ShardedCmp<u64> = ShardedCmp::with_config(cfg);
        // chunk = max_rank_error / shards = 2: a 20-item batch must
        // spread over all four shards.
        q.try_enqueue_batch((0..20).collect()).unwrap();
        let nonempty = (0..4).filter(|&i| q.shard(i).pop().is_some()).count();
        assert_eq!(nonempty, 4, "batch was not spread across shards");
        // Drain the rest through the facade; conservation must hold.
        let mut out = Vec::new();
        while q.try_dequeue_batch(64, &mut out) > 0 {}
        assert_eq!(out.len(), 16); // 20 minus the 4 probed off above
    }

    #[test]
    fn affinity_slots_register_per_thread() {
        let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::new(2));
        assert_eq!(q.registered_consumers(), 0);
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let _ = q.try_dequeue();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.registered_consumers(), 3);
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn blocking_pop_wakes_across_shards() {
        // Strict fabric, 2 shards: the consumer thread registers a
        // non-zero home slot, so its parking shard is *not* the head
        // shard the item lands on — delivery proves the cross-shard
        // wakeup protocol.
        let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::new(2));
        let _ = q.try_dequeue(); // main thread takes slot 0 (home 0)
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop_blocking()) // slot 1 → home 1
        };
        std::thread::sleep(Duration::from_millis(20));
        q.enqueue(99); // strict: lands on shard 0
        assert_eq!(consumer.join().unwrap(), 99);
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn pop_deadline_times_out_empty() {
        let q: ShardedCmp<u64> = ShardedCmp::new(2);
        let t0 = Instant::now();
        assert_eq!(q.pop_deadline(t0 + Duration::from_millis(15)), None);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn steal_storm_conserves_items() {
        let cfg = ShardedConfig::default()
            .with_shards(4)
            .with_mode(ShardMode::Relaxed {
                max_rank_error: 4096,
            });
        let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::with_config(cfg));
        let total = 20_000u64;
        let popped = Arc::new(TestAtomicU64::new(0));
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..total / 2 {
                        q.enqueue(p * (total / 2) + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                let popped = Arc::clone(&popped);
                std::thread::spawn(move || loop {
                    match q.pop_deadline(Instant::now() + Duration::from_millis(50)) {
                        Some(_) => {
                            popped.fetch_add(1, Ordering::Relaxed);
                        }
                        None => return,
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(popped.load(Ordering::Relaxed), total);
        assert_eq!(q.parked_consumers(), 0);
    }

    #[test]
    fn sized_for_rate_uses_per_shard_rate() {
        let cfg = ShardedConfig::default()
            .with_shards(8)
            .sized_for_rate(8_000_000, 0.5);
        // 1M ops/s per shard × 0.5 s resilience = 500k window.
        assert_eq!(cfg.shard_config.window, 500_000);
        let q: ShardedCmp<u64> = ShardedCmp::with_config(cfg);
        assert_eq!(q.shard(0).config().window, 500_000);
        assert_eq!(q.shard_count(), 8);
    }

    #[test]
    fn wake_all_is_a_wake_not_a_cancel() {
        let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::new(2));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop_deadline(Instant::now() + Duration::from_secs(5)))
        };
        std::thread::sleep(Duration::from_millis(20));
        q.wake_all(); // woken consumer finds nothing and re-parks
        std::thread::sleep(Duration::from_millis(20));
        q.enqueue(7);
        assert_eq!(consumer.join().unwrap(), Some(7));
    }
}
