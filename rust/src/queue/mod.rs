//! Concurrent MPMC queue implementations: the paper's CMP queue plus
//! every comparator its evaluation uses or its related-work section
//! discusses, behind one [`ConcurrentQueue`] trait so the benchmark
//! harness can sweep them uniformly.

pub mod baselines;
pub mod cmp;
pub mod reclamation;
pub mod sharded;

use std::future::Future;
use std::marker::PhantomData;
use std::pin::Pin;
use std::sync::Arc;
use std::task::{Context, Poll};
use std::time::{Duration, Instant};

use crate::util::executor::wake_at;
use crate::util::Backoff;

/// Boxed future returned by the [`ConcurrentQueue`] async dequeues.
/// Boxing keeps the trait object-safe (the async paths work through
/// `Arc<dyn ConcurrentQueue<T>>`, exactly like the benches use it) at
/// the cost of one allocation per call — on the empty-queue slow path
/// by construction, never per item of a resolved batch.
pub type BoxFuture<'a, R> = Pin<Box<dyn Future<Output = R> + Send + 'a>>;

/// Default async dequeue: poll-and-reschedule. Each `poll` tries one
/// `try_dequeue`; on empty it immediately wakes itself, so the hosting
/// executor keeps it fair but busy (see
/// [`ConcurrentQueue::pop_async`] for the CPU caveat).
struct PollPop<'a, Q: ?Sized, T> {
    queue: &'a Q,
    _item: PhantomData<fn() -> T>,
}

impl<T: Send, Q: ConcurrentQueue<T> + ?Sized> Future for PollPop<'_, Q, T> {
    type Output = T;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        match self.queue.try_dequeue() {
            Some(v) => Poll::Ready(v),
            None => {
                cx.waker().wake_by_ref();
                Poll::Pending
            }
        }
    }
}

/// Deadline variant of [`PollPop`].
struct PollPopDeadline<'a, Q: ?Sized, T> {
    queue: &'a Q,
    deadline: Instant,
    _item: PhantomData<fn() -> T>,
}

impl<T: Send, Q: ConcurrentQueue<T> + ?Sized> Future for PollPopDeadline<'_, Q, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Option<T>> {
        if let Some(v) = self.queue.try_dequeue() {
            return Poll::Ready(Some(v));
        }
        if Instant::now() >= self.deadline {
            return Poll::Ready(None);
        }
        cx.waker().wake_by_ref();
        Poll::Pending
    }
}

/// Batch variant of [`PollPop`].
struct PollPopBatch<'a, Q: ?Sized, T> {
    queue: &'a Q,
    max: usize,
    _item: PhantomData<fn() -> T>,
}

impl<T: Send, Q: ConcurrentQueue<T> + ?Sized> Future for PollPopBatch<'_, Q, T> {
    type Output = Vec<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Vec<T>> {
        if self.max == 0 {
            return Poll::Ready(Vec::new());
        }
        let mut out = Vec::new();
        if self.queue.try_dequeue_batch(self.max, &mut out) > 0 {
            return Poll::Ready(out);
        }
        cx.waker().wake_by_ref();
        Poll::Pending
    }
}

/// Future behind the default [`ConcurrentQueue::push_async`]: try the
/// enqueue on every poll; while the queue stays full, re-arm the
/// shared timer with the same bounded exponential backoff the default
/// blocking dequeues use (50 µs … 1 ms), so an awaiting producer never
/// busy-spins through its executor. The item rides inside the future
/// until accepted (dropping a pending future drops the item with it).
struct PollPush<'a, Q: ?Sized, T> {
    queue: &'a Q,
    item: Option<T>,
    sleep_us: u64,
}

// No field is structurally pinned (the item is moved out by value on
// the successful attempt), so the future is `Unpin` regardless of `T`.
impl<Q: ?Sized, T> Unpin for PollPush<'_, Q, T> {}

impl<T: Send, Q: ConcurrentQueue<T> + ?Sized> Future for PollPush<'_, Q, T> {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let this = &mut *self;
        let item = this.item.take().expect("push future polled after completion");
        match this.queue.try_enqueue(item) {
            Ok(()) => Poll::Ready(()),
            Err(item) => {
                this.item = Some(item);
                this.sleep_us = (this.sleep_us * 2).clamp(POLL_SLEEP_FLOOR_US, POLL_SLEEP_CAP_US);
                wake_at(
                    Instant::now() + Duration::from_micros(this.sleep_us),
                    cx.waker().clone(),
                );
                Poll::Pending
            }
        }
    }
}

/// Longest single sleep of the default (polling) blocking-dequeue
/// implementations: bounds both wake latency and idle CPU burn for
/// implementations without a native parking path.
const POLL_SLEEP_CAP_US: u64 = 1000;
/// Shortest sleep once the default blocking dequeues escalate past
/// spinning.
const POLL_SLEEP_FLOOR_US: u64 = 50;

/// Shared escalation loop of the default blocking dequeues: run
/// `attempt` until it yields a value, spinning → yielding → sleeping in
/// bounded exponential steps (50 µs … 1 ms), truncated to the remaining
/// time when a deadline is set. `None` means the deadline passed with
/// every attempt empty.
fn poll_escalate<R>(
    mut attempt: impl FnMut() -> Option<R>,
    deadline: Option<Instant>,
) -> Option<R> {
    let mut backoff = Backoff::new();
    let mut sleep_us = 0u64;
    loop {
        if let Some(r) = attempt() {
            return Some(r);
        }
        let mut sleep_cap = Duration::from_micros(POLL_SLEEP_CAP_US);
        if let Some(d) = deadline {
            let now = Instant::now();
            if now >= d {
                return None;
            }
            sleep_cap = sleep_cap.min(d - now);
        }
        if backoff.is_yielding() {
            sleep_us = (sleep_us * 2).clamp(POLL_SLEEP_FLOOR_US, POLL_SLEEP_CAP_US);
            std::thread::sleep(Duration::from_micros(sleep_us).min(sleep_cap));
        } else {
            backoff.spin();
        }
    }
}

/// Common interface over all queue implementations.
///
/// All methods take `&self`; implementations are internally synchronized
/// (lock-free except the explicitly blocking baselines).
pub trait ConcurrentQueue<T: Send>: Send + Sync {
    /// Attempt to enqueue. Bounded queues return `Err(item)` when full;
    /// unbounded queues only fail on allocation exhaustion (never in the
    /// default configurations).
    fn try_enqueue(&self, item: T) -> Result<(), T>;

    /// Attempt to dequeue. `None` means empty *at the linearization
    /// point* (or, for CMP past its protection window, a lost claim —
    /// see DESIGN.md §6).
    fn try_dequeue(&self) -> Option<T>;

    /// Enqueue, spinning with backoff until accepted.
    fn enqueue(&self, mut item: T) {
        let mut backoff = Backoff::new();
        loop {
            match self.try_enqueue(item) {
                Ok(()) => return,
                Err(it) => {
                    item = it;
                    backoff.spin();
                }
            }
        }
    }

    /// Attempt to enqueue a batch. The default is a best-effort prefix:
    /// items are enqueued one by one and `Err` returns the suffix that
    /// was *not* accepted (first element = the item that failed).
    /// Implementations with a native batch path (CMP) override this
    /// with an all-or-nothing amortized insert; either way `Ok(())`
    /// means every item was enqueued, in order.
    fn try_enqueue_batch(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        let mut it = items.into_iter();
        while let Some(item) = it.next() {
            if let Err(item) = self.try_enqueue(item) {
                let mut rest = Vec::with_capacity(it.len() + 1);
                rest.push(item);
                rest.extend(it);
                return Err(rest);
            }
        }
        Ok(())
    }

    /// Dequeue up to `max` items, appending to `out` in queue order;
    /// returns the number dequeued (0 = empty at the linearization
    /// point of the last probe). The default loops `try_dequeue`; CMP
    /// overrides it with a claimed-run dequeue that amortizes its
    /// global RMWs across the batch.
    fn try_dequeue_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        let mut n = 0;
        while n < max {
            match self.try_dequeue() {
                Some(v) => {
                    out.push(v);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Enqueue a whole batch, spinning with backoff until every item is
    /// accepted (mirrors [`ConcurrentQueue::enqueue`] for batches).
    ///
    /// When an attempt makes no progress at all (the implementation is
    /// all-or-nothing, like CMP, and the full batch can never fit a
    /// bounded pool at once), the batch is split in half so completion
    /// degrades gracefully to single-item `enqueue` semantics instead
    /// of retrying an unsatisfiable batch forever.
    fn enqueue_batch(&self, mut items: Vec<T>) {
        let mut backoff = Backoff::new();
        loop {
            let attempted = items.len();
            match self.try_enqueue_batch(items) {
                Ok(()) => return,
                Err(rest) => {
                    items = rest;
                    if items.len() == attempted && attempted > 1 {
                        // Zero progress: halve. The front half keeps
                        // FIFO order by completing before the back half
                        // is retried.
                        let back = items.split_off(attempted / 2);
                        self.enqueue_batch(items);
                        items = back;
                    }
                    backoff.spin();
                }
            }
        }
    }

    /// Enqueue asynchronously: the returned future resolves once the
    /// queue accepts the item — backpressure as suspension instead of
    /// an `Err(item)` to retry (the TCP ingress feeds its bounded
    /// accept handoff through this, so a full queue slows accepting
    /// rather than dropping connections — DESIGN.md §12).
    ///
    /// The first poll tries [`ConcurrentQueue::try_enqueue`] directly,
    /// so unbounded implementations (CMP in its default configuration)
    /// resolve immediately without suspending. Bounded or
    /// capacity-exhausted queues park the future and retry on
    /// shared-timer wakeups with bounded exponential backoff
    /// (50 µs … 1 ms — the dequeue-default escalation mirrored);
    /// implementations with a producer-side eventcount (Vyukov)
    /// override this so a pop of the full ring wakes the producer
    /// immediately instead. Cancellation is `Drop`: a pending future
    /// still owns its item and drops it along with itself.
    fn push_async(&self, item: T) -> BoxFuture<'_, ()> {
        Box::pin(PollPush {
            queue: self,
            item: Some(item),
            sleep_us: 0,
        })
    }

    /// Dequeue, blocking until an item is available.
    ///
    /// The default escalates spin → yield → bounded exponential sleep
    /// (50 µs … 1 ms), so an idle consumer costs well under 5% of a
    /// core at the price of up to ~1 ms wake latency. Implementations
    /// with a real parking path (CMP's epoch-guarded eventcount,
    /// [`crate::util::WaitStrategy`]) override this with a
    /// lost-wakeup-safe sleep that producers end immediately.
    fn pop_blocking(&self) -> T {
        poll_escalate(|| self.try_dequeue(), None)
            .expect("poll_escalate without a deadline cannot time out")
    }

    /// Dequeue, blocking until an item is available or `deadline`
    /// passes; `None` means the queue was empty through the deadline.
    ///
    /// Same default escalation (and the same parking override contract)
    /// as [`ConcurrentQueue::pop_blocking`]; sleeps are truncated to
    /// the remaining time so expiry is detected promptly.
    fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        poll_escalate(|| self.try_dequeue(), Some(deadline))
    }

    /// Batch variant of [`ConcurrentQueue::pop_blocking`]: block until
    /// at least one item is claimed, then claim up to `max`, appending
    /// to `out` in queue order. Returns the number claimed (≥ 1, except
    /// `max == 0`, which returns 0 immediately).
    fn pop_blocking_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        if max == 0 {
            return 0;
        }
        poll_escalate(
            || match self.try_dequeue_batch(max, out) {
                0 => None,
                n => Some(n),
            },
            None,
        )
        .expect("poll_escalate without a deadline cannot time out")
    }

    /// Batch variant of [`ConcurrentQueue::pop_deadline`]: claim up to
    /// `max` items (appending to `out`), blocking until at least one is
    /// available or `deadline` passes. Returns the number claimed
    /// (0 = empty through the deadline). `max == 0` returns 0 at once.
    fn pop_deadline_batch(&self, max: usize, out: &mut Vec<T>, deadline: Instant) -> usize {
        if max == 0 {
            return 0;
        }
        poll_escalate(
            || match self.try_dequeue_batch(max, out) {
                0 => None,
                n => Some(n),
            },
            Some(deadline),
        )
        .unwrap_or(0)
    }

    /// Dequeue asynchronously: the returned future resolves once an
    /// item is claimed. Executor-agnostic — the future communicates
    /// only through [`std::task::Waker`]s (drive it with
    /// [`crate::util::executor::block_on`], an [`crate::util::Executor`]
    /// task, or any runtime).
    ///
    /// The default is *polling-based* so all seven implementations
    /// stay comparable: every poll that finds the queue empty
    /// immediately re-schedules itself, which keeps the hosting
    /// executor fair but busy-polls through it (an idle default future
    /// costs CPU like a spinning consumer). [`cmp::CmpQueue`]
    /// overrides this with real push-side wakeups on its eventcount —
    /// a pending future costs nothing until a push lands
    /// (DESIGN.md §10). Like [`ConcurrentQueue::pop_blocking`], the
    /// only exit is a resolved item; dropping the future cancels
    /// cleanly for every implementation.
    fn pop_async(&self) -> BoxFuture<'_, T> {
        Box::pin(PollPop {
            queue: self,
            _item: PhantomData,
        })
    }

    /// Async [`ConcurrentQueue::pop_deadline`]: resolves to
    /// `Some(item)` on a claim, `None` once `deadline` passes with the
    /// queue observed empty. Default is polling-based (see
    /// [`ConcurrentQueue::pop_async`]); CMP overrides it with waker
    /// wakeups plus a shared-timer expiry.
    fn pop_deadline_async(&self, deadline: Instant) -> BoxFuture<'_, Option<T>> {
        Box::pin(PollPopDeadline {
            queue: self,
            deadline,
            _item: PhantomData,
        })
    }

    /// Async batch dequeue: resolves to a run of 1..=`max` items in
    /// queue order (`max == 0` resolves immediately empty). Default is
    /// polling-based over [`ConcurrentQueue::try_dequeue_batch`]; CMP
    /// overrides it with its amortized claimed-run dequeue behind a
    /// waker registration.
    fn pop_async_batch(&self, max: usize) -> BoxFuture<'_, Vec<T>> {
        Box::pin(PollPopBatch {
            queue: self,
            max,
            _item: PhantomData,
        })
    }

    /// Wake every consumer currently parked in a blocking dequeue. The
    /// default is a no-op because the default blocking dequeues poll
    /// with bounded sleeps and never park indefinitely; parking
    /// implementations override it to kick their waiters immediately.
    ///
    /// This is a *wake*, not a cancellation: a woken
    /// [`ConcurrentQueue::pop_blocking`]/
    /// [`ConcurrentQueue::pop_blocking_batch`] caller that still finds
    /// the queue empty re-parks and keeps waiting — those calls return
    /// only when an item arrives. Shutdown/drain paths must therefore
    /// use the `pop_deadline*` variants (as the coordinator's worker
    /// and batcher loops do), with `wake_all` serving to cut the
    /// remaining deadline short.
    fn wake_all(&self) {}

    /// Short static identifier used by the benchmark reports.
    fn name(&self) -> &'static str;

    /// Whether dequeue order is the global enqueue (link) order.
    fn is_strict_fifo(&self) -> bool;

    /// Whether all operations are lock-free.
    fn is_lock_free(&self) -> bool;

    /// Whether capacity is fixed at construction.
    fn is_bounded(&self) -> bool {
        false
    }

    /// Adaptive-control observability (DESIGN.md §15): the queue's
    /// current park ratio, reclamation probability, and spin budget,
    /// reported into bench rows and the `/metrics` endpoint. Default
    /// `None` — implementations without a control plane report
    /// nothing; CMP overrides it.
    fn control_report(&self) -> Option<ControlReport> {
        None
    }
}

/// Point-in-time adaptive-control observations reported by a queue
/// through [`ConcurrentQueue::control_report`] (DESIGN.md §15).
/// Fields are individually optional: an implementation reports only
/// what it measures.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ControlReport {
    /// Fraction of blocking-wait exits that went through a park
    /// registration (`parks / (spins + parks)`); `None` when the
    /// inputs are not tracked or nothing has waited yet.
    pub park_ratio: Option<f64>,
    /// Reclamation Bernoulli probability in effect — the live,
    /// occupancy-tuned value in adaptive mode, the configured
    /// constant otherwise.
    pub reclaim_p: Option<f64>,
    /// Spin steps a blocking waiter performs before parking.
    pub spin_budget: Option<u32>,
}

/// Identifier for each queue implementation, used by the CLI and the
/// benchmark harness to instantiate comparators uniformly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    /// The paper's contribution (Cyclic Memory Protection).
    Cmp,
    /// CMP with the adaptive control plane on (DESIGN.md §15):
    /// learned spin budget, occupancy-tuned Bernoulli reclamation.
    CmpAdaptive,
    /// Michael & Scott + hazard pointers — the paper's "Boost" comparator.
    MsHp,
    /// Michael & Scott + epoch-based reclamation (§2.2 discussion).
    MsEbr,
    /// M&S *with* the original helping mechanism (§3.4 ablation).
    MsHelping,
    /// Per-producer segmented relaxed-FIFO — "moodycamel" stand-in.
    Segmented,
    /// Vyukov bounded MPMC ring (fixed capacity).
    Vyukov,
    /// Mutex-protected VecDeque — TBB/Folly-style blocking comparator.
    Mutex,
    /// Sharded CMP fabric (strict mode, 4 shards) — the §13
    /// scale-out facade, benched against the single-queue CMP.
    Sharded,
}

impl Impl {
    /// All implementations, in the order the paper's tables list them
    /// (CMP, Moodycamel, Boost) followed by the extra comparators.
    pub const ALL: [Impl; 9] = [
        Impl::Cmp,
        Impl::CmpAdaptive,
        Impl::Segmented,
        Impl::MsHp,
        Impl::MsEbr,
        Impl::MsHelping,
        Impl::Vyukov,
        Impl::Mutex,
        Impl::Sharded,
    ];

    /// The paper's evaluation set (Figure 1, Tables 1–3, Figure 2).
    pub const PAPER_SET: [Impl; 3] = [Impl::Cmp, Impl::Segmented, Impl::MsHp];

    /// Short machine-readable identifier (CLI `--impls` values, report
    /// keys).
    pub fn name(&self) -> &'static str {
        match self {
            Impl::Cmp => "cmp",
            Impl::CmpAdaptive => "cmp-adaptive",
            Impl::MsHp => "ms-hp",
            Impl::MsEbr => "ms-ebr",
            Impl::MsHelping => "ms-helping",
            Impl::Segmented => "segmented",
            Impl::Vyukov => "vyukov",
            Impl::Mutex => "mutex",
            Impl::Sharded => "sharded",
        }
    }

    /// Display label matching the paper's tables where applicable.
    pub fn label(&self) -> &'static str {
        match self {
            Impl::Cmp => "CMP",
            Impl::CmpAdaptive => "CMP (adaptive control)",
            Impl::MsHp => "Boost-like (M&S+HP)",
            Impl::MsEbr => "M&S+EBR",
            Impl::MsHelping => "M&S (helping)",
            Impl::Segmented => "Moodycamel-like (segmented)",
            Impl::Vyukov => "Vyukov (bounded)",
            Impl::Mutex => "Mutex (TBB/Folly-like)",
            Impl::Sharded => "Sharded CMP (strict, 4 shards)",
        }
    }

    /// Inverse of [`Impl::name`]; `None` for unknown identifiers.
    pub fn parse(s: &str) -> Option<Impl> {
        Impl::ALL.iter().copied().find(|i| i.name() == s)
    }

    /// Instantiate. `capacity_hint` sizes the bounded Vyukov ring (other
    /// implementations are unbounded and ignore it).
    ///
    /// Perf knob: setting `CMPQ_NO_STATS=1` builds the CMP queue with
    /// statistics counters disabled (used by the §Perf experiments to
    /// quantify the counters' cost; tests leave it unset).
    pub fn make<T: Send + 'static>(&self, capacity_hint: usize) -> Arc<dyn ConcurrentQueue<T>> {
        match self {
            Impl::Cmp => {
                let mut cfg = cmp::CmpConfig::default();
                if std::env::var_os("CMPQ_NO_STATS").is_some() {
                    cfg = cfg.without_stats();
                }
                Arc::new(cmp::CmpQueue::with_config(cfg))
            }
            Impl::CmpAdaptive => {
                // Bernoulli trigger so the occupancy-tuned live `p`
                // actually drives reclamation (Modulo ignores it).
                let mut cfg = cmp::CmpConfig::default()
                    .with_trigger(cmp::ReclaimTrigger::Bernoulli)
                    .with_adaptive();
                if std::env::var_os("CMPQ_NO_STATS").is_some() {
                    cfg = cfg.without_stats();
                }
                Arc::new(cmp::CmpQueue::with_config(cfg))
            }
            Impl::MsHp => Arc::new(baselines::ms_hp::MsHpQueue::new()),
            Impl::MsEbr => Arc::new(baselines::ms_ebr::MsEbrQueue::new()),
            Impl::MsHelping => Arc::new(baselines::ms_helping::MsHelpingQueue::new()),
            Impl::Segmented => Arc::new(baselines::segmented::SegmentedQueue::new()),
            Impl::Vyukov => Arc::new(baselines::vyukov::VyukovQueue::new(capacity_hint.max(2))),
            Impl::Mutex => Arc::new(baselines::mutex_queue::MutexQueue::new()),
            Impl::Sharded => {
                let mut cfg = cmp::CmpConfig::default();
                if std::env::var_os("CMPQ_NO_STATS").is_some() {
                    cfg = cfg.without_stats();
                }
                Arc::new(sharded::ShardedCmp::with_config(
                    sharded::ShardedConfig::default().with_shard_config(cfg),
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn impl_names_roundtrip() {
        for i in Impl::ALL {
            assert_eq!(Impl::parse(i.name()), Some(i));
        }
        assert_eq!(Impl::parse("nope"), None);
    }

    #[test]
    fn paper_set_is_subset_of_all() {
        for i in Impl::PAPER_SET {
            assert!(Impl::ALL.contains(&i));
        }
    }

    #[test]
    fn make_and_smoke_every_impl() {
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
            assert_eq!(q.name(), i.name());
            q.enqueue(7);
            q.enqueue(8);
            assert_eq!(q.try_dequeue(), Some(7));
            assert_eq!(q.try_dequeue(), Some(8));
            assert_eq!(q.try_dequeue(), None);
        }
    }

    #[test]
    fn batch_roundtrip_every_impl() {
        // The default trait impls make the batch API uniform across all
        // comparators; CMP exercises its native override.
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
            q.try_enqueue_batch((0..20).collect::<Vec<_>>())
                .unwrap_or_else(|_| panic!("{} rejected a small batch", i.name()));
            let mut out = Vec::new();
            assert_eq!(q.try_dequeue_batch(7, &mut out), 7, "{}", i.name());
            assert_eq!(q.try_dequeue_batch(100, &mut out), 13, "{}", i.name());
            assert_eq!(q.try_dequeue_batch(1, &mut out), 0, "{}", i.name());
            if q.is_strict_fifo() {
                assert_eq!(out, (0..20).collect::<Vec<_>>(), "{}", i.name());
            } else {
                let mut sorted = out.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..20).collect::<Vec<_>>(), "{}", i.name());
            }
        }
    }

    #[test]
    fn default_try_enqueue_batch_returns_rejected_suffix() {
        // Vyukov with capacity 4: a batch of 6 must hand back the last
        // two items (default prefix semantics).
        let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Vyukov.make(4);
        let rest = q
            .try_enqueue_batch((0..6).collect::<Vec<_>>())
            .unwrap_err();
        assert_eq!(rest, vec![4, 5]);
        let mut out = Vec::new();
        assert_eq!(q.try_dequeue_batch(10, &mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn default_blocking_pops_poll_through() {
        // Every implementation (CMP overrides, baselines use the polling
        // defaults) must deliver via the blocking/deadline paths.
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
            q.enqueue(5);
            assert_eq!(q.pop_blocking(), 5, "{}", i.name());
            q.enqueue(6);
            let d = Instant::now() + Duration::from_secs(5);
            assert_eq!(q.pop_deadline(d), Some(6), "{}", i.name());
            q.try_enqueue_batch(vec![1, 2, 3]).unwrap();
            let mut out = Vec::new();
            assert_eq!(q.pop_blocking_batch(8, &mut out), 3, "{}", i.name());
            q.try_enqueue_batch(vec![7, 8]).unwrap();
            let d = Instant::now() + Duration::from_secs(5);
            assert_eq!(q.pop_deadline_batch(8, &mut out, d), 2, "{}", i.name());
        }
    }

    #[test]
    fn default_pop_deadline_times_out_empty() {
        let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Mutex.make(16);
        let t0 = Instant::now();
        assert_eq!(q.pop_deadline(t0 + Duration::from_millis(20)), None);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        let mut out = Vec::new();
        let t0 = Instant::now();
        assert_eq!(
            q.pop_deadline_batch(4, &mut out, t0 + Duration::from_millis(20)),
            0
        );
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // max == 0 returns immediately, even with a far deadline.
        let t0 = Instant::now();
        assert_eq!(
            q.pop_deadline_batch(0, &mut out, t0 + Duration::from_secs(30)),
            0
        );
        assert!(t0.elapsed() < Duration::from_secs(5));
        q.wake_all(); // default no-op must exist for every impl
    }

    #[test]
    fn async_defaults_deliver_for_every_impl() {
        use crate::util::executor::block_on;
        // Every implementation (CMP overrides with waker wakeups, the
        // baselines use the polling defaults) must deliver through the
        // async paths.
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
            q.enqueue(5);
            assert_eq!(block_on(q.pop_async()), 5, "{}", i.name());
            q.enqueue(6);
            let d = Instant::now() + Duration::from_secs(5);
            assert_eq!(block_on(q.pop_deadline_async(d)), Some(6), "{}", i.name());
            q.try_enqueue_batch(vec![1, 2, 3]).unwrap();
            let run = block_on(q.pop_async_batch(8));
            if q.is_strict_fifo() {
                assert_eq!(run, vec![1, 2, 3], "{}", i.name());
            } else {
                assert_eq!(run.len(), 3, "{}", i.name());
            }
            assert!(block_on(q.pop_async_batch(0)).is_empty(), "{}", i.name());
        }
    }

    #[test]
    fn push_async_fast_path_every_impl() {
        use crate::util::executor::block_on;
        // With headroom, push_async resolves without suspending for
        // every implementation (the unbounded fast path, plus a
        // non-full bounded ring).
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(64);
            block_on(q.push_async(1));
            block_on(q.push_async(2));
            assert_eq!(q.try_dequeue(), Some(1), "{}", i.name());
            assert_eq!(q.try_dequeue(), Some(2), "{}", i.name());
        }
    }

    #[test]
    fn push_async_awaits_capacity_on_full_bounded() {
        use crate::util::executor::block_on;
        let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Vyukov.make(2);
        q.enqueue(0);
        q.enqueue(1);
        assert!(q.try_enqueue(9).is_err(), "ring is full");
        let q2 = q.clone();
        let popper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.try_dequeue()
        });
        let t0 = Instant::now();
        block_on(q.push_async(2)); // suspends until the pop frees a slot
        assert!(
            t0.elapsed() >= Duration::from_millis(20),
            "push_async resolved before capacity existed"
        );
        assert_eq!(popper.join().unwrap(), Some(0));
        assert_eq!(q.try_dequeue(), Some(1));
        assert_eq!(q.try_dequeue(), Some(2));
    }

    #[test]
    fn async_default_deadline_times_out_empty() {
        use crate::util::executor::block_on;
        let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Mutex.make(16);
        let t0 = Instant::now();
        let out = block_on(q.pop_deadline_async(t0 + Duration::from_millis(20)));
        assert_eq!(out, None);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn async_resolves_after_cross_thread_push() {
        use crate::util::executor::block_on;
        for i in [Impl::Cmp, Impl::Mutex] {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(64);
            let q2 = q.clone();
            let h = std::thread::spawn(move || block_on(q2.pop_async()));
            std::thread::sleep(Duration::from_millis(10));
            q.enqueue(77);
            assert_eq!(h.join().unwrap(), 77, "{}", i.name());
        }
    }

    #[test]
    fn trait_metadata_is_consistent() {
        let cmp: Arc<dyn ConcurrentQueue<u32>> = Impl::Cmp.make(0);
        assert!(cmp.is_strict_fifo());
        assert!(cmp.is_lock_free());
        assert!(!cmp.is_bounded());

        let seg: Arc<dyn ConcurrentQueue<u32>> = Impl::Segmented.make(0);
        assert!(!seg.is_strict_fifo(), "segmented queue relaxes FIFO");

        let vy: Arc<dyn ConcurrentQueue<u32>> = Impl::Vyukov.make(64);
        assert!(vy.is_bounded());

        let mx: Arc<dyn ConcurrentQueue<u32>> = Impl::Mutex.make(0);
        assert!(!mx.is_lock_free());
    }
}
