//! Concurrent MPMC queue implementations: the paper's CMP queue plus
//! every comparator its evaluation uses or its related-work section
//! discusses, behind one [`ConcurrentQueue`] trait so the benchmark
//! harness can sweep them uniformly.

pub mod baselines;
pub mod cmp;
pub mod reclamation;

use std::sync::Arc;

use crate::util::Backoff;

/// Common interface over all queue implementations.
///
/// All methods take `&self`; implementations are internally synchronized
/// (lock-free except the explicitly blocking baselines).
pub trait ConcurrentQueue<T: Send>: Send + Sync {
    /// Attempt to enqueue. Bounded queues return `Err(item)` when full;
    /// unbounded queues only fail on allocation exhaustion (never in the
    /// default configurations).
    fn try_enqueue(&self, item: T) -> Result<(), T>;

    /// Attempt to dequeue. `None` means empty *at the linearization
    /// point* (or, for CMP past its protection window, a lost claim —
    /// see DESIGN.md §6).
    fn try_dequeue(&self) -> Option<T>;

    /// Enqueue, spinning with backoff until accepted.
    fn enqueue(&self, mut item: T) {
        let mut backoff = Backoff::new();
        loop {
            match self.try_enqueue(item) {
                Ok(()) => return,
                Err(it) => {
                    item = it;
                    backoff.spin();
                }
            }
        }
    }

    /// Short static identifier used by the benchmark reports.
    fn name(&self) -> &'static str;

    /// Whether dequeue order is the global enqueue (link) order.
    fn is_strict_fifo(&self) -> bool;

    /// Whether all operations are lock-free.
    fn is_lock_free(&self) -> bool;

    /// Whether capacity is fixed at construction.
    fn is_bounded(&self) -> bool {
        false
    }
}

/// Identifier for each queue implementation, used by the CLI and the
/// benchmark harness to instantiate comparators uniformly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    /// The paper's contribution (Cyclic Memory Protection).
    Cmp,
    /// Michael & Scott + hazard pointers — the paper's "Boost" comparator.
    MsHp,
    /// Michael & Scott + epoch-based reclamation (§2.2 discussion).
    MsEbr,
    /// M&S *with* the original helping mechanism (§3.4 ablation).
    MsHelping,
    /// Per-producer segmented relaxed-FIFO — "moodycamel" stand-in.
    Segmented,
    /// Vyukov bounded MPMC ring (fixed capacity).
    Vyukov,
    /// Mutex-protected VecDeque — TBB/Folly-style blocking comparator.
    Mutex,
}

impl Impl {
    /// All implementations, in the order the paper's tables list them
    /// (CMP, Moodycamel, Boost) followed by the extra comparators.
    pub const ALL: [Impl; 7] = [
        Impl::Cmp,
        Impl::Segmented,
        Impl::MsHp,
        Impl::MsEbr,
        Impl::MsHelping,
        Impl::Vyukov,
        Impl::Mutex,
    ];

    /// The paper's evaluation set (Figure 1, Tables 1–3, Figure 2).
    pub const PAPER_SET: [Impl; 3] = [Impl::Cmp, Impl::Segmented, Impl::MsHp];

    pub fn name(&self) -> &'static str {
        match self {
            Impl::Cmp => "cmp",
            Impl::MsHp => "ms-hp",
            Impl::MsEbr => "ms-ebr",
            Impl::MsHelping => "ms-helping",
            Impl::Segmented => "segmented",
            Impl::Vyukov => "vyukov",
            Impl::Mutex => "mutex",
        }
    }

    /// Display label matching the paper's tables where applicable.
    pub fn label(&self) -> &'static str {
        match self {
            Impl::Cmp => "CMP",
            Impl::MsHp => "Boost-like (M&S+HP)",
            Impl::MsEbr => "M&S+EBR",
            Impl::MsHelping => "M&S (helping)",
            Impl::Segmented => "Moodycamel-like (segmented)",
            Impl::Vyukov => "Vyukov (bounded)",
            Impl::Mutex => "Mutex (TBB/Folly-like)",
        }
    }

    pub fn parse(s: &str) -> Option<Impl> {
        Impl::ALL.iter().copied().find(|i| i.name() == s)
    }

    /// Instantiate. `capacity_hint` sizes the bounded Vyukov ring (other
    /// implementations are unbounded and ignore it).
    ///
    /// Perf knob: setting `CMPQ_NO_STATS=1` builds the CMP queue with
    /// statistics counters disabled (used by the §Perf experiments to
    /// quantify the counters' cost; tests leave it unset).
    pub fn make<T: Send + 'static>(&self, capacity_hint: usize) -> Arc<dyn ConcurrentQueue<T>> {
        match self {
            Impl::Cmp => {
                let mut cfg = cmp::CmpConfig::default();
                if std::env::var_os("CMPQ_NO_STATS").is_some() {
                    cfg = cfg.without_stats();
                }
                Arc::new(cmp::CmpQueue::with_config(cfg))
            }
            Impl::MsHp => Arc::new(baselines::ms_hp::MsHpQueue::new()),
            Impl::MsEbr => Arc::new(baselines::ms_ebr::MsEbrQueue::new()),
            Impl::MsHelping => Arc::new(baselines::ms_helping::MsHelpingQueue::new()),
            Impl::Segmented => Arc::new(baselines::segmented::SegmentedQueue::new()),
            Impl::Vyukov => Arc::new(baselines::vyukov::VyukovQueue::new(capacity_hint.max(2))),
            Impl::Mutex => Arc::new(baselines::mutex_queue::MutexQueue::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn impl_names_roundtrip() {
        for i in Impl::ALL {
            assert_eq!(Impl::parse(i.name()), Some(i));
        }
        assert_eq!(Impl::parse("nope"), None);
    }

    #[test]
    fn paper_set_is_subset_of_all() {
        for i in Impl::PAPER_SET {
            assert!(Impl::ALL.contains(&i));
        }
    }

    #[test]
    fn make_and_smoke_every_impl() {
        for i in Impl::ALL {
            let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
            assert_eq!(q.name(), i.name());
            q.enqueue(7);
            q.enqueue(8);
            assert_eq!(q.try_dequeue(), Some(7));
            assert_eq!(q.try_dequeue(), Some(8));
            assert_eq!(q.try_dequeue(), None);
        }
    }

    #[test]
    fn trait_metadata_is_consistent() {
        let cmp: Arc<dyn ConcurrentQueue<u32>> = Impl::Cmp.make(0);
        assert!(cmp.is_strict_fifo());
        assert!(cmp.is_lock_free());
        assert!(!cmp.is_bounded());

        let seg: Arc<dyn ConcurrentQueue<u32>> = Impl::Segmented.make(0);
        assert!(!seg.is_strict_fifo(), "segmented queue relaxes FIFO");

        let vy: Arc<dyn ConcurrentQueue<u32>> = Impl::Vyukov.make(64);
        assert!(vy.is_bounded());

        let mx: Arc<dyn ConcurrentQueue<u32>> = Impl::Mutex.make(0);
        assert!(!mx.is_lock_free());
    }
}
