//! Comparator queues (§2.3.2, §4): every implementation the paper
//! evaluates against or discusses, rebuilt from scratch (DESIGN.md §3
//! documents each stand-in).

pub mod ms_ebr;
pub mod ms_helping;
pub mod ms_hp;
pub mod mutex_queue;
pub mod segmented;
pub mod vyukov;
