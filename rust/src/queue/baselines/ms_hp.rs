//! Michael & Scott queue + hazard-pointer reclamation — the paper's
//! "Boost Lockfree Queue" comparator (§4: "based on the M&S algorithm,
//! using hazard pointers for memory safety and CAS for
//! synchronization"). Strict FIFO, unbounded, lock-free, and paying the
//! full coordination cost CMP eliminates: two hazard publications plus
//! validation per operation and `O(P × K)` scans on reclamation.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicPtr, Ordering};

use crossbeam_utils::CachePadded;

use crate::queue::reclamation::hazard::{drop_box, HazardDomain};
use crate::queue::ConcurrentQueue;

pub(crate) struct MsNode<T> {
    next: AtomicPtr<MsNode<T>>,
    /// Valid for every node except the current dummy (whose payload has
    /// already been moved out by the dequeue that made it dummy).
    data: UnsafeCell<MaybeUninit<T>>,
}

impl<T> MsNode<T> {
    fn dummy() -> *mut Self {
        Box::into_raw(Box::new(MsNode {
            next: AtomicPtr::new(ptr::null_mut()),
            data: UnsafeCell::new(MaybeUninit::uninit()),
        }))
    }

    fn with_data(v: T) -> *mut Self {
        Box::into_raw(Box::new(MsNode {
            next: AtomicPtr::new(ptr::null_mut()),
            data: UnsafeCell::new(MaybeUninit::new(v)),
        }))
    }
}

/// M&S queue with hazard-pointer reclamation.
pub struct MsHpQueue<T> {
    head: CachePadded<AtomicPtr<MsNode<T>>>,
    tail: CachePadded<AtomicPtr<MsNode<T>>>,
    domain: HazardDomain,
}

unsafe impl<T: Send> Send for MsHpQueue<T> {}
unsafe impl<T: Send> Sync for MsHpQueue<T> {}

impl<T: Send> Default for MsHpQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> MsHpQueue<T> {
    /// An empty queue with its own hazard-pointer domain.
    pub fn new() -> Self {
        let dummy = MsNode::<T>::dummy();
        MsHpQueue {
            head: CachePadded::new(AtomicPtr::new(dummy)),
            tail: CachePadded::new(AtomicPtr::new(dummy)),
            domain: HazardDomain::new(),
        }
    }

    /// Reclamation diagnostics (FAULT experiment).
    pub fn domain(&self) -> &HazardDomain {
        &self.domain
    }

    /// Enqueue (always succeeds; the list is unbounded).
    pub fn push(&self, item: T) {
        let node = MsNode::with_data(item);
        loop {
            // Hazard-protect the tail before dereferencing: the original
            // reactive protect-validate loop (§3.1 contrast).
            let tail = self.domain.protect(0, &self.tail);
            let next = unsafe { (*tail).next.load(Ordering::Acquire) };
            // Revalidate tail (Algorithm 2 line 5 in the paper).
            if tail != self.tail.load(Ordering::Acquire) {
                continue;
            }
            if !next.is_null() {
                // Original M&S helping: advance tail using possibly
                // stale next (the very mechanism §3.4 removes).
                let _ = self.tail.compare_exchange(
                    tail,
                    next,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                continue;
            }
            if unsafe {
                (*tail)
                    .next
                    .compare_exchange(ptr::null_mut(), node, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            } {
                let _ = self
                    .tail
                    .compare_exchange(tail, node, Ordering::AcqRel, Ordering::Acquire);
                self.domain.clear(0);
                return;
            }
        }
    }

    /// Dequeue; `None` when empty at the linearization point.
    pub fn pop(&self) -> Option<T> {
        loop {
            let head = self.domain.protect(0, &self.head);
            let tail = self.tail.load(Ordering::Acquire);
            // Protect head->next before dereferencing it.
            let next = self.domain.protect(1, unsafe { &(*head).next });
            if head != self.head.load(Ordering::Acquire) {
                continue;
            }
            if next.is_null() {
                self.domain.clear_all();
                return None; // empty
            }
            if head == tail {
                // Tail lagging: help advance, retry.
                let _ = self.tail.compare_exchange(
                    tail,
                    next,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                continue;
            }
            // Swing head: the winner gains exclusive rights to next.data.
            if self
                .head
                .compare_exchange(head, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let data = unsafe { (*(*next).data.get()).assume_init_read() };
                self.domain.clear_all();
                // Retire the old dummy (its payload was moved out when it
                // became dummy — MaybeUninit drops nothing).
                unsafe { self.domain.retire(head, drop_box::<MsNode<T>>) };
                return Some(data);
            }
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for MsHpQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item);
        Ok(())
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn name(&self) -> &'static str {
        "ms-hp"
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        true
    }
}

impl<T> Drop for MsHpQueue<T> {
    fn drop(&mut self) {
        // Drain remaining nodes: the first is the dummy (no payload),
        // the rest carry live payloads.
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            let mut is_dummy = true;
            while !cur.is_null() {
                let next = (*cur).next.load(Ordering::Acquire);
                if !is_dummy {
                    (*(*cur).data.get()).assume_init_drop();
                }
                drop(Box::from_raw(cur));
                cur = next;
                is_dummy = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn spsc_fifo() {
        let q: MsHpQueue<u32> = MsHpQueue::new();
        for i in 0..500 {
            q.push(i);
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn drop_with_live_items_frees_payloads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);
        {
            let q: MsHpQueue<D> = MsHpQueue::new();
            for _ in 0..7 {
                q.push(D);
            }
            drop(q.pop());
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q: Arc<MsHpQueue<u64>> = Arc::new(MsHpQueue::new());
        let done = Arc::new(AtomicBool::new(false));
        let per = 3000u64;
        let producers: Vec<_> = (0..3u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p * per + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Some(v) => got.push(v),
                            None => {
                                if done.load(Ordering::Acquire) && q.pop().is_none() {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        assert_eq!(all.len() as u64, 3 * per);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, 3 * per);
    }

    #[test]
    fn reclamation_happens_under_churn() {
        let q: MsHpQueue<u64> = MsHpQueue::new();
        for i in 0..10_000 {
            q.push(i);
            q.pop();
        }
        assert!(q.domain().freed() > 0, "hazard scans freed nodes");
    }
}
